"""§Planner: Crispy HBM-planner extrapolation accuracy — profile five
reduced-depth compiles, extrapolate per-device memory to the full depth,
compare against the ground-truth full compile. The at-scale Table I row:
'did Crispy get the memory requirement right without running the job'."""
from __future__ import annotations

import dataclasses
import time

from repro.configs import SHAPES, get_arch
from repro.configs.base import RunConfig
from repro.core.hbm_planner import HBMPlanner
from repro.launch.mesh import compat_make_mesh

GiB = 1024 ** 3

ARCHS_TO_CHECK = ["deepseek-7b", "chatglm3-6b", "rwkv6-7b", "whisper-small"]


def run(verbose=True):
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=256,
                                global_batch=4)
    run_cfg = RunConfig(attn_impl="full", remat="nothing",
                        compute_dtype="float32", microbatches=1)
    planner = HBMPlanner(leeway=0.0)
    rows = []
    for arch in ARCHS_TO_CHECK:
        cfg = get_arch(arch).reduced(n_layers=24, d_model=128,
                                     vocab_size=512)
        rep = planner.plan(cfg, shape, mesh, run=run_cfg, anchor_layers=10,
                           select=False)
        truth = planner.profile_memory(cfg, shape, mesh, run_cfg)
        pred = rep.predicted_per_dev_gib * GiB
        rel = abs(pred - truth) / truth
        rows.append({"arch": arch, "r2": rep.model.r2,
                     "confident": rep.model.confident,
                     "rel_err": rel, "wall_s": rep.profile_wall_s})
        if verbose:
            print(f"{arch:18s} R2={rep.model.r2:8.5f} "
                  f"gate={'PASS' if rep.model.confident else 'fallback'} "
                  f"pred={pred / 2**20:8.1f}MiB truth={truth / 2**20:8.1f}MiB "
                  f"err={rel:6.2%} profile={rep.profile_wall_s:5.1f}s")
    return rows


def main():
    t0 = time.monotonic()
    rows = run()
    wall = time.monotonic() - t0
    import numpy as np
    max_err = max(r["rel_err"] for r in rows if r["confident"])
    n_pass = sum(r["confident"] for r in rows)
    print(f"planner_validation,{wall / max(len(rows),1) * 1e6:.0f},"
          f"max_rel_err={max_err:.4f};gate_pass={n_pass}/{len(rows)}")


if __name__ == "__main__":
    main()
