"""Tiered load benchmark: the service under four request mixes, across
thread counts, with latency percentiles from client-side timing and the
telemetry plane's own counters — recorded to BENCH_load.json.

The earlier allocation_service_throughput module prints means; this one
is the production-tier harness ROADMAP asks for: per-tier p50/p99
latency + throughput, machine-readable, so the perf trajectory across
PRs is a file diff instead of scrollback archaeology.

Tiers (each drives REQUESTS requests at every thread count):

  warm_start    repeats of confident registered signatures — the
                registry answers, no profiling; the latency floor.
  classifier    novel NOISY signatures every request — unconfident fits
                rescued (or not) by nearest-neighbor transfer; the full
                measure -> fit -> classify path.
  fresh         novel LINEAR signatures every request — profile + fit +
                register; the cold-path ceiling.
  tag_override  repeats of a noisy signature under rotating Flora tag
                palettes — tag-keyed plans and the plan cache under
                palette churn.
  mixed         70% warm / 15% fresh / 10% classifier / 5% tagged — the
                steady state a service actually sees.

Per (tier, threads) the JSON records {p50_ms, p95_ms, p99_ms, mean_ms,
throughput_rps, wall_s, requests, counters, spans_recorded, exemplars}
where `counters` is the delta of the service's `repro.telemetry`
counter snapshot over the tier — so e.g. warm_start's
`pipeline.warm_start.hits` == its request count is asserted by CI, not
eyeballed. `spans_recorded` is the process trace-ring delta (how many
sampled span trees the tier produced) and `exemplars` counts the
histogram exemplar slots populated by tier end — the tracing plane's
own overhead ledger, tracked per PR like the latencies.

Backends: every tier runs against a process-local service (top-level
"tiers", the historical shape) AND — unless LOAD_TIERS_BACKENDS says
otherwise — against a service sharing state through a real crispy-daemon
subprocess over its unix socket ("backends"."daemon"."tiers"). The
daemon-backed rows are the wire-path trajectory the ROADMAP tracks: the
per-batch store/registry refreshes, profile-point write-through, and
registry flushes all cross the newline-JSON protocol, so protocol work
(batching, pipelining) shows up here as a BENCH_load.json diff.

Env knobs: LOAD_TIERS_REQUESTS (default 60), LOAD_TIERS_THREADS
(comma-separated, default "1,8"), LOAD_TIERS_BACKENDS (comma-separated
subset of "local,daemon", default both), BENCH_LOAD_PATH (default
./BENCH_load.json).

Final CSV line: load_tiers,<mixed us/req @ max threads>,<mixed p99 ms>
(from the local run, or the daemon run when local is disabled)
"""
import json
import os
import subprocess
import sys
import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor

from repro.allocator import AllocationRequest, AllocationService
from repro.core.catalog import aws_like_catalog
from repro.core.simulator import (GiB, JobSpec, build_history,
                                  make_profile_fn, scout_like_jobs)
from repro.telemetry import default_ring

TAG_PALETTES = (("etl",), ("ml", "iterative"), ("adhoc",), ("etl", "ml"))


def _variant(base: JobSpec, name: str, mem_profile: str) -> JobSpec:
    return JobSpec(name, base.framework, base.dataset_gib, base.cpu_hours,
                   base.working_set_factor, base.iterations, base.caching,
                   mem_profile)


def _request(job: JobSpec, tags=None) -> AllocationRequest:
    full = job.dataset_gib * GiB
    return AllocationRequest(job.name, make_profile_fn(job), full,
                             anchor=full * 0.01, tags=tags)


class _TierMix:
    """Generates one tier's request stream. A fresh instance per run so
    novel-signature tiers never accidentally warm themselves across
    thread counts."""

    def __init__(self, kind: str, corpus, run_id: str):
        self.kind = kind
        self.corpus = corpus
        self.run_id = run_id
        self.linear = [j for j in corpus if j.mem_profile == "linear"]
        self.noisy = [j for j in corpus if j.mem_profile == "noisy"]
        self._n = 0
        self._lock = threading.Lock()

    def _next_i(self) -> int:
        with self._lock:
            i, self._n = self._n, self._n + 1
            return i

    def request(self) -> AllocationRequest:
        i = self._next_i()
        k = self.kind
        if k == "mixed":
            r = i % 20
            k = ("warm_start" if r < 14 else
                 "fresh" if r < 17 else
                 "classifier" if r < 19 else "tag_override")
        if k == "warm_start":
            return _request(self.linear[i % len(self.linear)])
        if k == "classifier":
            base = self.noisy[i % len(self.noisy)]
            job = _variant(base, f"clsf-{self.run_id}-{i}/{base.framework}",
                           "noisy")
            return _request(job)
        if k == "fresh":
            base = self.linear[i % len(self.linear)]
            job = _variant(base, f"fresh-{self.run_id}-{i}/{base.framework}",
                           "linear")
            return _request(job)
        if k == "tag_override":
            base = self.noisy[i % len(self.noisy)]
            return _request(base, tags=TAG_PALETTES[i % len(TAG_PALETTES)])
        raise ValueError(f"unknown tier {self.kind!r}")


def _pctl(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def _counter_delta(before, after) -> dict:
    keys = set(before.get("counters", {})) | set(after.get("counters", {}))
    out = {}
    for key in sorted(keys):
        d = (after.get("counters", {}).get(key, 0.0)
             - before.get("counters", {}).get(key, 0.0))
        if d:
            out[key] = round(d, 6)
    return out


def _drive_tier(svc: AllocationService, mix: _TierMix, requests: int,
                threads: int) -> dict:
    lat = []
    lock = threading.Lock()

    def one(_i) -> None:
        req = mix.request()
        t0 = time.monotonic()
        svc.allocate(req)
        dt = time.monotonic() - t0
        with lock:
            lat.append(dt)

    before = svc.metrics()
    spans_before = default_ring().recorded
    t0 = time.monotonic()
    if threads <= 1:
        for i in range(requests):
            one(i)
    else:
        with ThreadPoolExecutor(threads) as ex:
            list(ex.map(one, range(requests)))
    wall = time.monotonic() - t0
    after = svc.metrics()
    lat.sort()
    return {"requests": requests,
            "wall_s": round(wall, 6),
            "throughput_rps": round(requests / wall, 2) if wall else 0.0,
            "mean_ms": round(sum(lat) / len(lat) * 1e3, 4),
            "p50_ms": round(_pctl(lat, 0.50) * 1e3, 4),
            "p95_ms": round(_pctl(lat, 0.95) * 1e3, 4),
            "p99_ms": round(_pctl(lat, 0.99) * 1e3, 4),
            "counters": _counter_delta(before, after),
            "spans_recorded": default_ring().recorded - spans_before,
            "exemplars": sum(len(h.get("exemplars", ()))
                             for h in after["histograms"].values())}


def _build_service(catalog, history, corpus, backend=None
                   ) -> AllocationService:
    """Fresh service, prewarmed: one pass over the corpus registers
    confident models for the linear jobs (warm_start substrate) and
    observes every ladder (classifier substrate)."""
    svc = AllocationService(catalog, history, batch_window_s=0.001,
                            backend=backend)
    svc.allocate_many([_request(j) for j in corpus])
    return svc


class _DaemonProcess:
    """A real crispy-daemon subprocess on a fresh unix socket — the
    daemon-backed rows must pay genuine wire round-trips, not in-process
    method calls. None-address when unavailable (no unix sockets /
    failed start): the daemon section is then skipped."""

    def __init__(self):
        self.address = None
        self.child = None
        import socket as _socket
        if not hasattr(_socket, "AF_UNIX"):
            return
        self.address = os.path.join(
            tempfile.mkdtemp(prefix="crispy-load-"), "d.sock")
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env = {**os.environ,
               "PYTHONPATH": src + os.pathsep
               + os.environ.get("PYTHONPATH", "")}
        self.child = subprocess.Popen(
            [sys.executable, "-m", "repro.state.daemon",
             "--socket", self.address],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True)
        from repro.state import DaemonBackend
        client = DaemonBackend(self.address, timeout_s=2.0)
        for _ in range(200):
            if os.path.exists(self.address) and client.ping():
                client.close()
                return
            if self.child.poll() is not None:
                break
            time.sleep(0.05)
        self.stop()
        self.address = None

    def backend(self):
        from repro.state import DaemonBackend
        return DaemonBackend(self.address)

    def stop(self):
        if self.child is None:
            return
        try:
            if self.child.poll() is None and self.address:
                from repro.state import DaemonBackend
                DaemonBackend(self.address, timeout_s=2.0).shutdown_daemon()
            self.child.wait(timeout=10)
        except Exception:
            self.child.kill()
            self.child.wait(timeout=10)
        self.child = None


TIERS = ("warm_start", "classifier", "fresh", "tag_override", "mixed")


def _run_backend(kind: str, catalog, history, corpus, requests, threads,
                 out_tiers, out_hists) -> dict:
    """Drive every tier x thread count against one backend kind
    ("local" | "daemon"); returns the mixed-tier row at max threads."""
    mixed_summary = None
    for nthreads in threads:
        # fresh prewarmed service (and, for the daemon rows, a fresh
        # daemon) per thread count: novel-signature tiers must not
        # inherit a sibling run's registry entries
        daemon = _DaemonProcess() if kind == "daemon" else None
        if daemon is not None and daemon.address is None:
            print(f"{kind}: skipped (no daemon available)")
            return None
        backend = daemon.backend() if daemon is not None else None
        try:
            with _build_service(catalog, history, corpus, backend) as svc:
                for tier in TIERS:
                    mix = _TierMix(tier, corpus,
                                   run_id=f"{kind}-t{nthreads}")
                    row = _drive_tier(svc, mix, requests, nthreads)
                    out_tiers[tier]["by_threads"][str(nthreads)] = row
                    print(f"{kind:>6}/{tier:>13} x{nthreads:<3} "
                          f"p50 {row['p50_ms']:8.3f}ms"
                          f"  p99 {row['p99_ms']:8.3f}ms"
                          f"  {row['throughput_rps']:8.1f} req/s",
                          flush=True)
                # the service's own view of the whole run, percentiles
                # included — service.queue_wait.seconds p99 is the
                # contention signal the wire-path work is judged by
                snap = svc.metrics()
                out_hists[str(nthreads)] = {
                    name: {k: s[k] for k in
                           ("count", "p50", "p95", "p99", "sum")}
                    for name, s in snap["histograms"].items()
                    if name.startswith(("service.", "pipeline.stage."))}
        finally:
            if backend is not None:
                backend.close()
            if daemon is not None:
                daemon.stop()
        mixed_summary = out_tiers["mixed"]["by_threads"][str(nthreads)]
    return mixed_summary


def main() -> None:
    requests = int(os.environ.get("LOAD_TIERS_REQUESTS", "60"))
    threads = [int(t) for t in
               os.environ.get("LOAD_TIERS_THREADS", "1,8").split(",")]
    backends = [b.strip() for b in
                os.environ.get("LOAD_TIERS_BACKENDS",
                               "local,daemon").split(",") if b.strip()]
    out_path = os.environ.get("BENCH_LOAD_PATH", "BENCH_load.json")

    corpus = scout_like_jobs()
    catalog = aws_like_catalog()
    history = build_history(corpus, catalog)

    result = {"benchmark": "load_tiers",
              "created_unix": round(time.time(), 3),
              "requests_per_tier": requests,
              "thread_counts": threads,
              # the historical top-level shape stays the LOCAL run so
              # cross-PR diffs of old files keep lining up
              "tiers": {t: {"by_threads": {}} for t in TIERS},
              "service_histograms": {},
              "backends": {}}

    mixed_summary = None
    for kind in backends:
        if kind == "local":
            tiers, hists = result["tiers"], result["service_histograms"]
        else:
            body = result["backends"].setdefault(
                kind, {"tiers": {t: {"by_threads": {}} for t in TIERS},
                       "service_histograms": {}})
            tiers, hists = body["tiers"], body["service_histograms"]
        summary = _run_backend(kind, catalog, history, corpus, requests,
                               threads, tiers, hists)
        if kind == "daemon" and summary is None:
            result["backends"].pop(kind, None)
        if summary is not None and (mixed_summary is None
                                    or kind == "local"):
            mixed_summary = summary

    tmp = out_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    os.replace(tmp, out_path)
    print(f"wrote {out_path}")

    us_per_req = mixed_summary["wall_s"] / mixed_summary["requests"] * 1e6
    print(f"load_tiers,{us_per_req:.1f},{mixed_summary['p99_ms']:.3f}")


if __name__ == "__main__":
    main()
