"""AllocationService throughput: requests/sec and cache hit-rate under a
zipf-ish mix of repeated and novel jobs submitted from concurrent clients.

Three traffic phases over the simulated scout corpus plus synthetic novel
jobs:
  cold   every signature new — profiling + zoo fit on each
  warm   repeats of confident jobs — served from the model registry
  mixed  80/20 repeat/novel — the steady state a service actually sees

Final CSV line: allocation_service_throughput,<us_per_request>,<hit_rate>
(hit_rate = registry + LRU hits over all plan lookups in the mixed phase).
"""
import time
from concurrent.futures import ThreadPoolExecutor

from repro.allocator import AllocationRequest, AllocationService
from repro.core.catalog import aws_like_catalog
from repro.core.simulator import (GiB, JobSpec, build_history,
                                  make_profile_fn, scout_like_jobs)

WORKERS = 8


def _novel_job(i: int) -> JobSpec:
    base = scout_like_jobs()[i % 4]
    return JobSpec(f"novel-{i}/{base.framework}/gen", base.framework,
                   base.dataset_gib * (1.0 + 0.1 * (i % 7)), base.cpu_hours,
                   base.working_set_factor, base.iterations, base.caching,
                   base.mem_profile)


def _request(job: JobSpec) -> AllocationRequest:
    full = job.dataset_gib * GiB
    return AllocationRequest(job.name, make_profile_fn(job), full,
                             anchor=full * 0.01)


def _drive(svc: AllocationService, jobs) -> float:
    t0 = time.monotonic()
    with ThreadPoolExecutor(WORKERS) as ex:
        list(ex.map(lambda j: svc.allocate(_request(j)), jobs))
    return time.monotonic() - t0


def main() -> None:
    corpus = scout_like_jobs()
    catalog = aws_like_catalog()
    history = build_history(corpus, catalog)

    with AllocationService(catalog, history) as svc:
        cold = list(corpus)
        t_cold = _drive(svc, cold)
        print(f"cold:  {len(cold)} novel jobs in {t_cold:.3f}s "
              f"({len(cold) / t_cold:.0f} req/s), "
              f"{svc.stats.profile_calls} profile runs")

        warm = [corpus[i % len(corpus)] for i in range(64)]
        calls_before = svc.stats.profile_calls
        t_warm = _drive(svc, warm)
        print(f"warm:  {len(warm)} repeats in {t_warm:.3f}s "
              f"({len(warm) / t_warm:.0f} req/s), "
              f"{svc.stats.profile_calls - calls_before} new profile runs, "
              f"{svc.stats.registry_hits} registry hits")

        mixed = []
        for i in range(96):
            mixed.append(corpus[i % len(corpus)] if i % 5 else
                         _novel_job(i))
        reqs_before = svc.stats.requests
        hits_before = (svc.stats.registry_hits + svc.stats.cache_hits)
        lookups_before = hits_before + svc.stats.profile_calls
        t_mixed = _drive(svc, mixed)
        n = svc.stats.requests - reqs_before
        hits = (svc.stats.registry_hits + svc.stats.cache_hits) - hits_before
        lookups = (svc.stats.registry_hits + svc.stats.cache_hits +
                   svc.stats.profile_calls) - lookups_before
        hit_rate = hits / lookups if lookups else 0.0
        us_per_req = t_mixed / n * 1e6
        print(f"mixed: {n} requests (80/20 repeat/novel) in {t_mixed:.3f}s "
              f"({n / t_mixed:.0f} req/s), hit-rate {hit_rate:.0%}")
        s = svc.stats
        print(f"totals: {s.requests} requests, {s.batches} batches, "
              f"{s.profile_calls} profile runs, {s.zoo_confident} models "
              f"registered, {s.classifier_fallbacks} classifier / "
              f"{s.baseline_fallbacks} baseline fallbacks")
        print(f"allocation_service_throughput,{us_per_req:.1f},"
              f"{hit_rate:.3f}")


if __name__ == "__main__":
    main()
