"""Table II analogue: wall-clock profiling time per job — REAL profiling
runs of the seven HiBench-family algorithms on this machine with the
OS-level RSS profiler (paper: 2-20 min on a laptop; here the sample sizes
are scaled so the whole suite profiles in seconds — the paper's 0.5-3 min
per-run band is a parameter, see core/sampling.py)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.local_jobs import LOCAL_JOBS
from repro.core.memory_model import fit_memory_model
from repro.core.profiler import RSSProfiler
from repro.core.sampling import ladder_from_anchor

ANCHOR_BYTES = 48 * 1024 * 1024       # 48 MiB anchor sample


def run(verbose: bool = True):
    profiler = RSSProfiler(interval_s=0.002)
    rows = []
    for name, factory in LOCAL_JOBS.items():
        ladder = ladder_from_anchor(ANCHOR_BYTES)
        # warm the allocator arena at the anchor size (the paper profiles
        # each sample in a fresh Spark JVM; in-process we stabilize instead)
        profiler.profile(factory(int(ladder.anchor)), ladder.anchor)
        t0 = time.monotonic()
        results = [profiler.profile(factory(int(s)), s)
                   for s in ladder.sizes]
        wall = time.monotonic() - t0
        m = fit_memory_model(ladder.sizes,
                             [r.job_mem_bytes for r in results])
        rows.append({"job": name, "profile_s": wall, "r2": m.r2,
                     "confident": m.confident,
                     "slope": m.slope})
        if verbose:
            print(f"{name:16s} profiling {wall:7.2f}s   R2={m.r2:8.5f} "
                  f"gate={'PASS' if m.confident else 'fallback'} "
                  f"slope={m.slope:.3f} B/B")
    mean_s = float(np.mean([r["profile_s"] for r in rows]))
    if verbose:
        print(f"{'Mean':16s} profiling {mean_s:7.2f}s   "
              f"(paper mean: 565 s at full sample sizes)")
    return rows, mean_s


def main():
    rows, mean_s = run(verbose=True)
    n_pass = sum(r["confident"] for r in rows)
    print(f"table2_profiling_time,{mean_s * 1e6:.0f},"
          f"gate_pass={n_pass}/{len(rows)}")


if __name__ == "__main__":
    main()
