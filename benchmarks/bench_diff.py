"""bench_diff: compare two BENCH_load.json artifacts and gate on regressions.

`benchmarks/load_tiers.py` emits BENCH_load.json — per tier (and, when
the daemon harness ran, per backend) a `by_threads` map of
throughput_rps / p99_ms rows. This tool diffs two such files:

    python benchmarks/bench_diff.py BEFORE.json AFTER.json \
        [--threshold 0.20] [--markdown]

For every (backend, tier, threads) row present in BOTH files it prints
throughput and p99 latency side by side with the relative change, then
exits 1 if any row regressed by more than `--threshold` (default 20%):
throughput dropping below (1 - t)x the baseline, or p99 rising above
(1 + t)x. Rows missing from either side are reported but never fail
the gate (tier sets legitimately change across PRs; CI smokes with a
truncated tier matrix). `--markdown` emits a GitHub-flavored table for
$GITHUB_STEP_SUMMARY; CI downloads the previous run's `bench-load`
artifact when one exists and publishes the diff in the job summary.

Exit codes: 0 ok / nothing comparable, 1 regression beyond threshold,
2 bad input files.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterator, List, Tuple

DEFAULT_THRESHOLD = 0.20


def _rows(doc: Dict) -> Iterator[Tuple[Tuple[str, str, str], Dict]]:
    """Yield ((backend, tier, threads), row) for every measured row.
    The top-level `tiers` map is the local-backend run; daemon (or other
    backend) runs live under `backends.<kind>.tiers`."""
    sections = [("local", doc.get("tiers") or {})]
    for kind, sub in (doc.get("backends") or {}).items():
        sections.append((kind, (sub or {}).get("tiers") or {}))
    for backend, tiers in sections:
        for tier, td in tiers.items():
            for threads, row in (td.get("by_threads") or {}).items():
                if isinstance(row, dict) and "throughput_rps" in row:
                    yield (backend, tier, str(threads)), row


def _pct(before: float, after: float) -> float:
    return (after - before) / before * 100.0 if before else 0.0


def diff(before: Dict, after: Dict,
         threshold: float = DEFAULT_THRESHOLD) -> Tuple[List[Dict], bool]:
    """(per-row comparison dicts, any_regression)."""
    b_rows = dict(_rows(before))
    a_rows = dict(_rows(after))
    out: List[Dict] = []
    regressed = False
    for key in sorted(set(b_rows) | set(a_rows)):
        backend, tier, threads = key
        b, a = b_rows.get(key), a_rows.get(key)
        if b is None or a is None:
            out.append({"backend": backend, "tier": tier,
                        "threads": threads,
                        "status": "only-after" if b is None
                        else "only-before"})
            continue
        b_tp, a_tp = b["throughput_rps"], a["throughput_rps"]
        b_p99, a_p99 = b.get("p99_ms", 0.0), a.get("p99_ms", 0.0)
        tp_bad = a_tp < b_tp * (1.0 - threshold)
        p99_bad = b_p99 and a_p99 > b_p99 * (1.0 + threshold)
        row_regressed = bool(tp_bad or p99_bad)
        regressed = regressed or row_regressed
        out.append({"backend": backend, "tier": tier, "threads": threads,
                    "status": "REGRESSED" if row_regressed else "ok",
                    "throughput_before": b_tp, "throughput_after": a_tp,
                    "throughput_pct": _pct(b_tp, a_tp),
                    "p99_before_ms": b_p99, "p99_after_ms": a_p99,
                    "p99_pct": _pct(b_p99, a_p99)})
    return out, regressed


def _format_table(rows: List[Dict], markdown: bool) -> str:
    headers = ("backend/tier", "thr", "rps before", "rps after", "rps Δ%",
               "p99 before", "p99 after", "p99 Δ%", "status")
    body: List[Tuple[str, ...]] = []
    for r in rows:
        name = f"{r['backend']}/{r['tier']}"
        if "throughput_before" not in r:
            body.append((name, r["threads"], "-", "-", "-", "-", "-", "-",
                         r["status"]))
            continue
        body.append((
            name, r["threads"],
            f"{r['throughput_before']:.1f}", f"{r['throughput_after']:.1f}",
            f"{r['throughput_pct']:+.1f}",
            f"{r['p99_before_ms']:.3f}", f"{r['p99_after_ms']:.3f}",
            f"{r['p99_pct']:+.1f}", r["status"]))
    if markdown:
        lines = ["| " + " | ".join(headers) + " |",
                 "|" + "|".join("---" for _ in headers) + "|"]
        lines += ["| " + " | ".join(row) + " |" for row in body]
        return "\n".join(lines)
    widths = [max(len(h), *(len(row[i]) for row in body)) if body
              else len(h) for i, h in enumerate(headers)]
    lines = ["  ".join(h.ljust(w) for h, w in zip(headers, widths))]
    lines += ["  ".join(c.ljust(w) for c, w in zip(row, widths))
              for row in body]
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("before", help="baseline BENCH_load.json")
    ap.add_argument("after", help="candidate BENCH_load.json")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative regression tolerance per row "
                         "(default: 0.20 = 20%%)")
    ap.add_argument("--markdown", action="store_true",
                    help="emit a GitHub-flavored markdown table")
    args = ap.parse_args(argv)
    docs = []
    for path in (args.before, args.after):
        try:
            with open(path) as f:
                docs.append(json.load(f))
        except (OSError, ValueError) as e:
            print(f"bench_diff: cannot read {path}: {e}", file=sys.stderr)
            return 2
    rows, regressed = diff(docs[0], docs[1], args.threshold)
    comparable = [r for r in rows if "throughput_before" in r]
    if not comparable:
        print("bench_diff: no comparable (backend, tier, threads) rows "
              "between the two files — nothing to gate on")
        return 0
    print(_format_table(rows, args.markdown))
    worst_tp = min(comparable, key=lambda r: r["throughput_pct"])
    worst_p99 = max(comparable, key=lambda r: r["p99_pct"])
    uncompared = len(rows) - len(comparable)
    summary = (f"{len(comparable)} rows compared; worst throughput "
               f"{worst_tp['throughput_pct']:+.1f}% "
               f"({worst_tp['backend']}/{worst_tp['tier']} "
               f"x{worst_tp['threads']}), worst p99 "
               f"{worst_p99['p99_pct']:+.1f}% "
               f"({worst_p99['backend']}/{worst_p99['tier']} "
               f"x{worst_p99['threads']})"
               + (f"; {uncompared} row(s) present on one side only "
                  f"(new/retired tiers never gate)" if uncompared else ""))
    print(("\n**" + summary + "**") if args.markdown else ("\n" + summary))
    if regressed:
        bad = [r for r in rows if r["status"] == "REGRESSED"]
        print(f"bench_diff: {len(bad)} row(s) regressed beyond "
              f"{args.threshold:.0%}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
