"""Fig. 4 analogue: measurement hygiene changes what the profiler sees.

Paper: aggressive JVM GC (NewRatio) makes OS readings track LIVE memory.
Here, two analogues:
  (a) RSS profiling with vs without aggressive gc.collect cadence — the
      no-GC reading rides the allocator high-water mark;
  (b) the XLA analogue: compile-profiled per-device bytes with vs without
      input donation — without donation the dry-run double-counts the
      train state (arguments + outputs), exactly the allocator-slack
      analogue of the paper's lazy-GC curve.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.local_jobs import kmeans_job
from repro.core.profiler import RSSProfiler


def rss_hygiene(verbose=True):
    """The Fig. 4 experiment proper: the same five-sample K-Means ladder
    profiled lazily vs aggressively. Lazy readings ride the allocator
    high-water mark across runs, flattening the memory(size) relation —
    the R2 gate then wrongly rejects a genuinely linear job."""
    from repro.core.memory_model import fit_memory_model
    from repro.core.sampling import ladder_from_anchor
    ladder = ladder_from_anchor(48 * 1024 * 1024)
    out = {}
    for name, aggressive in (("lazy", False), ("aggressive", True)):
        prof = RSSProfiler(interval_s=0.002, aggressive_gc=aggressive)
        if aggressive:   # warm the arena as table2 does
            prof.profile(kmeans_job(int(ladder.anchor)), ladder.anchor)
        peaks = [prof.profile(kmeans_job(int(s)), s).job_mem_bytes
                 for s in ladder.sizes]
        m = fit_memory_model(ladder.sizes, peaks)
        out[name] = m
        if verbose:
            print(f"K-Means ladder, {name:10s} GC: R2={m.r2:8.5f} "
                  f"gate={'PASS' if m.confident else 'REJECT'} "
                  f"slope={m.slope:.3f} B/B")
    return out


def donation_hygiene(verbose=True):
    """Per-device bytes of a param-update step with/without donation."""
    def step(w, x):
        g = x.T @ jnp.tanh(x @ w)
        return w - 1e-3 * g, (x @ w).sum()

    specs = (jax.ShapeDtypeStruct((512, 512), jnp.float32),
             jax.ShapeDtypeStruct((64, 512), jnp.float32))

    def total(donate):
        fn = jax.jit(step, donate_argnums=(0,) if donate else ())
        ma = fn.lower(*specs).compile().memory_analysis()
        return (ma.argument_size_in_bytes + ma.output_size_in_bytes +
                ma.temp_size_in_bytes - ma.alias_size_in_bytes)

    no_don = total(False)
    don = total(True)
    if verbose:
        print(f"XLA bytes, no donation:  {no_don / 2**20:8.2f} MiB")
        print(f"XLA bytes, donated:      {don / 2**20:8.2f} MiB")
    return no_don, don


def main():
    t0 = time.monotonic()
    fits = rss_hygiene()
    no_don, don = donation_hygiene()
    wall = time.monotonic() - t0
    print(f"fig4_measurement_hygiene,{wall * 1e6:.0f},"
          f"r2_lazy={fits['lazy'].r2:.4f};"
          f"r2_aggressive={fits['aggressive'].r2:.4f};"
          f"donation_saving={1 - don / max(no_don, 1):.3f}")


if __name__ == "__main__":
    main()
