"""Table I analogue: normalized job execution cost by selection method
(Random / Medium / BFA / Crispy) over the scout-like corpus."""
from __future__ import annotations

import time

import numpy as np

from repro.core.catalog import aws_like_catalog
from repro.core.crispy import CrispyAllocator
from repro.core.selector import (random_expected_cost, select_bfa,
                                 select_medium)
from repro.core.simulator import build_history, make_profile_fn, \
    scout_like_jobs

GiB = 1024 ** 3


def run(verbose: bool = True):
    jobs = scout_like_jobs()
    catalog = aws_like_catalog()
    history = build_history(jobs, catalog)
    med = select_medium(catalog)
    rows = []
    t0 = time.monotonic()
    for job in jobs:
        nc = history.normalized_costs(job.name)
        bfa = select_bfa(catalog, history, exclude_job=job.name)
        alloc = CrispyAllocator(catalog, history, overhead_per_node_gib=2.0)
        rep = alloc.allocate(job.name, make_profile_fn(job),
                             job.dataset_gib * GiB,
                             anchor=job.dataset_gib * GiB * 0.01)
        rows.append({
            "job": job.name,
            "random": random_expected_cost(catalog, history, job.name),
            "medium": nc[med.name],
            "bfa": nc[bfa.name],
            "crispy": nc[rep.selection.config.name],
            "fell_back": rep.selection.fell_back,
        })
    wall = time.monotonic() - t0
    means = {k: float(np.mean([r[k] for r in rows]))
             for k in ("random", "medium", "bfa", "crispy")}
    if verbose:
        hdr = f"{'job':34s} {'Random':>8s} {'Medium':>8s} {'BFA':>8s} " \
              f"{'Crispy':>8s}  fallback"
        print(hdr)
        for r in rows:
            print(f"{r['job']:34s} {r['random']:8.4f} {r['medium']:8.4f} "
                  f"{r['bfa']:8.4f} {r['crispy']:8.4f}  "
                  f"{'yes' if r['fell_back'] else 'no'}")
        print(f"{'Mean':34s} {means['random']:8.4f} {means['medium']:8.4f} "
              f"{means['bfa']:8.4f} {means['crispy']:8.4f}")
        excess = (means["crispy"] - 1.0) / max(means["bfa"] - 1.0, 1e-9)
        print(f"# excess-cost reduction vs BFA: {100 * (1 - excess):.1f}% "
              f"(paper: 56%)")
    return rows, means, wall


def main():
    rows, means, wall = run(verbose=True)
    per_call_us = wall / max(len(rows), 1) * 1e6
    print(f"table1_selection_cost,{per_call_us:.0f},"
          f"crispy_mean={means['crispy']:.4f};bfa_mean={means['bfa']:.4f}")


if __name__ == "__main__":
    main()
