"""Fig. 1 analogue: total cluster RAM vs normalized execution cost. The
memory-bottleneck cliff must be visible for K-Means/Spark (caching,
iterative) and absent for PageRank/Hadoop (no caching)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.catalog import aws_like_catalog
from repro.core.simulator import build_history, cost_usd, scout_like_jobs


def run(verbose: bool = True):
    jobs = {j.name: j for j in scout_like_jobs()}
    catalog = aws_like_catalog()
    out = {}
    for jname in ("kmeans/spark/bigdata", "pagerank/hadoop/bigdata"):
        job = jobs[jname]
        pts = sorted(
            ((c.total_mem_gib, cost_usd(job, c)) for c in catalog))
        best = min(p[1] for p in pts)
        out[jname] = [(m, c / best) for m, c in pts]
        if verbose:
            print(f"-- {jname} (working set "
                  f"{job.working_set_gib:.0f} GiB cached="
                  f"{job.caching}) --")
            for m, c in out[jname][::9]:
                bar = "#" * min(int(c * 8), 60)
                print(f"  {m:7.0f} GiB  {c:7.2f}x  {bar}")
    # cliff metric: correlation of cost with memory-deficit for KM,
    # ~none for hadoop PR
    km = np.array(out["kmeans/spark/bigdata"])
    ws = jobs["kmeans/spark/bigdata"].working_set_gib
    deficit = np.maximum(0, 1 - km[:, 0] / ws)
    corr_km = float(np.corrcoef(deficit, km[:, 1])[0, 1])
    pr = np.array(out["pagerank/hadoop/bigdata"])
    deficit_pr = np.maximum(0, 1 - pr[:, 0] / max(ws, 1))
    corr_pr = float(np.corrcoef(deficit_pr, pr[:, 1])[0, 1]) \
        if deficit_pr.std() > 0 else 0.0
    if verbose:
        print(f"cost~memory-deficit correlation: kmeans {corr_km:.3f}, "
              f"pagerank/hadoop {corr_pr:.3f}")
    return corr_km, corr_pr


def main():
    t0 = time.monotonic()
    corr_km, corr_pr = run(verbose=True)
    wall = time.monotonic() - t0
    print(f"fig1_memory_cliff,{wall * 1e6:.0f},"
          f"corr_km={corr_km:.3f};corr_prhadoop={corr_pr:.3f}")


if __name__ == "__main__":
    main()
