"""Shared-state backend comparison: FileBackend vs crispy-daemon under
multi-process load, over either daemon transport.

Spawns N real worker processes per backend. Each worker hammers the same
three shared structures the allocation stack uses:

  * lease reservations on ONE shared `ProfilingBudget` envelope
    (the cross-process arbitration path — every op is a backend
    `reserve`);
  * appends to a shared profile log + incremental `read`s;
  * CAS updates on a shared document (the registry-flush shape).

Correctness is asserted, not assumed: across all workers the envelope
must grant exactly `max_points` reservations (never over-granted), and
every appended log row must be visible afterwards.

`--transport unix` (default) talks to the daemon over its unix socket;
`--transport tcp` exercises the multi-host path over loopback TCP — the
same protocol, framed over `--listen host:port`. The daemon section
starts its own `python -m repro.state.daemon` child (or reuses a daemon
at $CRISPY_DAEMON_SOCKET / $CRISPY_DAEMON_TCP when one is already
running, e.g. the CI smoke steps) and shuts it down cleanly. If
$CRISPY_DAEMON_TOKEN is set, both the spawned daemon and every client
inherit it, so the run exercises the auth handshake too. Where unix
sockets are unavailable the unix section is skipped and only the file
numbers are reported.

`--batch N` adds a wire-coalescing section: against the same daemon it
times N appends + one tail read issued as N+1 single-op round trips vs
ONE `DaemonBackend.batch()` frame, and reports the speedup — the
mechanism behind `ProfileStore(write_behind=True)` and
`refresh_views()`. Runs over whichever `--transport` was selected.

Final CSV: state_backends,<us_per_op_file>,<daemon_vs_file_speedup>
(speedup 0.0 when the daemon section was skipped). With `--batch N` a
second CSV line follows: state_backends_batch,<us_single>,<batch_speedup>.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:                  # standalone `python benchmarks/...`
    sys.path.insert(0, _SRC)

from repro.state import HAS_UNIX_SOCKETS  # noqa: E402

WORKERS = 2
OPS_PER_WORKER = 60           # reserve+charge (+append/read/cas every 4th)
MAX_POINTS = 40               # < total attempts: contention + denials

_WORKER_CODE = """
import json, os, sys, time
sys.path.insert(0, {src!r})
from repro.profiling import ProfilingBudget
from repro.state import DaemonBackend, FileBackend

mode, target, ops, tag, run = (sys.argv[1], sys.argv[2], int(sys.argv[3]),
                               sys.argv[4], sys.argv[5])
backend = FileBackend(target) if mode == "file" else DaemonBackend(target)
budget = ProfilingBudget(max_points={max_points}, backend=backend,
                         namespace="bench-budget-" + run)
granted = appended = 0
cursor = 0
t0 = time.monotonic()
for i in range(ops):
    if budget.try_spend():
        granted += 1
        budget.charge(0.5)
    if i % 4 == 0:
        backend.append("bench-log-" + run, {{"tag": tag, "i": i}})
        appended += 1
        _rows, cursor = backend.read("bench-log-" + run, cursor)
        value, version = backend.load("bench-doc-" + run, "merged")
        doc = dict(value or {{}})
        doc[tag] = doc.get(tag, 0) + 1
        backend.cas("bench-doc-" + run, "merged", version, doc)
wall = time.monotonic() - t0
print(json.dumps({{"granted": granted, "appended": appended,
                   "wall": wall}}))
"""

# unique per benchmark invocation so a reused long-lived daemon (or a
# persistent --root) never leaks a previous run's spent envelope into
# this run's correctness assertions
_RUN_ID = f"{os.getpid()}-{int(time.time() * 1000)}"


def _run_workers(mode: str, target: str):
    code = _WORKER_CODE.format(src=_SRC, max_points=MAX_POINTS)
    procs = [subprocess.Popen(
        [sys.executable, "-c", code, mode, target, str(OPS_PER_WORKER),
         f"w{i}", _RUN_ID],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for i in range(WORKERS)]
    outs = [p.communicate(timeout=180) for p in procs]
    rows = []
    for p, (out, err) in zip(procs, outs):
        if p.returncode != 0:
            raise RuntimeError(f"{mode} worker failed: {err[-2000:]}")
        rows.append(json.loads(out.strip().splitlines()[-1]))
    return rows


def _verify(mode: str, backend, rows) -> None:
    granted = sum(r["granted"] for r in rows)
    appended = sum(r["appended"] for r in rows)
    assert granted == MAX_POINTS, \
        f"{mode}: envelope over/under-granted: {granted} != {MAX_POINTS}"
    log_rows, _ = backend.read(f"bench-log-{_RUN_ID}", 0)
    assert len(log_rows) == appended, \
        f"{mode}: lost log rows: {len(log_rows)} != {appended}"


def _report(mode: str, rows) -> float:
    ops = WORKERS * OPS_PER_WORKER
    wall = max(r["wall"] for r in rows)
    us_per_op = wall / OPS_PER_WORKER * 1e6
    print(f"{mode}: {WORKERS} procs x {OPS_PER_WORKER} iterations in "
          f"{wall:.2f}s ({ops / wall:.0f} iter/s aggregate, "
          f"{us_per_op:.0f} us/iter/proc)")
    return us_per_op


def bench_file() -> float:
    from repro.state import FileBackend
    root = tempfile.mkdtemp(prefix="crispy-bench-file-")
    rows = _run_workers("file", root)
    _verify("file", FileBackend(root), rows)
    return _report("file", rows)


def _spawn_daemon(transport: str):
    """(address, child|None) for a fresh daemon on `transport`, or
    (None, None) when it could not be started."""
    tmp = tempfile.mkdtemp(prefix=f"crispy-bench-daemon-{transport}-")
    env = {**os.environ,
           "PYTHONPATH": _SRC + os.pathsep + os.environ.get("PYTHONPATH", "")}
    if transport == "unix":
        addr = os.path.join(tmp, "d.sock")
        argv = [sys.executable, "-m", "repro.state.daemon", "--socket", addr]
        ready = lambda: os.path.exists(addr)            # noqa: E731
    else:
        port_file = os.path.join(tmp, "addr")
        argv = [sys.executable, "-m", "repro.state.daemon",
                "--listen", "127.0.0.1:0", "--port-file", port_file]
        ready = lambda: os.path.exists(port_file)       # noqa: E731
    child = subprocess.Popen(argv, env=env, stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT, text=True)
    for _ in range(100):
        if ready():
            break
        if child.poll() is not None:
            print(f"daemon({transport}): skipped (failed to start: "
                  f"{child.communicate()[0][-500:]})")
            return None, None
        time.sleep(0.05)
    else:
        child.kill()
        print(f"daemon({transport}): skipped (did not become ready)")
        return None, None
    if transport == "tcp":
        with open(port_file) as f:
            addr = f.read().strip()
    from repro.state import DaemonBackend
    client = DaemonBackend(addr, timeout_s=2.0)
    for _ in range(100):
        if client.ping():
            return addr, child
        time.sleep(0.05)
    child.kill()
    print(f"daemon({transport}): skipped (never answered ping)")
    return None, None


def bench_daemon(transport: str = "unix") -> float:
    """0.0 when skipped (no unix sockets / daemon failed to start)."""
    if transport == "unix" and not HAS_UNIX_SOCKETS:
        print("daemon(unix): skipped (no unix-domain sockets on this "
              "platform)")
        return 0.0
    from repro.state import DaemonBackend
    label = f"daemon({transport})"
    reuse_env = ("CRISPY_DAEMON_SOCKET" if transport == "unix"
                 else "CRISPY_DAEMON_TCP")
    env_addr = os.environ.get(reuse_env)
    if env_addr and DaemonBackend(env_addr, timeout_s=2.0).ping():
        addr, child = env_addr, None
        print(f"{label}: reusing running daemon at {addr}")
    else:
        addr, child = _spawn_daemon(transport)
        if addr is None:
            return 0.0
    try:
        rows = _run_workers("daemon", addr)
        _verify(label, DaemonBackend(addr), rows)
        return _report(label, rows)
    finally:
        if child is not None:
            DaemonBackend(addr).shutdown_daemon()
            child.wait(timeout=10)
            assert child.returncode == 0, \
                f"daemon did not shut down cleanly: rc={child.returncode}"
            print(f"{label}: clean shutdown")


def bench_batch(transport: str, batch_n: int, repeats: int = 20):
    """Batched vs single-op wire throughput on one daemon: `batch_n`
    appends + one tail read, issued per-op vs as one batch frame.
    Returns (us_single_per_group, speedup), or (0.0, 0.0) if skipped."""
    if transport == "unix" and not HAS_UNIX_SOCKETS:
        print("batch: skipped (no unix-domain sockets on this platform)")
        return 0.0, 0.0
    from repro.state import DaemonBackend
    addr, child = _spawn_daemon(transport)
    if addr is None:
        return 0.0, 0.0
    label = f"batch({transport}) x{batch_n}"
    try:
        client = DaemonBackend(addr)
        cursor = 0
        t0 = time.monotonic()
        for i in range(repeats):
            for j in range(batch_n):
                client.append("batch-single", {"i": i, "j": j})
            _rows, cursor = client.read("batch-single", cursor)
        wall_single = time.monotonic() - t0
        cursor = 0
        t0 = time.monotonic()
        for i in range(repeats):
            ops = [{"op": "append", "ns": "batch-batched",
                    "record": {"i": i, "j": j}} for j in range(batch_n)]
            ops.append({"op": "read", "ns": "batch-batched",
                        "cursor": cursor})
            results = client.batch(ops)
            assert all(r.get("ok") for r in results), results
            cursor = results[-1]["cursor"]
        wall_batched = time.monotonic() - t0
        n_single, _ = client.read("batch-single", 0)
        n_batched, _ = client.read("batch-batched", 0)
        assert len(n_single) == len(n_batched) == repeats * batch_n
        us_single = wall_single / repeats * 1e6
        us_batched = wall_batched / repeats * 1e6
        speedup = us_single / us_batched if us_batched else 0.0
        print(f"{label}: {us_single:.0f} us/group single-op vs "
              f"{us_batched:.0f} us/group batched -> {speedup:.2f}x "
              f"({batch_n} appends + 1 read per group, {repeats} groups)")
        return us_single, speedup
    finally:
        if child is not None:
            try:
                # the shutdown reply can race the daemon's drain when
                # other connections (our bench client) are still open;
                # the child's exit code is the real cleanliness signal
                DaemonBackend(addr).shutdown_daemon()
            except Exception:
                pass
            child.wait(timeout=10)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--transport", choices=("unix", "tcp"), default="unix",
                    help="daemon transport to benchmark against "
                         "(default: unix)")
    ap.add_argument("--batch", type=int, metavar="N",
                    default=int(os.environ.get("STATE_BACKENDS_BATCH",
                                               "0")) or None,
                    help="also measure batched vs single-op wire "
                         "throughput with N appends + 1 read per group "
                         "(default: $STATE_BACKENDS_BATCH, off)")
    # argv=None means "called programmatically" (benchmarks/run.py): use
    # defaults rather than swallowing the harness's own sys.argv
    args = ap.parse_args(argv if argv is not None else [])
    us_file = bench_file()
    us_daemon = bench_daemon(args.transport)
    speedup = us_file / us_daemon if us_daemon else 0.0
    if us_daemon:
        print(f"daemon({args.transport}) vs file: {speedup:.2f}x per "
              f"contended iteration")
    print(f"state_backends,{us_file:.1f},{speedup:.2f}")
    if args.batch:
        us_single, batch_speedup = bench_batch(args.transport, args.batch)
        print(f"state_backends_batch,{us_single:.1f},{batch_speedup:.2f}")


if __name__ == "__main__":
    main(sys.argv[1:])
