"""Shared-state backend comparison: FileBackend vs crispy-daemon under
multi-process load.

Spawns N real worker processes per backend. Each worker hammers the same
three shared structures the allocation stack uses:

  * lease reservations on ONE shared `ProfilingBudget` envelope
    (the cross-process arbitration path — every op is a backend
    `reserve`);
  * appends to a shared profile log + incremental `read`s;
  * CAS updates on a shared document (the registry-flush shape).

Correctness is asserted, not assumed: across all workers the envelope
must grant exactly `max_points` reservations (never over-granted), and
every appended log row must be visible afterwards.

The daemon section starts its own `python -m repro.state.daemon` child
(or reuses a daemon at $CRISPY_DAEMON_SOCKET when one is already
running, e.g. the CI smoke step) and shuts it down cleanly. Where
unix-domain sockets are unavailable the section is skipped and only the
file numbers are reported.

Final CSV: state_backends,<us_per_op_file>,<daemon_vs_file_speedup>
(speedup 0.0 when the daemon section was skipped).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:                  # standalone `python benchmarks/...`
    sys.path.insert(0, _SRC)

from repro.state import HAS_UNIX_SOCKETS  # noqa: E402

WORKERS = 2
OPS_PER_WORKER = 60           # reserve+charge (+append/read/cas every 4th)
MAX_POINTS = 40               # < total attempts: contention + denials

_WORKER_CODE = """
import json, os, sys, time
sys.path.insert(0, {src!r})
from repro.profiling import ProfilingBudget
from repro.state import DaemonBackend, FileBackend

mode, target, ops, tag, run = (sys.argv[1], sys.argv[2], int(sys.argv[3]),
                               sys.argv[4], sys.argv[5])
backend = FileBackend(target) if mode == "file" else DaemonBackend(target)
budget = ProfilingBudget(max_points={max_points}, backend=backend,
                         namespace="bench-budget-" + run)
granted = appended = 0
cursor = 0
t0 = time.monotonic()
for i in range(ops):
    if budget.try_spend():
        granted += 1
        budget.charge(0.5)
    if i % 4 == 0:
        backend.append("bench-log-" + run, {{"tag": tag, "i": i}})
        appended += 1
        _rows, cursor = backend.read("bench-log-" + run, cursor)
        value, version = backend.load("bench-doc-" + run, "merged")
        doc = dict(value or {{}})
        doc[tag] = doc.get(tag, 0) + 1
        backend.cas("bench-doc-" + run, "merged", version, doc)
wall = time.monotonic() - t0
print(json.dumps({{"granted": granted, "appended": appended,
                   "wall": wall}}))
"""

# unique per benchmark invocation so a reused long-lived daemon (or a
# persistent --root) never leaks a previous run's spent envelope into
# this run's correctness assertions
_RUN_ID = f"{os.getpid()}-{int(time.time() * 1000)}"


def _run_workers(mode: str, target: str):
    code = _WORKER_CODE.format(src=_SRC, max_points=MAX_POINTS)
    procs = [subprocess.Popen(
        [sys.executable, "-c", code, mode, target, str(OPS_PER_WORKER),
         f"w{i}", _RUN_ID],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for i in range(WORKERS)]
    outs = [p.communicate(timeout=180) for p in procs]
    rows = []
    for p, (out, err) in zip(procs, outs):
        if p.returncode != 0:
            raise RuntimeError(f"{mode} worker failed: {err[-2000:]}")
        rows.append(json.loads(out.strip().splitlines()[-1]))
    return rows


def _verify(mode: str, backend, rows) -> None:
    granted = sum(r["granted"] for r in rows)
    appended = sum(r["appended"] for r in rows)
    assert granted == MAX_POINTS, \
        f"{mode}: envelope over/under-granted: {granted} != {MAX_POINTS}"
    log_rows, _ = backend.read(f"bench-log-{_RUN_ID}", 0)
    assert len(log_rows) == appended, \
        f"{mode}: lost log rows: {len(log_rows)} != {appended}"


def _report(mode: str, rows) -> float:
    ops = WORKERS * OPS_PER_WORKER
    wall = max(r["wall"] for r in rows)
    us_per_op = wall / OPS_PER_WORKER * 1e6
    print(f"{mode}: {WORKERS} procs x {OPS_PER_WORKER} iterations in "
          f"{wall:.2f}s ({ops / wall:.0f} iter/s aggregate, "
          f"{us_per_op:.0f} us/iter/proc)")
    return us_per_op


def bench_file() -> float:
    from repro.state import FileBackend
    root = tempfile.mkdtemp(prefix="crispy-bench-file-")
    rows = _run_workers("file", root)
    _verify("file", FileBackend(root), rows)
    return _report("file", rows)


def bench_daemon() -> float:
    """0.0 when skipped (no unix sockets / daemon failed to start)."""
    if not HAS_UNIX_SOCKETS:
        print("daemon: skipped (no unix-domain sockets on this platform)")
        return 0.0
    from repro.state import DaemonBackend
    env_sock = os.environ.get("CRISPY_DAEMON_SOCKET")
    if env_sock and DaemonBackend(env_sock, timeout_s=2.0).ping():
        sock, child = env_sock, None
        print(f"daemon: reusing running daemon at {sock}")
    else:
        tmp = tempfile.mkdtemp(prefix="crispy-bench-daemon-")
        sock = os.path.join(tmp, "d.sock")
        child = subprocess.Popen(
            [sys.executable, "-m", "repro.state.daemon", "--socket", sock],
            env={**os.environ,
                 "PYTHONPATH": _SRC + os.pathsep
                 + os.environ.get("PYTHONPATH", "")},
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
        client = DaemonBackend(sock, timeout_s=2.0)
        for _ in range(100):
            if os.path.exists(sock) and client.ping():
                break
            if child.poll() is not None:
                print("daemon: skipped (failed to start: "
                      f"{child.communicate()[0][-500:]})")
                return 0.0
            time.sleep(0.05)
        else:
            child.kill()
            print("daemon: skipped (did not become ready)")
            return 0.0
    try:
        rows = _run_workers("daemon", sock)
        _verify("daemon", DaemonBackend(sock), rows)
        return _report("daemon", rows)
    finally:
        if child is not None:
            DaemonBackend(sock).shutdown_daemon()
            child.wait(timeout=10)
            assert child.returncode == 0, \
                f"daemon did not shut down cleanly: rc={child.returncode}"
            print("daemon: clean shutdown")


def main() -> None:
    us_file = bench_file()
    us_daemon = bench_daemon()
    speedup = us_file / us_daemon if us_daemon else 0.0
    if us_daemon:
        print(f"daemon vs file: {speedup:.2f}x per contended iteration")
    print(f"state_backends,{us_file:.1f},{speedup:.2f}")


if __name__ == "__main__":
    main()
