"""Shared-state backend comparison: FileBackend vs crispy-daemon under
multi-process load, over either daemon transport.

Spawns N real worker processes per backend. Each worker hammers the same
three shared structures the allocation stack uses:

  * lease reservations on ONE shared `ProfilingBudget` envelope
    (the cross-process arbitration path — every op is a backend
    `reserve`);
  * appends to a shared profile log + incremental `read`s;
  * CAS updates on a shared document (the registry-flush shape).

Correctness is asserted, not assumed: across all workers the envelope
must grant exactly `max_points` reservations (never over-granted), and
every appended log row must be visible afterwards.

`--transport unix` (default) talks to the daemon over its unix socket;
`--transport tcp` exercises the multi-host path over loopback TCP — the
same protocol, framed over `--listen host:port`. The daemon section
starts its own `python -m repro.state.daemon` child (or reuses a daemon
at $CRISPY_DAEMON_SOCKET / $CRISPY_DAEMON_TCP when one is already
running, e.g. the CI smoke steps) and shuts it down cleanly. If
$CRISPY_DAEMON_TOKEN is set, both the spawned daemon and every client
inherit it, so the run exercises the auth handshake too. Where unix
sockets are unavailable the unix section is skipped and only the file
numbers are reported.

`--batch N` adds a wire-coalescing section: against the same daemon it
times N appends + one tail read issued as N+1 single-op round trips vs
ONE `DaemonBackend.batch()` frame, and reports the speedup — the
mechanism behind `ProfileStore(write_behind=True)` and
`refresh_views()`. Runs over whichever `--transport` was selected.

`--shards N` adds the scale-out section: spawns 1-, 2- and 4-shard
daemon topologies (capped at N) and drives each with multi-process
workers issuing BATCHED frames over many namespaces — the service's
steady-state wire shape, where `ShardedBackend.batch()` splits each
frame by owning shard and fans out concurrently. Every daemon runs
with the same small `--op-delay` per-mutation service time (a stand-in
for a durable backend's fsync under the writer lock), so the measured
quantity is topology scaling — serialized service time on one shard vs
overlapped service time across shards — independent of how many cores
the host happens to have. Per-shard ops/s comes from each daemon's own
`daemon.op.*` histograms, every appended row is verified readable
through the ring, and the rows land in BENCH_shards.json in the same
backends/tiers/by_threads shape `bench_diff.py` consumes — the scaling
claim is gated by diffable JSON, not scrollback.

Final CSV: state_backends,<us_per_op_file>,<daemon_vs_file_speedup>
(speedup 0.0 when the daemon section was skipped). With `--batch N` a
second CSV line follows: state_backends_batch,<us_single>,<batch_speedup>;
with `--shards N`: state_backends_shards,<rps_1shard>,<scaling_1_to_2>.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:                  # standalone `python benchmarks/...`
    sys.path.insert(0, _SRC)

from repro.state import HAS_UNIX_SOCKETS  # noqa: E402

WORKERS = 2
OPS_PER_WORKER = 60           # reserve+charge (+append/read/cas every 4th)
MAX_POINTS = 40               # < total attempts: contention + denials

# --shards section: enough concurrent batched load that daemon-side CPU,
# not client round trips, is the bottleneck — otherwise adding shards
# can't show up in aggregate ops/s at all
SHARD_WORKERS = 6             # worker processes per topology
SHARD_BATCHES = 20            # batch frames per worker
SHARD_BATCH_OPS = 24          # appends per frame (+1 piggybacked read)
# namespaces per worker: the unit of placement on the hash ring. Many
# namespaces -> the per-shard load split concentrates near even (the
# namespace sample, not ring-arc size, dominates the variance), and
# DETERMINISTIC names (no run id — every topology gets fresh daemons)
# make the split identical run to run
SHARD_NAMESPACES = 32
# per-append service time injected with the daemon's --op-delay: models
# a durable backend's fsync under the writer lock, so the measured
# quantity is topology scaling (serialized waits on one shard vs
# overlapped waits across shards) rather than how many cores this
# particular host happens to have — CI runners are often single-core,
# where pure in-memory daemons could never show scaling at all
SHARD_OP_DELAY_S = 0.0005
SHARD_BENCH_FILE = os.path.join(_ROOT, "BENCH_shards.json")

_WORKER_CODE = """
import json, os, sys, time
sys.path.insert(0, {src!r})
from repro.profiling import ProfilingBudget
from repro.state import DaemonBackend, FileBackend

mode, target, ops, tag, run = (sys.argv[1], sys.argv[2], int(sys.argv[3]),
                               sys.argv[4], sys.argv[5])
backend = FileBackend(target) if mode == "file" else DaemonBackend(target)
budget = ProfilingBudget(max_points={max_points}, backend=backend,
                         namespace="bench-budget-" + run)
granted = appended = 0
cursor = 0
t0 = time.monotonic()
for i in range(ops):
    if budget.try_spend():
        granted += 1
        budget.charge(0.5)
    if i % 4 == 0:
        backend.append("bench-log-" + run, {{"tag": tag, "i": i}})
        appended += 1
        _rows, cursor = backend.read("bench-log-" + run, cursor)
        value, version = backend.load("bench-doc-" + run, "merged")
        doc = dict(value or {{}})
        doc[tag] = doc.get(tag, 0) + 1
        backend.cas("bench-doc-" + run, "merged", version, doc)
wall = time.monotonic() - t0
print(json.dumps({{"granted": granted, "appended": appended,
                   "wall": wall}}))
"""

_SHARD_WORKER_CODE = """
import json, sys, time
sys.path.insert(0, {src!r})
from repro.state import ShardedBackend

addrs, batches, batch_ops, nss, tag = (
    sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]),
    sys.argv[5])
lat_ms = []
total = 0
with ShardedBackend.from_addresses(addrs.split(",")) as backend:
    cursor = 0
    ns0 = "shard-bench-%s-0" % tag
    t0 = time.monotonic()
    for b in range(batches):
        ops = []
        for j in range(batch_ops):
            k = (b * batch_ops + j) % nss
            ops.append({{"op": "append",
                         "ns": "shard-bench-%s-%d" % (tag, k),
                         "record": {{"tag": tag, "b": b, "j": j}}}})
        ops.append({{"op": "read", "ns": ns0, "cursor": cursor}})
        t1 = time.monotonic()
        results = backend.batch(ops)
        lat_ms.append((time.monotonic() - t1) * 1e3)
        assert all(r.get("ok") for r in results), results
        cursor = results[-1]["cursor"]
        total += len(ops)
    wall = time.monotonic() - t0
print(json.dumps({{"ops": total, "appends": batches * batch_ops,
                   "wall": wall, "lat_ms": lat_ms}}))
"""

# unique per benchmark invocation so a reused long-lived daemon (or a
# persistent --root) never leaks a previous run's spent envelope into
# this run's correctness assertions
_RUN_ID = f"{os.getpid()}-{int(time.time() * 1000)}"


def _run_workers(mode: str, target: str):
    code = _WORKER_CODE.format(src=_SRC, max_points=MAX_POINTS)
    procs = [subprocess.Popen(
        [sys.executable, "-c", code, mode, target, str(OPS_PER_WORKER),
         f"w{i}", _RUN_ID],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for i in range(WORKERS)]
    outs = [p.communicate(timeout=180) for p in procs]
    rows = []
    for p, (out, err) in zip(procs, outs):
        if p.returncode != 0:
            raise RuntimeError(f"{mode} worker failed: {err[-2000:]}")
        rows.append(json.loads(out.strip().splitlines()[-1]))
    return rows


def _verify(mode: str, backend, rows) -> None:
    granted = sum(r["granted"] for r in rows)
    appended = sum(r["appended"] for r in rows)
    assert granted == MAX_POINTS, \
        f"{mode}: envelope over/under-granted: {granted} != {MAX_POINTS}"
    log_rows, _ = backend.read(f"bench-log-{_RUN_ID}", 0)
    assert len(log_rows) == appended, \
        f"{mode}: lost log rows: {len(log_rows)} != {appended}"


def _report(mode: str, rows) -> float:
    ops = WORKERS * OPS_PER_WORKER
    wall = max(r["wall"] for r in rows)
    us_per_op = wall / OPS_PER_WORKER * 1e6
    print(f"{mode}: {WORKERS} procs x {OPS_PER_WORKER} iterations in "
          f"{wall:.2f}s ({ops / wall:.0f} iter/s aggregate, "
          f"{us_per_op:.0f} us/iter/proc)")
    return us_per_op


def bench_file() -> float:
    from repro.state import FileBackend
    root = tempfile.mkdtemp(prefix="crispy-bench-file-")
    rows = _run_workers("file", root)
    _verify("file", FileBackend(root), rows)
    return _report("file", rows)


def _spawn_daemon(transport: str, extra_args=()):
    """(address, child|None) for a fresh daemon on `transport`, or
    (None, None) when it could not be started."""
    tmp = tempfile.mkdtemp(prefix=f"crispy-bench-daemon-{transport}-")
    env = {**os.environ,
           "PYTHONPATH": _SRC + os.pathsep + os.environ.get("PYTHONPATH", "")}
    if transport == "unix":
        addr = os.path.join(tmp, "d.sock")
        argv = [sys.executable, "-m", "repro.state.daemon", "--socket", addr]
        ready = lambda: os.path.exists(addr)            # noqa: E731
    else:
        port_file = os.path.join(tmp, "addr")
        argv = [sys.executable, "-m", "repro.state.daemon",
                "--listen", "127.0.0.1:0", "--port-file", port_file]
        ready = lambda: os.path.exists(port_file)       # noqa: E731
    argv.extend(extra_args)
    child = subprocess.Popen(argv, env=env, stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT, text=True)
    for _ in range(100):
        if ready():
            break
        if child.poll() is not None:
            print(f"daemon({transport}): skipped (failed to start: "
                  f"{child.communicate()[0][-500:]})")
            return None, None
        time.sleep(0.05)
    else:
        child.kill()
        print(f"daemon({transport}): skipped (did not become ready)")
        return None, None
    if transport == "tcp":
        with open(port_file) as f:
            addr = f.read().strip()
    from repro.state import DaemonBackend
    with DaemonBackend(addr, timeout_s=2.0) as client:
        for _ in range(100):
            if client.ping():
                return addr, child
            time.sleep(0.05)
    child.kill()
    print(f"daemon({transport}): skipped (never answered ping)")
    return None, None


def bench_daemon(transport: str = "unix") -> float:
    """0.0 when skipped (no unix sockets / daemon failed to start)."""
    if transport == "unix" and not HAS_UNIX_SOCKETS:
        print("daemon(unix): skipped (no unix-domain sockets on this "
              "platform)")
        return 0.0
    from repro.state import DaemonBackend
    label = f"daemon({transport})"
    reuse_env = ("CRISPY_DAEMON_SOCKET" if transport == "unix"
                 else "CRISPY_DAEMON_TCP")
    env_addr = os.environ.get(reuse_env)
    reusable = False
    if env_addr:
        with DaemonBackend(env_addr, timeout_s=2.0) as probe:
            reusable = probe.ping()
    if reusable:
        addr, child = env_addr, None
        print(f"{label}: reusing running daemon at {addr}")
    else:
        addr, child = _spawn_daemon(transport)
        if addr is None:
            return 0.0
    try:
        rows = _run_workers("daemon", addr)
        with DaemonBackend(addr) as checker:
            _verify(label, checker, rows)
        return _report(label, rows)
    finally:
        if child is not None:
            with DaemonBackend(addr) as closer:
                closer.shutdown_daemon()
            child.wait(timeout=10)
            assert child.returncode == 0, \
                f"daemon did not shut down cleanly: rc={child.returncode}"
            print(f"{label}: clean shutdown")


def bench_batch(transport: str, batch_n: int, repeats: int = 20):
    """Batched vs single-op wire throughput on one daemon: `batch_n`
    appends + one tail read, issued per-op vs as one batch frame.
    Returns (us_single_per_group, speedup), or (0.0, 0.0) if skipped."""
    if transport == "unix" and not HAS_UNIX_SOCKETS:
        print("batch: skipped (no unix-domain sockets on this platform)")
        return 0.0, 0.0
    from repro.state import DaemonBackend
    addr, child = _spawn_daemon(transport)
    if addr is None:
        return 0.0, 0.0
    label = f"batch({transport}) x{batch_n}"
    try:
        with DaemonBackend(addr) as client:
            cursor = 0
            t0 = time.monotonic()
            for i in range(repeats):
                for j in range(batch_n):
                    client.append("batch-single", {"i": i, "j": j})
                _rows, cursor = client.read("batch-single", cursor)
            wall_single = time.monotonic() - t0
            cursor = 0
            t0 = time.monotonic()
            for i in range(repeats):
                ops = [{"op": "append", "ns": "batch-batched",
                        "record": {"i": i, "j": j}} for j in range(batch_n)]
                ops.append({"op": "read", "ns": "batch-batched",
                            "cursor": cursor})
                results = client.batch(ops)
                assert all(r.get("ok") for r in results), results
                cursor = results[-1]["cursor"]
            wall_batched = time.monotonic() - t0
            n_single, _ = client.read("batch-single", 0)
            n_batched, _ = client.read("batch-batched", 0)
            assert len(n_single) == len(n_batched) == repeats * batch_n
        us_single = wall_single / repeats * 1e6
        us_batched = wall_batched / repeats * 1e6
        speedup = us_single / us_batched if us_batched else 0.0
        print(f"{label}: {us_single:.0f} us/group single-op vs "
              f"{us_batched:.0f} us/group batched -> {speedup:.2f}x "
              f"({batch_n} appends + 1 read per group, {repeats} groups)")
        return us_single, speedup
    finally:
        if child is not None:
            try:
                # the shutdown reply can race the daemon's drain when
                # other connections (our bench client) are still open;
                # the child's exit code is the real cleanliness signal
                with DaemonBackend(addr) as closer:
                    closer.shutdown_daemon()
            except Exception:
                pass
            child.wait(timeout=10)


def _pct(sorted_ms, q: float) -> float:
    if not sorted_ms:
        return 0.0
    idx = min(len(sorted_ms) - 1, int(q * len(sorted_ms)))
    return sorted_ms[idx]


def _shutdown_fleet(fleet) -> None:
    from repro.state import DaemonBackend
    for addr, child in fleet:
        if child is None or child.poll() is not None:
            continue
        try:
            with DaemonBackend(addr, timeout_s=5.0) as closer:
                closer.shutdown_daemon()
        except Exception:
            pass
        try:
            child.wait(timeout=10)
        except subprocess.TimeoutExpired:
            child.kill()


def _bench_shard_topology(transport: str, n_shards: int):
    """One topology: spawn `n_shards` fresh daemons, drive them with
    SHARD_WORKERS processes of batched frames, return the by_threads row
    (or None when a daemon could not be started). Aggregate ops/s is
    total ops over the slowest worker's wall — the number that should
    scale with shard count; per-shard ops/s is read back from each
    daemon's own `daemon.op.*` histograms so skew is visible."""
    from repro.state import DaemonBackend, ShardedBackend
    fleet = []
    for _ in range(n_shards):
        addr, child = _spawn_daemon(
            transport, ("--op-delay", str(SHARD_OP_DELAY_S)))
        if addr is None:
            _shutdown_fleet(fleet)
            return None
        fleet.append((addr, child))
    addrs = [addr for addr, _child in fleet]
    try:
        code = _SHARD_WORKER_CODE.format(src=_SRC)
        procs = [subprocess.Popen(
            [sys.executable, "-c", code, ",".join(addrs),
             str(SHARD_BATCHES), str(SHARD_BATCH_OPS),
             str(SHARD_NAMESPACES), f"w{i}"],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
            for i in range(SHARD_WORKERS)]
        outs = [p.communicate(timeout=300) for p in procs]
        rows = []
        for p, (out, err) in zip(procs, outs):
            if p.returncode != 0:
                raise RuntimeError(f"shard worker failed: {err[-2000:]}")
            rows.append(json.loads(out.strip().splitlines()[-1]))
        wall = max(r["wall"] for r in rows)
        total_ops = sum(r["ops"] for r in rows)
        total_appends = sum(r["appends"] for r in rows)
        lats = sorted(l for r in rows for l in r["lat_ms"])
        # correctness: every acknowledged append is readable through the
        # ring afterwards, across every namespace of every worker
        with ShardedBackend.from_addresses(addrs) as ring:
            seen = 0
            for i in range(SHARD_WORKERS):
                for k in range(SHARD_NAMESPACES):
                    ns_rows, _ = ring.read(f"shard-bench-w{i}-{k}", 0)
                    seen += len(ns_rows)
        assert seen == total_appends, \
            f"shards={n_shards}: lost rows: {seen} != {total_appends}"
        per_shard_rps = {}
        for i, addr in enumerate(addrs):
            with DaemonBackend(addr, timeout_s=5.0) as client:
                snap = client.metrics()
            count = sum(
                int(h.get("count", 0))
                for name, h in snap.get("histograms", {}).items()
                if name.startswith("daemon.op.") and
                name.endswith(".seconds"))
            per_shard_rps[f"shard-{i}"] = round(count / wall, 1)
        return {
            "requests": total_ops,
            "throughput_rps": round(total_ops / wall, 1),
            "p50_ms": round(_pct(lats, 0.50), 3),
            "p99_ms": round(_pct(lats, 0.99), 3),
            "per_shard_rps": per_shard_rps,
        }
    finally:
        _shutdown_fleet(fleet)


def bench_shards(transport: str, max_shards: int):
    """Aggregate ops/s across 1-, 2- and 4-shard topologies (capped at
    `max_shards`), written to BENCH_shards.json in bench_diff.py's
    backends/tiers/by_threads shape. Returns (rps_1shard, scaling_1_to_2)
    or (0.0, 0.0) when skipped."""
    if transport == "unix" and not HAS_UNIX_SOCKETS:
        print("shards: skipped (no unix-domain sockets on this platform)")
        return 0.0, 0.0
    topologies = [n for n in (1, 2, 4) if n <= max_shards]
    tiers = {}
    rps_by_n = {}
    for n in topologies:
        row = _bench_shard_topology(transport, n)
        if row is None:
            print(f"shards({transport}) n={n}: skipped "
                  f"(daemon failed to start)")
            return 0.0, 0.0
        tiers[f"shards-{n}"] = {"by_threads": {str(SHARD_WORKERS): row}}
        rps_by_n[n] = row["throughput_rps"]
        shard_txt = " ".join(f"{k}={v:.0f}" for k, v in
                             sorted(row["per_shard_rps"].items()))
        print(f"shards({transport}) n={n}: {row['throughput_rps']:.0f} "
              f"ops/s aggregate (p50 {row['p50_ms']:.1f} ms, p99 "
              f"{row['p99_ms']:.1f} ms; per-shard {shard_txt})")
    scaling = {}
    if 1 in rps_by_n and 2 in rps_by_n and rps_by_n[1]:
        scaling["1_to_2"] = round(rps_by_n[2] / rps_by_n[1], 2)
    if 2 in rps_by_n and 4 in rps_by_n and rps_by_n[2]:
        scaling["2_to_4"] = round(rps_by_n[4] / rps_by_n[2], 2)
    doc = {
        "benchmark": "state_shards",
        "created_unix": time.time(),
        "transport": transport,
        "workers": SHARD_WORKERS,
        "batches_per_worker": SHARD_BATCHES,
        "ops_per_batch": SHARD_BATCH_OPS + 1,
        "op_delay_ms": SHARD_OP_DELAY_S * 1e3,
        "backends": {f"sharded-{transport}": {"tiers": tiers}},
        "scaling": scaling,
    }
    with open(SHARD_BENCH_FILE, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    if scaling:
        print(f"shards({transport}) scaling: " +
              " ".join(f"{k}={v:.2f}x" for k, v in sorted(scaling.items())))
    print(f"wrote {SHARD_BENCH_FILE}")
    return rps_by_n.get(1, 0.0), scaling.get("1_to_2", 0.0)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--transport", choices=("unix", "tcp"), default="unix",
                    help="daemon transport to benchmark against "
                         "(default: unix)")
    ap.add_argument("--batch", type=int, metavar="N",
                    default=int(os.environ.get("STATE_BACKENDS_BATCH",
                                               "0")) or None,
                    help="also measure batched vs single-op wire "
                         "throughput with N appends + 1 read per group "
                         "(default: $STATE_BACKENDS_BATCH, off)")
    ap.add_argument("--shards", type=int, metavar="N",
                    default=int(os.environ.get("STATE_BACKENDS_SHARDS",
                                               "0")) or None,
                    help="also measure aggregate ops/s across 1-, 2- and "
                         "4-shard topologies capped at N, writing "
                         "BENCH_shards.json "
                         "(default: $STATE_BACKENDS_SHARDS, off)")
    # argv=None means "called programmatically" (benchmarks/run.py): use
    # defaults rather than swallowing the harness's own sys.argv
    args = ap.parse_args(argv if argv is not None else [])
    us_file = bench_file()
    us_daemon = bench_daemon(args.transport)
    speedup = us_file / us_daemon if us_daemon else 0.0
    if us_daemon:
        print(f"daemon({args.transport}) vs file: {speedup:.2f}x per "
              f"contended iteration")
    print(f"state_backends,{us_file:.1f},{speedup:.2f}")
    if args.batch:
        us_single, batch_speedup = bench_batch(args.transport, args.batch)
        print(f"state_backends_batch,{us_single:.1f},{batch_speedup:.2f}")
    if args.shards:
        rps_one, scale_1_2 = bench_shards(args.transport, args.shards)
        print(f"state_backends_shards,{rps_one:.1f},{scale_1_2:.2f}")


if __name__ == "__main__":
    main(sys.argv[1:])
