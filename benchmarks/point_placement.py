"""Information-optimal vs ladder-prefix point placement, at equal budget.

Both strategies drive the SAME unified pipeline (repro.pipeline) over the
same synthetic jobs under the same per-job ProfilingBudget envelope; only
`placement=` differs:

  ladder     PR-2 behavior: smallest-first ladder prefix, early stop on a
             confident+stable requirement, gap-midpoint escalation while
             the zoo's candidates disagree.
  infogain   the default: profile whichever size is expected to shrink
             candidate-model disagreement at full size the most; stop
             when no remaining measurement is expected to change the
             answer.

Jobs cover the shapes the model zoo separates:

  curved     power-law (clean + mildly noisy) and log-linear — the shapes
             where a smallest-first prefix has the least leverage: its
             points cluster where every candidate looks like a line, so
             the prefix runs long while candidate disagreement at full
             size stays high. Disagreement-driven placement jumps to the
             far end of the calibrated range immediately.
  piecewise  a mid-ladder phase change: both strategies fail the gate
             (fallback outcome is equal) — what differs is how many
             points they spend discovering that.
  clean      exactly linear (+0.2% noise): both stop at the LOOCV minimum
             of 3 points; infogain must not regress the easy case.
  noisy      the paper's gate-failing profile: fallback at minimum spend.

Printed per job: points profiled, budget points charged, requirement
error vs the analytic ground truth (for gate-passing shapes). The
structural claim (asserted in tests/test_pipeline.py): on every curved
job infogain profiles FEWER points than the ladder prefix at
equal-or-better requirement error.

Final CSV line: point_placement,<us_per_infogain_alloc>,<point_ratio>
(point_ratio = infogain points / ladder points over the curved jobs).
"""
from __future__ import annotations

import math
import time
import zlib

import numpy as np

from repro.core.catalog import aws_like_catalog
from repro.core.profiler import ProfileResult
from repro.core.sampling import ladder_from_anchor
from repro.core.simulator import build_history
from repro.pipeline import AllocationPipeline, PipelineRequest
from repro.profiling import ProfilingBudget

GiB = 1024 ** 3
FULL = 1e11                     # bytes; ladder anchored at 1% of full size
BUDGET_POINTS = 7               # equal envelope: base ladder + escalation cap

# name, curved?, mem(size) -> bytes, noise
JOBS = [
    ("linear/clean", False, lambda s: 0.9 * s + 1.6e9, 0.002),
    ("powerlaw/clean", True, lambda s: 3.0e-4 * s ** 1.35, 0.002),
    ("powerlaw/noisy", True, lambda s: 3.0e-4 * s ** 1.35, 0.01),
    ("loglinear/clean", True, lambda s: 4e9 * math.log(s) - 60e9, 0.002),
    ("piecewise/kink", True,
     lambda s: 0.5 * s + 1e9 if s < 0.5e9 else 2.0 * s - 0.25e9, 0.002),
    ("noisy/gate-fail", False, lambda s: 1.1 * s, 0.09),
]


def profile_fn(name, mem_fn, noise):
    def profile_at(size: float) -> ProfileResult:
        # deterministic per (job, size) so both strategies measure the
        # exact same world (crc32: stable across interpreters)
        rng = np.random.default_rng(
            zlib.crc32(f"{name}|{round(size)}".encode()))
        mem = mem_fn(size) * (1.0 + rng.normal(0.0, noise))
        return ProfileResult(size, max(mem, 0.0), 0.0, 10.0)
    return profile_at


def run(verbose: bool = True):
    catalog = aws_like_catalog()
    history = build_history()
    ladder = ladder_from_anchor(FULL * 0.01).sizes
    rows = []
    wall_us = []
    for name, curved, mem_fn, noise in JOBS:
        truth = mem_fn(FULL)
        row = {"job": name, "curved": curved}
        for placement in ("ladder", "infogain"):
            budget = ProfilingBudget(max_points=BUDGET_POINTS)
            pipeline = AllocationPipeline(catalog, history,
                                          adaptive=True,
                                          placement=placement,
                                          budget=budget)
            t0 = time.monotonic()
            trace = pipeline.run(PipelineRequest(
                name, profile_fn(name, mem_fn, noise), FULL,
                sizes=list(ladder), exclude_job_in_history=False))
            wall = (time.monotonic() - t0) * 1e6
            if placement == "infogain":
                wall_us.append(wall)
            req = trace.requirement_gib * GiB
            err = abs(req - truth) / truth if req > 0 else None
            row[placement] = {
                "points": len(trace.sizes),
                "charged": budget.points_spent,
                "confident": getattr(trace.plan.fit, "confident", False),
                "err": err,
            }
        rows.append(row)
        if verbose:
            lad, inf = row["ladder"], row["infogain"]
            fmt = lambda r: (f"{r['points']}pts "
                             f"{'PASS' if r['confident'] else 'fallback':8s} "
                             + (f"err={r['err']:7.2%}" if r["err"] is not None
                                else "err=      —"))
            print(f"{name:18s} {'curved' if curved else 'other ':6s} "
                  f"ladder: {fmt(lad)}   infogain: {fmt(inf)}")
    return rows, wall_us


def main() -> None:
    rows, wall_us = run(verbose=True)
    curved = [r for r in rows if r["curved"]]
    lad_pts = sum(r["ladder"]["points"] for r in curved)
    inf_pts = sum(r["infogain"]["points"] for r in curved)
    ratio = inf_pts / lad_pts if lad_pts else 1.0
    regressions = []
    for r in curved:
        le, ie = r["ladder"]["err"], r["infogain"]["err"]
        # equal-or-better accuracy: a fallback (err None, requirement 0)
        # matches a fallback; a confident answer is compared directly,
        # with a small absolute tolerance for noise-level differences
        worse_acc = (ie is not None and le is not None and ie > le + 0.02) \
            or (ie is None) != (le is None)
        if r["infogain"]["points"] >= r["ladder"]["points"] or worse_acc:
            regressions.append(r["job"])
    print(f"\ncurved jobs: ladder {lad_pts} points -> infogain {inf_pts} "
          f"({1 - ratio:.0%} saved) at equal-or-better requirement error"
          + (f"  [REGRESSION: {regressions}]" if regressions else ""))
    us = sum(wall_us) / len(wall_us) if wall_us else 0.0
    print(f"point_placement,{us:.1f},{ratio:.3f}")


if __name__ == "__main__":
    main()
