"""§Roofline table: read dry-run records and emit the per-(arch x shape x
mesh) three-term roofline with dominant bottleneck, MODEL_FLOPS/HLO_FLOPs
utilization and the mfu bound."""
from __future__ import annotations

import glob
import json
import os
import time

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records(mesh_suffix: str = "singlepod"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR,
                                              f"*__{mesh_suffix}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs, verbose=True):
    rows = []
    for r in recs:
        if r.get("skipped"):
            rows.append({"cell": r["cell"], "skipped": True})
            continue
        ro = r["roofline"]
        rows.append({
            "cell": r["cell"],
            "gib_per_dev": r["memory"]["per_device_gib"],
            "compute_s": ro["compute_s"],
            "memory_s": ro["memory_s"],
            "collective_s": ro["collective_s"],
            "dominant": ro["dominant"],
            "useful": ro["useful_flops_fraction"],
            "mfu_bound": ro["mfu_bound"],
        })
    if verbose:
        print(f"{'cell':44s} {'GiB/dev':>8s} {'comp_s':>9s} {'mem_s':>9s} "
              f"{'coll_s':>9s} {'dom':>10s} {'useful':>7s} {'MFU':>6s}")
        skip_note = "SKIP-BY-DESIGN (full attention at 500k)"
        for row in rows:
            if row.get("skipped"):
                print(f"{row['cell']:44s} {skip_note}")
                continue
            print(f"{row['cell']:44s} {row['gib_per_dev']:8.2f} "
                  f"{row['compute_s']:9.3f} {row['memory_s']:9.3f} "
                  f"{row['collective_s']:9.3f} {row['dominant']:>10s} "
                  f"{row['useful']:7.3f} {row['mfu_bound']:6.3f}")
    return rows


def main():
    t0 = time.monotonic()
    for suffix in ("singlepod", "multipod"):
        recs = load_records(suffix)
        if not recs:
            continue
        print(f"== mesh: {suffix} ({len(recs)} cells) ==")
        rows = table(recs)
        live = [r for r in rows if not r.get("skipped")]
        if live:
            import numpy as np
            mean_mfu = float(np.mean([r["mfu_bound"] for r in live]))
            print(f"mean mfu_bound ({suffix}): {mean_mfu:.4f}")
    wall = time.monotonic() - t0
    n = len(load_records("singlepod")) + len(load_records("multipod"))
    print(f"roofline_table,{wall * 1e6:.0f},cells={n}")


if __name__ == "__main__":
    main()
