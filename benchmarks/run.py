"""Benchmark harness: one module per paper table/figure (+ roofline +
planner). Each prints human-readable results then a final
``name,us_per_call,derived`` CSV line."""
import sys
import traceback

MODULES = [
    "benchmarks.table1_selection_cost",
    "benchmarks.table2_profiling_time",
    "benchmarks.fig1_memory_cliff",
    "benchmarks.fig3_profile_traces",
    "benchmarks.fig4_measurement_hygiene",
    "benchmarks.allocation_service_throughput",
    "benchmarks.planner_validation",
    "benchmarks.roofline_table",
]


def main() -> None:
    failures = 0
    for mod_name in MODULES:
        print(f"\n===== {mod_name} =====", flush=True)
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(f"{failures} benchmarks failed")


if __name__ == '__main__':
    main()
