"""Benchmark harness: one module per paper table/figure (+ roofline +
planner + service/profiling). Each prints human-readable results then a
final ``name,us_per_call,derived`` CSV line.

``--only SUBSTR`` (repeatable) restricts the run to modules whose name
contains any given substring — CI smokes the fast allocation benchmarks
with ``--only table1 --only allocation --only profiling`` instead of
paying for the compile-heavy planner/roofline modules.
"""
import argparse
import os
import sys
import traceback

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; the `benchmarks.<module>` imports below need the root
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

MODULES = [
    "benchmarks.table1_selection_cost",
    "benchmarks.table2_profiling_time",
    "benchmarks.fig1_memory_cliff",
    "benchmarks.fig3_profile_traces",
    "benchmarks.fig4_measurement_hygiene",
    "benchmarks.allocation_service_throughput",
    "benchmarks.load_tiers",
    "benchmarks.profiling_adaptive",
    "benchmarks.point_placement",
    "benchmarks.cost_objectives",
    "benchmarks.state_backends",
    "benchmarks.planner_validation",
    "benchmarks.roofline_table",
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", action="append", default=[],
                    help="run only modules whose name contains this "
                         "substring (repeatable)")
    args = ap.parse_args(argv)
    mods = [m for m in MODULES
            if not args.only or any(s in m for s in args.only)]
    if not mods:
        sys.exit(f"no benchmark matches --only {args.only}")
    failures = 0
    for mod_name in mods:
        print(f"\n===== {mod_name} =====", flush=True)
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(f"{failures} benchmarks failed")


if __name__ == '__main__':
    main()
