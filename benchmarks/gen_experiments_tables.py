"""Generate the EXPERIMENTS.md §Dry-run / §Roofline / §Perf markdown tables
from experiments/dryrun/*.json and experiments/perf/*.json."""
import glob
import json
import os
import sys

GiB = 1024 ** 3


def fmt_bytes(b):
    if b >= GiB:
        return f"{b / GiB:.2f} GiB"
    return f"{b / 2**20:.1f} MiB"


def dryrun_table(suffix):
    rows = []
    for path in sorted(glob.glob(f"experiments/dryrun/*__{suffix}.json")):
        with open(path) as f:
            r = json.load(f)
        if r.get("skipped"):
            rows.append(f"| {r['cell']} | — | — | — | — | SKIP (full attn "
                        f"@500k) |")
            continue
        m = r["memory"]
        c = r["collectives"]
        rows.append(
            f"| {r['cell']} | {m['per_device_gib']:.2f} | "
            f"{r['hlo_costs']['dot_flops_per_dev'] / 1e12:.2f} | "
            f"{c['wire_bytes_per_dev'] / 1e9:.1f} | "
            f"{r['compile_s']:.0f}s | ok |")
    hdr = ("| cell | GiB/dev | HLO TFLOP/dev | coll GB/dev | compile | "
           "status |\n|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def roofline_table(suffix):
    rows = []
    for path in sorted(glob.glob(f"experiments/dryrun/*__{suffix}.json")):
        with open(path) as f:
            r = json.load(f)
        if r.get("skipped"):
            rows.append(f"| {r['cell']} | — | — | — | — | — | — | SKIP |")
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['cell']} | {ro['compute_s']:.3f} | {ro['memory_s']:.3f} |"
            f" {ro['collective_s']:.3f} | **{ro['dominant']}** | "
            f"{ro['useful_flops_fraction']:.3f} | {ro['mfu_bound']:.3f} | |")
    hdr = ("| cell | compute_s | memory_s | collective_s | dominant | "
           "6ND/HLO | MFU@bound | note |\n|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def perf_table():
    rows = []
    for path in sorted(glob.glob("experiments/perf/*.json")):
        with open(path) as f:
            r = json.load(f)
        ro = r["roofline"]
        name = os.path.basename(path)[:-5]
        rows.append(
            f"| {name} | {r.get('mesh_shape')} | {ro['compute_s']:.3f} | "
            f"{ro['memory_s']:.3f} | {ro['collective_s']:.3f} | "
            f"{ro['dominant']} | {ro['mfu_bound']:.3f} | "
            f"{r['memory']['per_device_gib']:.1f} |")
    hdr = ("| cell/variant | mesh | compute_s | memory_s | collective_s | "
           "dominant | MFU@bound | GiB/dev |\n|---|---|---|---|---|---|---|"
           "---|")
    return hdr + "\n" + "\n".join(rows)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### single-pod (16,16)\n")
        print(dryrun_table("singlepod"))
        print("\n### multi-pod (2,16,16)\n")
        print(dryrun_table("multipod"))
    if which in ("all", "roofline"):
        print("\n### roofline single-pod\n")
        print(roofline_table("singlepod"))
        print("\n### roofline multi-pod\n")
        print(roofline_table("multipod"))
    if which in ("all", "perf"):
        print("\n### perf\n")
        print(perf_table())
