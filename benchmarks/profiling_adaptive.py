"""Table II analogue for the adaptive scheduler: fixed 5-point ladder vs
budgeted adaptive profiling over the simulated scout corpus.

For every job both pipelines run on identical synthetic measurements; the
comparison reports, per profile and on average:

  points    profile runs spent (the paper's cost unit — each run is 0.5-3
            minutes of laptop time);
  wall      accounted profiling seconds (sum of simulated per-run wall
            times, i.e. the quantity a ProfilingBudget charges);
  req err   relative requirement error vs the corpus ground truth
            (working_set_factor * full_size) for confident-linear jobs.

Structural claims checked here (and asserted in tests/test_profiling.py):
adaptive spends strictly fewer points than the fixed ladder on every
confident-linear job while staying within 5% of the fixed ladder's
requirement, and never regresses the fallback outcome of noisy/flat jobs.

Final CSV line: profiling_adaptive,<us_per_adaptive_alloc>,<point_ratio>
(point_ratio = adaptive points / fixed points over confident-linear jobs).
"""
from __future__ import annotations

import time

from repro.allocator.model_zoo import zoo_fitter
from repro.core.catalog import aws_like_catalog
from repro.core.crispy import CrispyAllocator
from repro.core.simulator import (GiB, build_history, make_profile_fn,
                                  scout_like_jobs)
from repro.profiling import ProfilingBudget

PAPER_ENVELOPE_S = 600.0        # "less than ten minutes per job"


def run(verbose: bool = True):
    jobs = scout_like_jobs()
    catalog = aws_like_catalog()
    history = build_history(jobs, catalog)
    alloc = CrispyAllocator(catalog, history, overhead_per_node_gib=2.0,
                            fitter=zoo_fitter())
    rows = []
    wall_us = []
    for job in jobs:
        full = job.dataset_gib * GiB
        kw = dict(anchor=full * 0.01)
        fixed = alloc.allocate(job.name, make_profile_fn(job), full, **kw)
        budget = ProfilingBudget(charge_s=PAPER_ENVELOPE_S)
        t0 = time.monotonic()
        adapt = alloc.allocate(job.name, make_profile_fn(job), full,
                               adaptive=True, budget=budget, **kw)
        wall_us.append((time.monotonic() - t0) * 1e6)
        truth_gib = job.working_set_factor * job.dataset_gib \
            if job.mem_profile == "linear" else None
        rows.append({
            "job": job.name, "profile": job.mem_profile,
            "fixed_points": fixed.points_profiled,
            "adaptive_points": adapt.points_profiled,
            "fixed_wall_s": sum(r.wall_s for r in fixed.results),
            "adaptive_wall_s": sum(r.wall_s for r in adapt.results),
            "fixed_req_gib": fixed.requirement_gib,
            "adaptive_req_gib": adapt.requirement_gib,
            "fixed_confident": fixed.model.confident,
            "adaptive_confident": adapt.model.confident,
            "early_stop": adapt.early_stop,
            "escalated": adapt.escalated,
            "truth_gib": truth_gib,
        })
        if verbose:
            err = ""
            if truth_gib and adapt.requirement_gib > 0:
                fe = abs(fixed.requirement_gib - truth_gib) / truth_gib
                ae = abs(adapt.requirement_gib - truth_gib) / truth_gib
                err = f" err fixed={fe:6.2%} adaptive={ae:6.2%}"
            print(f"{job.name:28s} {job.mem_profile:6s} "
                  f"points {rows[-1]['fixed_points']}->"
                  f"{rows[-1]['adaptive_points']}  wall "
                  f"{rows[-1]['fixed_wall_s']:7.1f}s->"
                  f"{rows[-1]['adaptive_wall_s']:7.1f}s"
                  f"{'  EARLY' if adapt.early_stop else ''}"
                  f"{'  ESC' if adapt.escalated else ''}{err}")
    return rows, wall_us


def main() -> None:
    rows, wall_us = run(verbose=True)
    linear = [r for r in rows if r["profile"] == "linear"
              and r["fixed_confident"]]
    fixed_pts = sum(r["fixed_points"] for r in linear)
    adapt_pts = sum(r["adaptive_points"] for r in linear)
    ratio = adapt_pts / fixed_pts if fixed_pts else 1.0
    fixed_wall = sum(r["fixed_wall_s"] for r in rows)
    adapt_wall = sum(r["adaptive_wall_s"] for r in rows)
    worst_err = 0.0
    for r in linear:
        if r["truth_gib"] and r["fixed_req_gib"] > 0:
            drift = abs(r["adaptive_req_gib"] - r["fixed_req_gib"]) \
                / r["fixed_req_gib"]
            worst_err = max(worst_err, drift)
    print(f"\nconfident-linear jobs: {fixed_pts} fixed points -> "
          f"{adapt_pts} adaptive ({1 - ratio:.0%} saved), worst "
          f"requirement drift vs fixed {worst_err:.2%}")
    print(f"all jobs: accounted profiling wall {fixed_wall:.0f}s fixed -> "
          f"{adapt_wall:.0f}s adaptive "
          f"(paper envelope {PAPER_ENVELOPE_S:.0f}s/job)")
    us = sum(wall_us) / len(wall_us) if wall_us else 0.0
    print(f"profiling_adaptive,{us:.1f},{ratio:.3f}")


if __name__ == "__main__":
    main()
