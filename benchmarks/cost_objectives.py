"""Selection objectives compared: cheapest_fit vs min_cost vs min_runtime.

Crispy's selection (objective="cheapest_fit") picks the cheapest config
whose memory fits — but the follow-up work (arXiv:2306.03672) shows the
real objective is cost = price × predicted runtime. This benchmark drives
the SAME unified pipeline (repro.pipeline) over synthetic jobs whose
memory curve is cleanly linear (the memory gate passes everywhere) while
the RUNTIME curve varies:

  linear       wall ∝ size — scaling out buys runtime almost linearly, so
               the cost ranking is close to the price ranking.
  superlinear  wall ∝ size^1.35 — the full-size runtime dominates, and
               paying for a big BFA-favored cluster is cost-inefficient:
               min_cost picks a *cheaper* config at equal-or-lower
               predicted cost.

Per job × objective: selected config, $/h, predicted runtime and cost
(the runtime companion model is fit from the ladder's per-point wall
times; objectives degrade to cheapest_fit when it is unconfident).

Asserted here (the PR's acceptance criterion): on the superlinear job
min_cost selects a config with strictly lower $/h than cheapest_fit at
equal-or-lower predicted cost.

Final CSV line: cost_objectives,<us_per_alloc>,<price_ratio>
(price_ratio = min_cost $/h ÷ cheapest_fit $/h on the superlinear job).
"""
from __future__ import annotations

import math
import time
import zlib

import numpy as np

from repro.core.catalog import aws_like_catalog
from repro.core.profiler import ProfileResult
from repro.core.sampling import ladder_from_anchor
from repro.core.selector import (OBJECTIVES, predicted_cost_usd,
                                 predicted_runtime_s)
from repro.core.simulator import build_history
from repro.pipeline import AllocationPipeline, PipelineRequest

GiB = 1024 ** 3
FULL = 1e11                     # bytes; ladder anchored at 1% of full size

# name, mem(size) -> bytes, wall(size) -> seconds
JOBS = [
    ("runtime/linear", lambda s: 0.9 * s + 1.6e9,
     lambda s: 20.0 + 4e-8 * s),
    ("runtime/superlinear", lambda s: 0.9 * s + 1.6e9,
     lambda s: 1e-11 * s ** 1.35),
]


def profile_fn(name, mem_fn, wall_fn):
    def profile_at(size: float) -> ProfileResult:
        # deterministic per (job, size): every objective pass measures
        # the exact same world (crc32: stable across interpreters)
        rng = np.random.default_rng(
            zlib.crc32(f"{name}|{round(size)}".encode()))
        mem = mem_fn(size) * (1.0 + rng.normal(0.0, 0.002))
        return ProfileResult(size, max(mem, 0.0), 0.0, wall_fn(size))
    return profile_at


def run(verbose: bool = True):
    catalog = aws_like_catalog()
    history = build_history()
    ladder = ladder_from_anchor(FULL * 0.01).sizes
    rows = {}
    wall_us = []
    for name, mem_fn, wall_fn in JOBS:
        rows[name] = {}
        for objective in OBJECTIVES:
            pipeline = AllocationPipeline(catalog, history)
            t0 = time.monotonic()
            trace = pipeline.run(PipelineRequest(
                name, profile_fn(name, mem_fn, wall_fn), FULL,
                sizes=list(ladder), exclude_job_in_history=False,
                objective=objective))
            wall_us.append((time.monotonic() - t0) * 1e6)
            sel = trace.selection
            rows[name][objective] = {
                "selection": sel,
                "runtime_model": trace.plan.runtime_fit,
            }
            if verbose:
                rt = (f"{sel.predicted_runtime_s:9.1f}s"
                      if sel.predicted_runtime_s is not None else
                      "        —")
                cost = (f"${sel.predicted_cost_usd:7.3f}"
                        if sel.predicted_cost_usd is not None else
                        "      —")
                print(f"{name:22s} {objective:12s} "
                      f"{sel.config.name:16s} "
                      f"${sel.config.usd_per_hour:6.2f}/h  "
                      f"runtime={rt}  cost={cost}"
                      + ("  [fell back]" if sel.objective_fell_back
                         else ""))
    return rows, wall_us


def main() -> None:
    rows, wall_us = run(verbose=True)

    sup = rows["runtime/superlinear"]
    cheap_sel = sup["cheapest_fit"]["selection"]
    cost_sel = sup["min_cost"]["selection"]
    rt_model = sup["min_cost"]["runtime_model"]
    assert rt_model is not None and rt_model.confident, \
        "runtime companion fit must be confident on the clean job"
    assert not cost_sel.objective_fell_back, cost_sel
    # what min_cost avoided paying: the predicted cost of cheapest_fit's
    # pick under the SAME runtime model
    cheap_rt = predicted_runtime_s(rt_model, FULL, cheap_sel.config)
    cheap_cost = predicted_cost_usd(cheap_rt, cheap_sel.config)
    assert cost_sel.config.usd_per_hour < cheap_sel.config.usd_per_hour, \
        (cost_sel.config.name, cheap_sel.config.name)
    assert cost_sel.predicted_cost_usd <= cheap_cost + 1e-9, \
        (cost_sel.predicted_cost_usd, cheap_cost)
    price_ratio = (cost_sel.config.usd_per_hour
                   / cheap_sel.config.usd_per_hour)
    print(f"\nsuperlinear job: cheapest_fit {cheap_sel.config.name} "
          f"(${cheap_sel.config.usd_per_hour:.2f}/h, predicted "
          f"${cheap_cost:.3f}) -> min_cost {cost_sel.config.name} "
          f"(${cost_sel.config.usd_per_hour:.2f}/h, predicted "
          f"${cost_sel.predicted_cost_usd:.3f})")

    # min_runtime never predicts slower than min_cost (it optimizes it)
    lin = rows["runtime/linear"]
    for jrows in (sup, lin):
        mr = jrows["min_runtime"]["selection"]
        mc = jrows["min_cost"]["selection"]
        if not (mr.objective_fell_back or mc.objective_fell_back):
            assert mr.predicted_runtime_s <= mc.predicted_runtime_s + 1e-9

    us = sum(wall_us) / len(wall_us) if wall_us else 0.0
    print(f"cost_objectives,{us:.1f},{price_ratio:.3f}")


if __name__ == "__main__":
    main()
