"""Fig. 3 analogue: memory-use-over-time traces for profiling runs at five
sample sizes — REAL RSS traces of local jobs; linear (K-Means) vs flat
(Sort) behaviour, with the per-job R2 the gate sees."""
from __future__ import annotations

import time

import numpy as np

from repro.core.local_jobs import LOCAL_JOBS
from repro.core.memory_model import fit_memory_model
from repro.core.profiler import RSSProfiler
from repro.core.sampling import ladder_from_anchor

ANCHOR = 48 * 1024 * 1024


def run(verbose: bool = True):
    profiler = RSSProfiler(interval_s=0.002)
    out = {}
    for name in ("kmeans", "logregression", "sort"):
        ladder = ladder_from_anchor(ANCHOR)
        profiler.profile(LOCAL_JOBS[name](int(ladder.anchor)), ladder.anchor)
        peaks = []
        for s in ladder.sizes:
            r = profiler.profile(LOCAL_JOBS[name](int(s)), s)
            peaks.append(r.job_mem_bytes)
            if verbose and r.trace:
                t = np.asarray(r.trace) - r.base_mem_bytes
                n = max(1, len(t) // 24)
                spark = "".join(
                    " .:-=+*#%@"[min(int(v / (max(t.max(), 1) + 1) * 10), 9)]
                    for v in t[::n][:24])
                print(f"{name:14s} size={s / 2**20:6.1f}MiB "
                      f"peak={r.job_mem_bytes / 2**20:7.1f}MiB |{spark}|")
        m = fit_memory_model(ladder.sizes, peaks)
        out[name] = m
        if verbose:
            print(f"{name:14s} R2={m.r2:.5f} -> "
                  f"{'extrapolate' if m.confident else 'fallback'}")
    return out


def main():
    t0 = time.monotonic()
    out = run(verbose=True)
    wall = time.monotonic() - t0
    km = out["kmeans"].r2
    print(f"fig3_profile_traces,{wall * 1e6:.0f},kmeans_r2={km:.5f}")


if __name__ == "__main__":
    main()
