"""The paper, end to end, on this machine: profile the seven HiBench-family
jobs with the OS-level RSS profiler (five sample sizes each), fit the
memory model, gate on R^2, and select an AWS-style cluster configuration —
Crispy §III steps 1-4 with *real* measurements.

  PYTHONPATH=src python examples/profile_and_select.py
"""
from repro.core.catalog import aws_like_catalog
from repro.core.crispy import CrispyAllocator
from repro.core.local_jobs import LOCAL_JOBS
from repro.core.profiler import RSSProfiler
from repro.core.sampling import ladder_from_anchor
from repro.core.simulator import build_history

GiB = 1024 ** 3
ANCHOR = 48 * 1024 * 1024            # profiling sample anchor (48 MiB)
FULL_DATASET_GIB = 64                # pretend production dataset size


def main():
    catalog = aws_like_catalog()
    history = build_history()         # cost history of unrelated jobs (BFA)
    profiler = RSSProfiler(interval_s=0.002)
    alloc = CrispyAllocator(catalog, history, overhead_per_node_gib=2.0,
                            leeway=0.05)
    print(f"{'job':16s} {'R2':>9s} {'gate':>9s} {'req(GiB)':>9s} "
          f"{'selected':>16s} {'profiling(s)':>12s}")
    for name, factory in LOCAL_JOBS.items():
        ladder = ladder_from_anchor(ANCHOR)
        profiler.profile(factory(int(ladder.anchor)), ladder.anchor)  # warmup

        def profile_at(size):
            return profiler.profile(factory(int(size)), size)

        rep = alloc.allocate(name, profile_at, FULL_DATASET_GIB * GiB,
                             sizes=ladder.sizes, exclude_job_in_history=False)
        print(f"{name:16s} {rep.model.r2:9.5f} "
              f"{'PASS' if rep.model.confident else 'fallback':>9s} "
              f"{rep.requirement_gib:9.1f} "
              f"{rep.selection.config.name:>16s} "
              f"{rep.profiling_wall_s:12.2f}")


if __name__ == "__main__":
    main()
