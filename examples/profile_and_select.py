"""The paper, end to end, on this machine: profile the seven HiBench-family
jobs with the OS-level RSS profiler (five sample sizes each), fit the
memory model, gate on R^2, and select an AWS-style cluster configuration —
Crispy §III steps 1-4 with *real* measurements.

A second pass re-runs the suite through the adaptive scheduler under a
shared ProfilingBudget (the paper's ten-minute envelope, scaled to this
demo): linear jobs stop after ~3 samples instead of 5, anything the
budget cuts short falls back exactly like an unconfident fit.

  PYTHONPATH=src python examples/profile_and_select.py
"""
from repro.core.catalog import aws_like_catalog
from repro.core.crispy import CrispyAllocator
from repro.core.local_jobs import LOCAL_JOBS
from repro.core.profiler import RSSProfiler
from repro.core.sampling import ladder_from_anchor
from repro.core.simulator import build_history
from repro.profiling import ProfilingBudget

GiB = 1024 ** 3
ANCHOR = 48 * 1024 * 1024            # profiling sample anchor (48 MiB)
FULL_DATASET_GIB = 64                # pretend production dataset size
BUDGET_WALL_S = 120.0                # demo-scaled ten-minute envelope


def _profile_fn(profiler, factory):
    def profile_at(size):
        return profiler.profile(factory(int(size)), size)
    return profile_at


def main():
    catalog = aws_like_catalog()
    history = build_history()         # cost history of unrelated jobs (BFA)
    profiler = RSSProfiler(interval_s=0.002)
    alloc = CrispyAllocator(catalog, history, overhead_per_node_gib=2.0,
                            leeway=0.05)
    print("== fixed 5-point ladders (the paper) ==")
    print(f"{'job':16s} {'R2':>9s} {'gate':>9s} {'req(GiB)':>9s} "
          f"{'selected':>16s} {'profiling(s)':>12s}")
    for name, factory in LOCAL_JOBS.items():
        ladder = ladder_from_anchor(ANCHOR)
        profiler.profile(factory(int(ladder.anchor)), ladder.anchor)  # warmup
        rep = alloc.allocate(name, _profile_fn(profiler, factory),
                             FULL_DATASET_GIB * GiB,
                             sizes=ladder.sizes, exclude_job_in_history=False)
        print(f"{name:16s} {rep.model.r2:9.5f} "
              f"{'PASS' if rep.model.confident else 'fallback':>9s} "
              f"{rep.requirement_gib:9.1f} "
              f"{rep.selection.config.name:>16s} "
              f"{rep.profiling_wall_s:12.2f}")

    print(f"\n== adaptive ladders under one {BUDGET_WALL_S:.0f}s budget ==")
    budget = ProfilingBudget(wall_s=BUDGET_WALL_S)
    print(f"{'job':16s} {'points':>6s} {'gate':>9s} {'req(GiB)':>9s} "
          f"{'notes':>22s}")
    for name, factory in LOCAL_JOBS.items():
        rep = alloc.allocate(name, _profile_fn(profiler, factory),
                             FULL_DATASET_GIB * GiB,
                             sizes=ladder_from_anchor(ANCHOR).sizes,
                             exclude_job_in_history=False,
                             adaptive=True, budget=budget)
        notes = " ".join(n for n, on in
                         (("early-stop", rep.early_stop),
                          ("escalated", rep.escalated),
                          ("budget-cut", rep.budget_exhausted)) if on)
        print(f"{name:16s} {rep.points_profiled:6d} "
              f"{'PASS' if rep.model.confident else 'fallback':>9s} "
              f"{rep.requirement_gib:9.1f} {notes:>22s}")
    snap = budget.snapshot()
    print(f"budget: {snap['points_spent']} points, "
          f"{snap['elapsed_s']:.1f}/{snap['wall_s']:.0f}s elapsed, "
          f"{snap['denials']} denials")


if __name__ == "__main__":
    main()
