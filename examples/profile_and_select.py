"""The paper, end to end, on this machine: profile the seven HiBench-family
jobs with the OS-level RSS profiler (five sample sizes each), fit the
memory model, gate on R^2, and select an AWS-style cluster configuration —
Crispy §III steps 1-4 with *real* measurements, driven through the unified
`repro.pipeline.AllocationPipeline` (the same staged path the batched
AllocationService serves; see repro/pipeline/__init__.py for the diagram).

A second pass re-runs the suite adaptively under a shared ProfilingBudget
(the paper's ten-minute envelope, scaled to this demo), comparing both
point-placement strategies: the PR-2 ladder prefix and the
information-optimal default — `placement="infogain"` profiles whichever
size is expected to shrink candidate-model disagreement at full size the
most, and stops when more measurement would not change the answer.

A third pass shows the objective axis: the same ladder's wall times feed
a runtime companion fit, and `objective="min_cost"` ranks the memory-
feasible configs by $/h x predicted runtime instead of price alone
(cheapest fit). On a superlinear-runtime job the cost-optimal config is
*cheaper per hour* than the cheapest-fit pick; whenever the runtime fit
is unconfident every objective degrades to cheapest_fit, so the answer
is never worse than the paper's.

  PYTHONPATH=src python examples/profile_and_select.py
"""
from repro.allocator.model_zoo import zoo_fitter
from repro.core.catalog import aws_like_catalog
from repro.core.memory_model import fit_memory_model
from repro.core.local_jobs import LOCAL_JOBS
from repro.core.profiler import ProfileResult, RSSProfiler
from repro.core.sampling import ladder_from_anchor
from repro.core.selector import OBJECTIVES
from repro.core.simulator import build_history
from repro.pipeline import AllocationPipeline, PipelineRequest
from repro.profiling import ProfilingBudget

GiB = 1024 ** 3
ANCHOR = 48 * 1024 * 1024            # profiling sample anchor (48 MiB)
FULL_DATASET_GIB = 64                # pretend production dataset size
BUDGET_WALL_S = 120.0                # demo-scaled ten-minute envelope


def _profile_fn(profiler, factory):
    def profile_at(size):
        return profiler.profile(factory(int(size)), size)
    return profile_at


def main():
    catalog = aws_like_catalog()
    history = build_history()         # cost history of unrelated jobs (BFA)
    profiler = RSSProfiler(interval_s=0.002)
    ladder = ladder_from_anchor(ANCHOR)

    # one staged decision path; the fixed pass uses the paper's OLS linear
    # fit, the adaptive passes the model zoo (placement needs candidates
    # that can disagree)
    pipeline = AllocationPipeline(catalog, history, fitter=fit_memory_model,
                                  overhead_per_node_gib=2.0, leeway=0.05)
    print("== fixed 5-point ladders (the paper) ==")
    print(f"{'job':16s} {'R2':>9s} {'gate':>9s} {'req(GiB)':>9s} "
          f"{'selected':>16s} {'profiling(s)':>12s}")
    for name, factory in LOCAL_JOBS.items():
        profiler.profile(factory(int(ladder.anchor)), ladder.anchor)  # warmup
        trace = pipeline.run(PipelineRequest(
            name, _profile_fn(profiler, factory), FULL_DATASET_GIB * GiB,
            sizes=ladder.sizes, exclude_job_in_history=False))
        model = trace.plan.fit
        print(f"{name:16s} {model.r2:9.5f} "
              f"{'PASS' if model.confident else 'fallback':>9s} "
              f"{trace.requirement_gib:9.1f} "
              f"{trace.selection.config.name:>16s} "
              f"{trace.wall_s:12.2f}")

    for placement in ("ladder", "infogain"):
        print(f"\n== adaptive ({placement}) under one "
              f"{BUDGET_WALL_S:.0f}s budget ==")
        budget = ProfilingBudget(wall_s=BUDGET_WALL_S)
        adaptive = AllocationPipeline(catalog, history,
                                      overhead_per_node_gib=2.0,
                                      leeway=0.05, fitter=zoo_fitter(),
                                      adaptive=True, placement=placement,
                                      budget=budget)
        print(f"{'job':16s} {'points':>6s} {'gate':>9s} {'req(GiB)':>9s} "
              f"{'notes':>22s}")
        for name, factory in LOCAL_JOBS.items():
            trace = adaptive.run(PipelineRequest(
                name, _profile_fn(profiler, factory),
                FULL_DATASET_GIB * GiB, sizes=ladder.sizes,
                exclude_job_in_history=False))
            plan = trace.plan
            notes = " ".join(n for n, on in
                             (("early-stop", plan.early_stop),
                              ("escalated", plan.escalated),
                              ("budget-cut", plan.budget_exhausted)) if on)
            print(f"{name:16s} {plan.total_points:6d} "
                  f"{'PASS' if plan.fit.confident else 'fallback':>9s} "
                  f"{trace.requirement_gib:9.1f} {notes:>22s}")
        snap = budget.snapshot()
        print(f"budget: {snap['points_spent']} points, "
              f"{snap['elapsed_s']:.1f}/{snap['wall_s']:.0f}s elapsed, "
              f"{snap['denials']} denials")

    # -- objective axis: cost-optimal vs cheapest-fit ----------------------
    # a synthetic job whose memory curve is cleanly linear (every config's
    # memory gate answers the same) while runtime grows superlinearly —
    # exactly where "cheapest config that fits" and "cheapest total run"
    # disagree. benchmarks/cost_objectives.py measures this at scale.
    print("\n== selection objectives (superlinear-runtime job) ==")
    full = 1e11

    def synthetic_profile(size):
        return ProfileResult(size, 0.9 * size + 1.6e9, 0.0,
                             1e-11 * size ** 1.35)

    objective_pipeline = AllocationPipeline(catalog, history,
                                            overhead_per_node_gib=2.0,
                                            fitter=zoo_fitter())
    print(f"{'objective':14s} {'selected':>16s} {'$/h':>7s} "
          f"{'pred runtime':>12s} {'pred cost':>10s}")
    for objective in OBJECTIVES:
        trace = objective_pipeline.run(PipelineRequest(
            "example/superlinear", synthetic_profile, full,
            sizes=ladder_from_anchor(full * 0.01).sizes,
            exclude_job_in_history=False, objective=objective))
        sel = trace.selection
        rt = (f"{sel.predicted_runtime_s:10.1f}s"
              if sel.predicted_runtime_s is not None else "         —")
        cost = (f"${sel.predicted_cost_usd:8.3f}"
                if sel.predicted_cost_usd is not None else "        —")
        print(f"{objective:14s} {sel.config.name:>16s} "
              f"{sel.config.usd_per_hour:7.2f} {rt:>12s} {cost:>10s}"
              + ("  [fell back to cheapest_fit]"
                 if sel.objective_fell_back else ""))


if __name__ == "__main__":
    main()
