"""Batched serving demo: continuous batching over a fixed-slot KV cache,
staggered arrivals, per-request latency stats. Uses the reduced rwkv6
(attention-free O(1)-state) and deepseek-7b (KV cache) configs.

  PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax

from repro.configs import get_arch
from repro.configs.base import RunConfig
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine

RUN = RunConfig(attn_impl="full", remat="nothing", compute_dtype="float32")


def demo(arch: str, n_requests: int = 12, slots: int = 4):
    cfg = get_arch(arch).reduced()
    model = Model(cfg, RUN)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=slots, max_len=64)
    t0 = time.monotonic()
    for rid in range(n_requests):
        engine.submit(Request(rid, prompt=[rid % 17 + 1, 5, 9],
                              max_new_tokens=16,
                              temperature=0.0 if rid % 2 else 0.8))
    done = engine.run()
    wall = time.monotonic() - t0
    lat = [r.finished_at - r.submitted_at for r in done]
    toks = sum(len(r.out_tokens) for r in done)
    print(f"{arch}: served {len(done)} requests / {toks} tokens in "
          f"{wall:.2f}s ({toks / wall:.1f} tok/s aggregate, "
          f"{slots} slots); mean latency {sum(lat) / len(lat):.2f}s")
    sample = sorted(done, key=lambda r: r.rid)[0]
    print(f"  e.g. request 0: {sample.prompt} -> {sample.out_tokens}")


def main():
    demo("deepseek-7b")
    demo("rwkv6-7b")


if __name__ == "__main__":
    main()
