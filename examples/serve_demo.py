"""Batched serving demo: continuous batching over a fixed-slot KV cache,
staggered arrivals, per-request latency stats, plus the allocation endpoint
(repro.allocator) answering concurrent resource-allocation requests on the
same serving surface. Uses the reduced rwkv6 (attention-free O(1)-state)
and deepseek-7b (KV cache) configs.

Every allocation answer is produced by the unified
`repro.pipeline.AllocationPipeline` (one staged path: warm-start ->
acquisition -> fit -> extrapolate -> select; see
repro/pipeline/__init__.py) — the AllocationService adds only batching,
caching and this wire surface. Adaptive requests default to
information-optimal point placement (`placement="infogain"`; the PR-2
ladder prefix remains as `placement="ladder"`), and the wire response
reports which strategy planned the profile.

  PYTHONPATH=src python examples/serve_demo.py

`demo_shared_state` shows the cross-process story (repro.state): a
crispy-daemon owning the shared profile store, model registry and ONE
profiling envelope that every allocation service arbitrates through
atomic reservations. In production the daemon is its own process:

  # start (persist state in ./crispy-state; restarts resume from it)
  PYTHONPATH=src python -m repro.state.daemon \\
      --socket /tmp/crispy.sock --root ./crispy-state
  # connect any number of services to it
  svc = AllocationService(catalog, history,
                          backend=DaemonBackend("/tmp/crispy.sock"),
                          budget=ProfilingBudget(charge_s=600.0,
                              backend=DaemonBackend("/tmp/crispy.sock")))
  # health-check / stop
  PYTHONPATH=src python -m repro.state.daemon --socket /tmp/crispy.sock \\
      --ping      # exits 0 iff alive
      --shutdown  # daemon drains, unlinks the socket, exits 0

Transports & compaction
-----------------------
The unix socket serves co-located services; `--listen host:port` serves
the SAME state over TCP so allocation services on other hosts share one
envelope/registry/store (this demo connects service B over loopback
TCP). TCP crosses the unix-permission boundary, so gate it with a
shared token — `--auth-token SECRET` or $CRISPY_DAEMON_TOKEN on the
daemon, `DaemonBackend("host:port", auth_token=...)` (or the same env
var) on clients; the client then authenticates each connection before
its first request:

  PYTHONPATH=src python -m repro.state.daemon \\
      --socket /tmp/crispy.sock --listen 0.0.0.0:7421 \\
      --auth-token SECRET --root ./crispy-state
  svc_remote = AllocationService(catalog, history,
                                 backend=DaemonBackend(
                                     "crispy-host:7421",
                                     auth_token="SECRET"))
  # health-check a tcp daemon
  PYTHONPATH=src python -m repro.state.daemon \\
      --listen crispy-host:7421 --ping

Sharded fleets, replication & failover
--------------------------------------
One daemon is a single writer AND a single point of failure. When one
isn't enough, shard the state plane — same `StateBackend` protocol, so
no service code changes (repro.state.sharding):

  # one daemon per shard; shard-1 also ships to a warm standby
  PYTHONPATH=src python -m repro.state.daemon --socket /tmp/s0.sock \\
      --shard-name shard-0
  PYTHONPATH=src python -m repro.state.daemon --socket /tmp/s1.sock \\
      --shard-name shard-1 --standby /tmp/s1-standby.sock \\
      --replicate-interval 0.5
  # the fleet client: namespaces route to their owning shard on a
  # stable hash ring; batch frames split per shard and fan out
  backend = ShardedBackend.from_addresses(
      ["/tmp/s0.sock", "/tmp/s1.sock"],
      standbys=[None, "/tmp/s1-standby.sock"])
  svc = AllocationService(catalog, history, backend=backend)

Each namespace lives on exactly ONE shard, so every per-namespace
guarantee (append order, CAS arbitration, the budget envelope's
never-over-grant) is untouched. If shard-1's primary dies, the client
retries its standby once and keeps going — acknowledged rows that
replication delivered are already there, and `publish_topology(backend)`
leaves a topology doc on every node so clients re-resolve the fleet
after failover. Watch per-shard heat and stitched traces fleet-wide:

  PYTHONPATH=src python -m repro.telemetry.trace_tool \\
      --daemon /tmp/s0.sock,/tmp/s1.sock --fleet

Scaling is measurable, not aspirational:
`benchmarks/state_backends.py --shards 4` records aggregate ops/s for
1/2/4-shard topologies in BENCH_shards.json.

Append-only logs grow forever under "later rows win", so the daemon
folds them into snapshot-plus-tail form: `--compact-after N`
auto-compacts a log namespace every N appends, `--compact-max-age S`
additionally drops rows older than S seconds, and
`--registry-max-records N` / `--registry-max-age S` evict the oldest
model-registry records after each flush, tombstoning them so sibling
services cannot resurrect the eviction. On demand:
`ProfileStore.compact()` / `DaemonBackend.compact(ns)` /
`DaemonBackend.evict_registry(...)` — this demo runs a compaction pass
after the two services finish and prints how far the shared profile log
shrank. With a FileBackend --root the shrunken log survives restarts.

The demo runs the daemon in-process (`CrispyDaemon(...).start()`) for a
self-contained script; everything else is identical.
"""
import os
import tempfile
import textwrap
import time
from concurrent.futures import ThreadPoolExecutor

import jax

from repro.allocator import AllocationService
from repro.configs import get_arch
from repro.configs.base import RunConfig
from repro.core.catalog import aws_like_catalog
from repro.core.simulator import (GiB, build_history, make_profile_fn,
                                  scout_like_jobs)
from repro.models.model import Model
from repro.profiling import ProfilingBudget
from repro.serve.engine import AllocationEndpoint, Request, ServeEngine
from repro.state import HAS_UNIX_SOCKETS, CrispyDaemon, DaemonBackend
from repro.telemetry import publish_traces, stitch_fleet_traces
from repro.telemetry.trace_tool import (collect_fleet, cross_process_trees,
                                        render_trace)

RUN = RunConfig(attn_impl="full", remat="nothing", compute_dtype="float32")


def demo_allocation(n_requests: int = 16, workers: int = 8):
    """Concurrent allocation traffic against the service endpoint: a mix of
    novel and repeated jobs; repeats skip profiling via the model registry."""
    jobs = scout_like_jobs()
    catalog = aws_like_catalog()
    history = build_history(jobs, catalog)
    with AllocationService(catalog, history) as svc:
        endpoint = AllocationEndpoint(svc)
        # half the corpus, twice over: the second visit of each signature
        # should be a registry (or LRU) hit, not a fresh profiling ladder
        mix = [jobs[i % (len(jobs) // 2)] for i in range(n_requests)]
        t0 = time.monotonic()

        def one(j):
            return endpoint.handle(job=j.name, profile_at=make_profile_fn(j),
                                   full_size=j.dataset_gib * GiB,
                                   anchor=j.dataset_gib * GiB * 0.01)

        with ThreadPoolExecutor(workers) as ex:
            answers = list(ex.map(one, mix))
        wall = time.monotonic() - t0
        by_source = {}
        for a in answers:
            by_source[a["source"]] = by_source.get(a["source"], 0) + 1
        s = svc.stats
        print(f"allocation: {len(answers)} requests in {wall:.2f}s "
              f"({len(answers) / wall:.0f} req/s); sources {by_source}; "
              f"profile calls {s.profile_calls}, registry hits "
              f"{s.registry_hits}, LRU hit-rate {s.profile_hit_rate:.0%}")
        a = answers[0]
        print(f"  e.g. {a['job']}: {a['requirement_gib']:.0f} GiB via "
              f"{a['candidate']} -> {a['config']} "
              f"(${a['usd_per_hour']:.2f}/h, source={a['source']})")
        # the telemetry plane (repro.telemetry): per-stage latency
        # histograms and cache-heat counters, one snapshot per service —
        # `endpoint.metrics()` is the same answer in wire form, and
        # `render_prometheus(svc.telemetry)` emits scrapeable text
        m = endpoint.metrics()["metrics"]
        req_h = m["histograms"].get("service.request.seconds", {})
        print(f"  telemetry: request p50 {req_h.get('p50', 0) * 1e3:.1f}ms "
              f"p99 {req_h.get('p99', 0) * 1e3:.1f}ms over "
              f"{req_h.get('count', 0)} requests; warm hits "
              f"{m['counters'].get('pipeline.warm_start.hits', 0):.0f}, "
              f"fresh profiles "
              f"{m['counters'].get('acquisition.fresh', 0):.0f}")


def demo_shared_state(n_jobs: int = 8):
    """Two allocation services sharing one crispy-daemon — service A over
    the unix socket, service B over loopback TCP (the multi-host
    transport): profile points, confident models and a single budget
    envelope are common property, so B answers from A's work without a
    single fresh profile run — and without charging the shared envelope a
    second time (stored points are free by construction in the pipeline's
    acquisition stage). Both services plan adaptively with the default
    infogain placement. A final compaction pass folds the shared profile
    log back down to one row per point."""
    if not HAS_UNIX_SOCKETS:
        print("shared state: skipped (no unix-domain sockets)")
        return
    jobs = scout_like_jobs()[:n_jobs]
    catalog = aws_like_catalog()
    history = build_history(jobs, catalog)
    tmp = tempfile.mkdtemp(prefix="crispy-demo-")
    sock = os.path.join(tmp, "crispy.sock")
    with CrispyDaemon(sock, root=os.path.join(tmp, "state"),
                      listen="127.0.0.1:0") as daemon:
        def serve_all(tag, address):
            with DaemonBackend(address) as backend:
                budget = ProfilingBudget(charge_s=600.0 * len(jobs),
                                         backend=backend)
                with AllocationService(catalog, history, backend=backend,
                                       adaptive=True, budget=budget) as svc:
                    for j in jobs:
                        full = j.dataset_gib * GiB
                        AllocationEndpoint(svc).handle(
                            job=j.name, profile_at=make_profile_fn(j),
                            full_size=full, anchor=full * 0.01)
                    s, snap = svc.stats, budget.snapshot()
                    print(f"  service {tag} [{svc.backend_kind} via "
                          f"{svc.backend_transport}:{svc.backend_address}]: "
                          f"{s.profile_calls} fresh profiles, "
                          f"{s.registry_hits} registry hits, "
                          f"{s.store_hits} store hits; shared envelope "
                          f"{snap['charged_s']:.0f}/{snap['charge_s']:.0f}s "
                          f"charged")
                    return s.profile_calls
        first = serve_all("A", sock)                 # co-located: unix
        second = serve_all("B", daemon.tcp_address)  # "remote": tcp
        print(f"shared state: service B re-profiled {second} points "
              f"after A spent {first} (daemon shares store+registry+"
              f"budget across transports)")
        with DaemonBackend(sock) as admin:
            stats = admin.compact("profiles")
            print(f"  compaction: profile log {stats['before']} -> "
                  f"{stats['after']} rows ({stats['dropped']} shadowed rows "
                  f"dropped; survives --root restarts)")
            # the daemon serves its own telemetry as a wire op — identical
            # over both transports (a real deployment publishes it with
            # `--telemetry-interval S` and reads the fleet with
            # `fleet_snapshot(backend)`)
            dm = admin.metrics()
        busiest = max(
            ((n.split(".")[2], h["count"])
             for n, h in dm["histograms"].items()
             if n.startswith("daemon.op.")), key=lambda kv: kv[1])
        print(f"  daemon telemetry: {dm['counters']['daemon.frames']:.0f} "
              f"frames, {dm['counters']['daemon.bytes_in'] / 1024:.0f} KiB "
              f"in; busiest op '{busiest[0]}' x{busiest[1]}")
        # distributed tracing: every handle() above ran inside an
        # `endpoint.request` span whose trace id rode each daemon frame,
        # so the daemon's `daemon.op.*` spans carry the caller's trace.
        # Publish this process's forest next to the daemon's own ring
        # and stitch — ONE tree per request, spanning both processes.
        # Against a live fleet the CLI does the same:
        #   python -m repro.telemetry.trace_tool --daemon /tmp/crispy.sock \
        #       --slowest 5 --expect-cross-process
        with DaemonBackend(sock) as tracer:
            publish_traces(tracer, "serve-demo")
            trees = stitch_fleet_traces(collect_fleet(tracer))
        crossed = cross_process_trees(trees)
        print(f"  tracing: {len(trees)} stitched traces, {len(crossed)} "
              f"cross-process; last one:")
        if crossed:
            print(textwrap.indent(render_trace(crossed[-1]), "  "))


def demo(arch: str, n_requests: int = 12, slots: int = 4):
    cfg = get_arch(arch).reduced()
    model = Model(cfg, RUN)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=slots, max_len=64)
    t0 = time.monotonic()
    for rid in range(n_requests):
        engine.submit(Request(rid, prompt=[rid % 17 + 1, 5, 9],
                              max_new_tokens=16,
                              temperature=0.0 if rid % 2 else 0.8))
    done = engine.run()
    wall = time.monotonic() - t0
    lat = [r.finished_at - r.submitted_at for r in done]
    toks = sum(len(r.out_tokens) for r in done)
    print(f"{arch}: served {len(done)} requests / {toks} tokens in "
          f"{wall:.2f}s ({toks / wall:.1f} tok/s aggregate, "
          f"{slots} slots); mean latency {sum(lat) / len(lat):.2f}s")
    sample = sorted(done, key=lambda r: r.rid)[0]
    print(f"  e.g. request 0: {sample.prompt} -> {sample.out_tokens}")


def main():
    demo_allocation()
    demo_shared_state()
    demo("deepseek-7b")
    demo("rwkv6-7b")


if __name__ == "__main__":
    main()
