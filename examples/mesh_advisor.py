"""Crispy for TPU slices: before launching an (arch x shape) job, profile
five reduced-depth compiles on this CPU host, extrapolate per-device HBM to
the full depth, and pick the cheapest feasible slice from the TPU catalog.

  PYTHONPATH=src python examples/mesh_advisor.py --arch deepseek-7b
"""
import argparse
import dataclasses

from repro.configs import SHAPES, get_arch
from repro.configs.base import RunConfig
from repro.core.hbm_planner import HBMPlanner
from repro.launch.mesh import compat_make_mesh

GiB = 1024 ** 3


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="width scale of the profiled job (1.0 = demo size)")
    args = ap.parse_args(argv)

    # demo-sized job so the advisor runs in seconds on CPU; the same code
    # path drives full configs under the dry-run device flag
    cfg = get_arch(args.arch).reduced(
        d_model=int(256 * args.scale), n_layers=32, vocab_size=2048,
        d_ff=int(512 * args.scale))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=512,
                                global_batch=8)
    run = RunConfig(attn_impl="blocked", remat="boundaries",
                    compute_dtype="bfloat16", microbatches=2)
    mesh = compat_make_mesh((1, 1), ("data", "model"))

    planner = HBMPlanner(leeway=0.05)
    rep = planner.plan(cfg, shape, mesh, run=run, anchor_layers=12)
    print(f"arch={cfg.name} layers ladder={rep.ladder}")
    print(f"per-device bytes at ladder: "
          f"{[f'{m / 2**20:.1f}MiB' for m in rep.per_dev_bytes]}")
    print(f"OLS: slope={rep.model.slope / 2**20:.2f} MiB/layer, "
          f"intercept={rep.model.intercept / 2**20:.1f} MiB, "
          f"R2={rep.model.r2:.5f} "
          f"({'PASS' if rep.model.confident else 'fallback'})")
    print(f"extrapolated to {cfg.n_layers} layers: "
          f"{rep.predicted_per_dev_gib:.3f} GiB/device "
          f"-> aggregate requirement {rep.requirement_gib:.2f} GiB")
    sel = rep.selection
    print(f"selected: {sel.config.name} "
          f"({sel.config.total_mem_gib:.0f} GiB HBM, "
          f"${sel.config.usd_per_hour:.2f}/h; "
          f"{sel.feasible_count} feasible configs"
          f"{'; fell back' if sel.fell_back else ''})")
    # ground truth check
    truth = planner.profile_memory(cfg, shape, mesh, run)
    err = abs(rep.predicted_per_dev_gib * GiB - truth) / truth
    print(f"ground-truth full compile: {truth / GiB:.3f} GiB/device "
          f"(extrapolation error {err:.2%})")


if __name__ == "__main__":
    main()
