"""Quickstart: train a reduced deepseek-7b-family model end-to-end on CPU
with the full production stack (data pipeline, AdamW + cosine schedule,
checkpointing, straggler watchdog), then generate from it.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax

from repro.configs import get_arch
from repro.configs.base import RunConfig
from repro.data.pipeline import ShardedLoader, SyntheticLMDataset
from repro.models.model import Model
from repro.optim import AdamWConfig
from repro.serve.engine import Request, ServeEngine
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import init_train_state, make_train_step


def main():
    cfg = get_arch("deepseek-7b").reduced(d_model=128, n_layers=4,
                                          vocab_size=512, d_ff=256)
    run = RunConfig(attn_impl="full", remat="nothing",
                    compute_dtype="float32")
    model = Model(cfg, run)
    acfg = AdamWConfig(lr=3e-3)
    state = init_train_state(model, jax.random.PRNGKey(0), acfg)
    step = jax.jit(make_train_step(model, acfg, None, total_steps=300))
    loader = ShardedLoader(SyntheticLMDataset(cfg.vocab_size), 16, 64)
    ckpt_dir = tempfile.mkdtemp(prefix="quickstart_ckpt_")
    state, report = train_loop(
        state, step, loader,
        LoopConfig(total_steps=300, ckpt_every=100, ckpt_dir=ckpt_dir,
                   log_every=25))
    print(f"\nloss: {report.losses[0]:.3f} -> {report.losses[-1]:.3f} "
          f"over {report.final_step} steps "
          f"(checkpoints in {ckpt_dir})")

    engine = ServeEngine(model, state.params, slots=4, max_len=64)
    for rid in range(4):
        engine.submit(Request(rid, prompt=[1 + rid, 7, 42],
                              max_new_tokens=12))
    for r in sorted(engine.run(), key=lambda r: r.rid):
        print(f"request {r.rid}: {r.prompt} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
