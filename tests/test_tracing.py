"""Distributed tracing (repro.telemetry + repro.state wire protocol):
trace identity and the one-anchor clock discipline, remote-parent
adoption, histogram exemplars through both Prometheus styles, logger
trace stamping, deterministic adaptive sampling, pipeline sampler
wiring, stitching semantics (orphans, cycles), legacy-frame byte
identity, and the acceptance path — ONE stitched cross-process trace
from a service talking to a live crispy-daemon over unix AND tcp, with
exemplars referencing that trace id."""
import json
import os
import socket
import subprocess
import sys
import tempfile
import time

import pytest

from repro.allocator import AllocationService
from repro.core.catalog import aws_like_catalog
from repro.core.simulator import (GiB, build_history, make_profile_fn,
                                  scout_like_jobs)
from repro.pipeline import AllocationPipeline, PipelineRequest
from repro.serve.engine import AllocationEndpoint
from repro.state import CrispyDaemon, DaemonBackend
from repro.telemetry import (AdaptiveSampler, FixedSampler, MetricsRegistry,
                             StructuredLogger, TraceRing,
                             current_trace_context, default_ring,
                             publish_traces, render_prometheus,
                             resolve_sampler, span, stitch_fleet_traces)
from repro.telemetry import trace_tool

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")
needs_unix_sockets = pytest.mark.skipif(
    not hasattr(socket, "AF_UNIX"),
    reason="unix-domain sockets unavailable")


def _daemon_socket() -> str:
    # AF_UNIX paths are length-limited (~108 bytes); use a short tempdir
    d = tempfile.mkdtemp(prefix="crispytr-")
    return os.path.join(d, "d.sock")


# -- identity + clock anchoring -----------------------------------------------


def test_trace_identity_and_single_clock_anchor(monkeypatch):
    """Every span carries 16-hex ids; descendants inherit the trace id
    AND its one (epoch, perf_counter) anchor, so a wall-clock step mid-
    trace cannot skew child started_at."""
    ring = TraceRing()
    real_time = time.time
    with span("root", ring=ring) as root:
        assert len(root.trace_id) == 16 and len(root.span_id) == 16
        # an NTP step lands mid-trace: time.time jumps a full day
        monkeypatch.setattr(time, "time", lambda: real_time() + 86400.0)
        with span("child", ring=ring) as child:
            assert child.trace_id == root.trace_id
            assert child.parent_id == root.span_id
            assert child.span_id != root.span_id
            assert child.anchor is root.anchor
            # derived from the monotonic offset, not the stepped clock
            assert 0.0 <= child.started_at - root.started_at < 60.0
    monkeypatch.undo()
    [rec] = ring.traces()
    d = rec.to_dict()
    assert d["trace_id"] == root.trace_id
    assert d["children"][0]["parent_id"] == root.span_id


def test_remote_parent_adoption_and_propagation_token():
    assert current_trace_context() is None
    ring = TraceRing()
    with span("caller", ring=ring) as caller:
        token = current_trace_context()
        assert token == {"trace_id": caller.trace_id,
                         "span_id": caller.span_id}
    # another "process" adopts the token: same trace, remote parent,
    # its OWN clock anchor (remote anchors live on a different host)
    with span("remote.op", ring=ring, parent=token) as remote:
        assert remote.trace_id == caller.trace_id
        assert remote.parent_id == caller.span_id
        assert remote.anchor is not caller.anchor
    # ...but a live LOCAL parent always wins over a stale remote token
    with span("outer", ring=ring) as outer:
        with span("inner", ring=ring, parent=token) as inner:
            assert inner.trace_id == outer.trace_id
            assert inner.parent_id == outer.span_id


# -- exemplars ----------------------------------------------------------------


def test_exemplars_capture_on_trace_only_and_latest_wins():
    reg = MetricsRegistry()
    h = reg.histogram("req.seconds")
    h.observe(0.002)                       # off-trace: no exemplar
    assert h.summary()["exemplars"] == []
    with span("t1") as s1:
        h.observe(0.002)
    with span("t2") as s2:
        h.observe(0.0021)                  # same bucket: latest wins
        h.observe(0.2)                     # a different bucket
    exs = h.summary()["exemplars"]
    by_le = {ex["le"]: ex for ex in exs}
    assert len(exs) == 2
    same_bucket = [ex for ex in exs if ex["value"] in (0.002, 0.0021)][0]
    assert same_bucket["trace_id"] == s2.trace_id != s1.trace_id
    assert by_le != {} and all(ex["trace_id"] == s2.trace_id for ex in exs)


def test_render_prometheus_styles_and_exemplar_suffix():
    reg = MetricsRegistry()
    h = reg.histogram("req.seconds")
    with span("t") as s:
        h.observe(0.002)
    h.observe(10.0)                        # off-trace +Inf bucket
    prom = render_prometheus(reg)
    assert f'# {{trace_id="{s.trace_id}"}} 0.002' in prom
    assert 'crispy_req_seconds_bucket{le="+Inf"} 2' in prom
    assert "crispy_req_seconds_sum" in prom
    flat = render_prometheus(reg, style="flat")
    assert "crispy_req_seconds_bucket_0" in flat
    assert "le=" not in flat and "# {" not in flat
    with pytest.raises(ValueError):
        render_prometheus(reg, style="openmetrics2")


def test_structured_logger_stamps_active_trace():
    import io
    buf = io.StringIO()
    log = StructuredLogger("unit", stream=buf)
    log.info("outside")
    with span("op") as s:
        log.info("inside")
        log.info("explicit", trace_id="override")
    recs = [json.loads(ln) for ln in buf.getvalue().splitlines()]
    assert "trace_id" not in recs[0]
    assert recs[1]["trace_id"] == s.trace_id
    assert recs[1]["span_id"] == s.span_id
    assert recs[2]["trace_id"] == "override"    # explicit field wins


# -- adaptive sampling --------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.now = 1000.0

    def __call__(self):
        return self.now


def test_adaptive_sampler_escalates_on_p99_drift_and_decays_back():
    reg = MetricsRegistry()
    clock = _FakeClock()
    s = AdaptiveSampler(reg, gate_p99_s=0.005, interval_s=2.0,
                        clock=clock)
    h = reg.histogram("pipeline.stage.warm_start.seconds")
    assert s.tick(force=True) == 7         # empty window: hold

    # p99 drifts past the gate: one escalation step per tick
    masks = []
    for _ in range(4):
        for _i in range(50):
            h.observe(0.05)
        clock.now += 3.0
        masks.append(s.tick())
    assert masks == [3, 1, 0, 0]           # 1-in-8 -> ... -> 1-in-1, floor
    assert reg.snapshot()["counters"]["sampling.escalations"] == 3
    assert reg.snapshot()["gauges"]["sampling.mask"] == 0

    # latency recovers below gate/2: decay one step per tick, back to 7
    masks = []
    for _ in range(4):
        for _i in range(50):
            h.observe(0.0001)
        clock.now += 3.0
        masks.append(s.tick())
    assert masks == [1, 3, 7, 7]
    assert reg.snapshot()["counters"]["sampling.decays"] == 3

    # interval gating: a tick inside the window is free and changes nothing
    for _i in range(50):
        h.observe(0.05)
    clock.now += 0.5
    assert s.tick() == 7


def test_adaptive_sampler_hysteresis_holds_rate_between_thresholds():
    reg = MetricsRegistry()
    clock = _FakeClock()
    s = AdaptiveSampler(reg, gate_p99_s=0.005, interval_s=1.0, clock=clock)
    h = reg.histogram("pipeline.stage.select.seconds")
    for _i in range(50):
        h.observe(0.05)
    assert s.tick(force=True) == 3         # escalated
    # p99 now sits BETWEEN recover (gate/2) and gate: no flapping
    for _ in range(3):
        for _i in range(50):
            h.observe(0.004)
        clock.now += 2.0
        assert s.tick() == 3


def test_sampler_specs_and_validation():
    assert resolve_sampler(None).mask == 7
    assert resolve_sampler("fixed").mask == 7
    assert resolve_sampler(0).mask == 0
    assert isinstance(resolve_sampler("adaptive", MetricsRegistry()),
                      AdaptiveSampler)
    fixed = FixedSampler(3)
    assert resolve_sampler(fixed) is fixed
    with pytest.raises(ValueError):
        FixedSampler(5)                    # not 2**k - 1
    with pytest.raises(ValueError):
        resolve_sampler("always")
    # disabled registry: tick() must not touch null instruments
    off = AdaptiveSampler(MetricsRegistry(enabled=False))
    assert off.tick(force=True) == 7


def _warm_pipeline(sampler):
    corpus = scout_like_jobs()
    job = next(j for j in corpus if j.mem_profile == "linear")
    catalog = aws_like_catalog()
    history = build_history(corpus, catalog)
    from repro.allocator.registry import ModelRegistry
    pipe = AllocationPipeline(catalog, history, registry=ModelRegistry(),
                              telemetry=MetricsRegistry(), sampler=sampler)
    req = PipelineRequest(job.name, make_profile_fn(job),
                          job.dataset_gib * GiB)
    pipe.run(req)                          # register a confident model
    assert pipe.warm_start(job.name) is not None
    return pipe, req


def test_pipeline_honors_sampler_mask():
    """mask 0 observes every warm-path stage wall; the default 1-in-8
    observes ~1/8 of them — the sampler really gates the histograms."""
    pipe_all, req_all = _warm_pipeline(sampler=0)
    base = pipe_all.telemetry.histogram(
        "pipeline.stage.warm_start.seconds").count
    for _ in range(32):
        pipe_all.run(req_all)
    h = pipe_all.telemetry.histogram("pipeline.stage.warm_start.seconds")
    assert h.count - base == 32

    pipe_8, req_8 = _warm_pipeline(sampler=None)
    base = pipe_8.telemetry.histogram(
        "pipeline.stage.warm_start.seconds").count
    for _ in range(32):
        pipe_8.run(req_8)
    h = pipe_8.telemetry.histogram("pipeline.stage.warm_start.seconds")
    assert 0 < h.count - base <= 8


# -- stitching semantics ------------------------------------------------------


def _span_dict(name, trace_id, span_id, parent_id=None, started=0.0,
               children=()):
    d = {"name": name, "trace_id": trace_id, "span_id": span_id,
         "started_at": started, "wall_s": 0.001, "thread": "t",
         "children": list(children)}
    if parent_id is not None:
        d["parent_id"] = parent_id
    return d


def test_stitch_grafts_remote_children_and_keeps_orphans_top_level():
    local = _span_dict("endpoint.request", "t1", "aaa", started=1.0)
    remote = _span_dict("daemon.op.append", "t1", "bbb", parent_id="aaa",
                        started=1.5)
    orphan = _span_dict("daemon.op.load", "t2", "ccc", parent_id="gone",
                        started=2.0)
    out = stitch_fleet_traces({"svc": [local],
                               "crispy-daemon": [remote, orphan]})
    assert [t["name"] for t in out] == ["endpoint.request",
                                       "daemon.op.load"]
    tree = out[0]
    assert tree["source"] == "svc"
    assert [c["name"] for c in tree["children"]] == ["daemon.op.append"]
    assert tree["children"][0]["source"] == "crispy-daemon"
    assert out[1]["source"] == "crispy-daemon"   # orphan is still a trace


def test_stitch_survives_parent_cycles():
    """Two roots naming each other as parent (clock skew / id reuse
    pathology) must not recurse forever or drop spans."""
    a = _span_dict("a", "t", "aaa", parent_id="bbb", started=1.0)
    b = _span_dict("b", "t", "bbb", parent_id="aaa", started=2.0)
    out = stitch_fleet_traces({"p1": [a], "p2": [b]})
    names = set()
    stack = list(out)
    while stack:
        s = stack.pop()
        names.add(s["name"])
        stack.extend(s.get("children", ()))
    assert names == {"a", "b"}
    assert len(out) == 1                   # one grafted, the cycle broken
    json.dumps(out)                        # still a tree, not a loop


# -- wire protocol: legacy frames stay byte-identical -------------------------


@needs_unix_sockets
def test_untraced_frame_bytes_identical_and_opens_no_daemon_span():
    sock_path = _daemon_socket()
    with CrispyDaemon(sock_path) as daemon:
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock_path)
        try:
            s.sendall(b'{"op": "ping"}\n')
            f = s.makefile("rb")
            line = f.readline()
        finally:
            s.close()
        # the exact pre-tracing response, byte for byte
        assert line == b'{"ok": true, "kind": "memory"}\n'
        assert len(daemon.trace_ring) == 0

        # the SAME op with a trace token: same payload fields, plus an
        # adopted daemon-side span in the daemon's ring
        be = DaemonBackend(sock_path)
        try:
            with span("caller"):
                assert be.ping()
        finally:
            be.close()
        [rec] = daemon.trace_ring.traces()
        assert rec.name == "daemon.op.ping"
        assert rec.parent_id is not None


# -- acceptance: one stitched cross-process trace over a live daemon ----------


def _drive_traced_service(backend, jobs, catalog, history):
    """One traced allocation request through the full service stack over
    `backend`; returns (wire answer, service metrics snapshot)."""
    with AllocationService(catalog, history, backend=backend) as svc:
        endpoint = AllocationEndpoint(svc)
        wire = None
        for j in jobs:
            full = j.dataset_gib * GiB
            wire = endpoint.handle(job=j.name,
                                   profile_at=make_profile_fn(j),
                                   full_size=full, anchor=full * 0.01)
        return wire, svc.telemetry.snapshot()


def _assert_one_stitched_trace(fleet, wire, local_snap, daemon_metrics):
    trees = stitch_fleet_traces(fleet)
    mine = [t for t in trees if t["trace_id"] == wire["trace_id"]]
    assert len(mine) == 1, (wire["trace_id"],
                            [t["trace_id"] for t in trees])
    sources = {s["source"] for _d, s in trace_tool._walk(mine[0])}
    assert len(sources) >= 2, sources      # spans from BOTH processes
    names = {s["name"] for _d, s in trace_tool._walk(mine[0])}
    assert "endpoint.request" in names
    assert any(n.startswith("daemon.op.") for n in names)
    # >= 1 histogram exemplar (either side) references this trace id
    ex_traces = {ex["trace_id"]
                 for snap in (local_snap, daemon_metrics)
                 for h in snap["histograms"].values()
                 for ex in h.get("exemplars", [])}
    assert wire["trace_id"] in ex_traces


@needs_unix_sockets
def test_cross_process_stitch_over_unix_daemon_subprocess():
    """THE acceptance case: a real daemon process, a traced service in
    this process, ONE stitched tree under the wire's trace id with spans
    from both processes and an exemplar pointing at it."""
    sock_path = _daemon_socket()
    env = {**os.environ,
           "PYTHONPATH": SRC + os.pathsep + os.environ.get("PYTHONPATH", "")}
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.state.daemon", "--socket", sock_path],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    try:
        client = DaemonBackend(sock_path, timeout_s=2.0)
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            try:
                if os.path.exists(sock_path) and client.ping():
                    break
            except Exception:
                pass
            assert proc.poll() is None, proc.communicate()[0]
            time.sleep(0.05)
        else:
            pytest.fail("daemon never became ready")

        jobs = scout_like_jobs()[:2]
        catalog = aws_like_catalog()
        history = build_history(jobs, catalog)
        wire, local_snap = _drive_traced_service(
            DaemonBackend(sock_path), jobs, catalog, history)
        assert wire["trace_id"]

        fleet = {"svc": [s.to_dict() for s in default_ring().traces()],
                 "crispy-daemon": client.traces()}
        _assert_one_stitched_trace(fleet, wire, local_snap,
                                   client.metrics())
        client.close()
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()


@needs_unix_sockets
def test_cross_process_stitch_over_tcp():
    """Same acceptance shape over the multi-host transport: the trace
    token rides tcp frames exactly like unix ones."""
    sock_path = _daemon_socket()
    with CrispyDaemon(sock_path, listen="127.0.0.1:0") as daemon:
        jobs = scout_like_jobs()[2:4]
        catalog = aws_like_catalog()
        history = build_history(jobs, catalog)
        wire, local_snap = _drive_traced_service(
            DaemonBackend(daemon.tcp_address), jobs, catalog, history)
        assert wire["trace_id"]
        be = DaemonBackend(daemon.tcp_address)
        try:
            fleet = {"svc": [s.to_dict() for s in default_ring().traces()],
                     "crispy-daemon": be.traces()}
            _assert_one_stitched_trace(fleet, wire, local_snap,
                                       be.metrics())
        finally:
            be.close()


@needs_unix_sockets
def test_trace_tool_cli_stitches_and_gates_on_cross_process(capsys):
    """`python -m repro.telemetry.trace_tool` in-process: prints stitched
    trees, honors --trace/--json, and --expect-cross-process is a real
    gate (1 on an untraced fleet, 0 once traces cross)."""
    sock_path = _daemon_socket()
    with CrispyDaemon(sock_path) as daemon:
        assert trace_tool.main(["--daemon", sock_path,
                                "--expect-cross-process"]) == 1
        capsys.readouterr()

        jobs = scout_like_jobs()[4:6]
        catalog = aws_like_catalog()
        history = build_history(jobs, catalog)
        backend = DaemonBackend(sock_path)
        with AllocationService(catalog, history, backend=backend) as svc:
            endpoint = AllocationEndpoint(svc)
            for j in jobs:
                full = j.dataset_gib * GiB
                wire = endpoint.handle(job=j.name,
                                       profile_at=make_profile_fn(j),
                                       full_size=full, anchor=full * 0.01)
            # publish this process's forest (endpoint roots AND the
            # worker-thread service.* roots both live in the default
            # ring — the daemon spans' parents are in the latter)
            publish_traces(backend, "svc-under-test")

        rc = trace_tool.main(["--daemon", sock_path, "--slowest", "3",
                              "--fleet", "--expect-cross-process"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "cross-process" in out and "slowest spans" in out
        assert wire["trace_id"] in out
        assert "daemon.op." in out

        rc = trace_tool.main(["--daemon", sock_path, "--json",
                              "--trace", wire["trace_id"]])
        doc = json.loads(capsys.readouterr().out)
        assert rc == 0
        assert [t["trace_id"] for t in doc["traces"]] == [wire["trace_id"]]
        assert doc["cross_process_traces"] == 1
