"""Unified allocation pipeline: placement strategies (ladder vs infogain),
the staged decision path's stage contracts, the one-acquisition-rule
budget accounting (cached/stored points are never charged — including the
shared-envelope regression with two services over one daemon), and the
service-purity contract (service.py carries no ladder/fit/selection logic
of its own)."""
import math
import os
import socket
import tempfile
import zlib

import numpy as np
import pytest

from repro.allocator import AllocationRequest, AllocationService
from repro.allocator.model_zoo import fit_zoo, zoo_fitter
from repro.core.catalog import aws_like_catalog
from repro.core.crispy import CrispyAllocator
from repro.core.memory_model import fit_memory_model
from repro.core.profiler import ProfileResult
from repro.core.sampling import ladder_from_anchor
from repro.core.simulator import (GiB, build_history, make_profile_fn,
                                  scout_like_jobs)
from repro.pipeline import (AllocationPipeline, InfoGainPlacer,
                            LadderPlacer, MemoryPointCache,
                            PipelineRequest, PointSource, drive_placement,
                            make_placer)
from repro.profiling import ProfileStore, ProfilingBudget
from repro.state import CrispyDaemon, DaemonBackend

FULL = 1e11
LADDER = ladder_from_anchor(FULL * 0.01).sizes

needs_unix_sockets = pytest.mark.skipif(
    not hasattr(socket, "AF_UNIX"),
    reason="unix-domain sockets unavailable")


def _daemon_socket() -> str:
    # AF_UNIX paths are length-limited; use a short tempdir
    return os.path.join(tempfile.mkdtemp(prefix="crispyd-"), "d.sock")


def _deterministic_mem(name, mem_fn, noise):
    def mem(s):
        rng = np.random.default_rng(
            zlib.crc32(f"{name}|{round(s)}".encode()))
        return mem_fn(s) * (1.0 + rng.normal(0.0, noise))
    return mem


def _acquire_fn(mem, wall=10.0, calls=None):
    def acquire(s):
        if calls is not None:
            calls.append(s)
        return ProfileResult(s, mem(s), 0.0, wall), True
    return acquire


@pytest.fixture(scope="module")
def corpus():
    jobs = scout_like_jobs()
    catalog = aws_like_catalog()
    return jobs, catalog, build_history(jobs, catalog)


def _req(job, **kw):
    full = job.dataset_gib * GiB
    return AllocationRequest(job.name, make_profile_fn(job), full,
                             anchor=full * 0.01, **kw)


# -- placement strategies -----------------------------------------------------


def test_make_placer_resolves_names_and_instances():
    assert make_placer("infogain").name == "infogain"
    assert make_placer("ladder").name == "ladder"
    assert make_placer(None).name == "infogain"          # the default
    custom = LadderPlacer(max_extra_points=0)
    assert make_placer(custom) is custom
    with pytest.raises(ValueError):
        make_placer("bogus")
    with pytest.raises(TypeError):
        make_placer(object())


def test_infogain_seeds_cheap_then_jumps_to_separating_size():
    """Seeds are the two cheapest points (no fit to rank by yet, and the
    PR-2 cost profile must survive for single-model fitters); the first
    gain-scored choice then jumps to whichever size best separates the
    candidates — the far end of the calibrated range — and placement
    never leaves the ladder's bounds."""
    mem = _deterministic_mem("span", lambda s: 2.0 * s, 0.0)
    out = drive_placement(InfoGainPlacer(), LADDER, FULL,
                          _acquire_fn(mem), lambda a, b: fit_zoo(a, b))
    assert out.sizes[:2] == sorted(LADDER)[:2]
    assert out.sizes[2] == max(LADDER)       # the separating jump
    assert all(min(LADDER) <= s <= max(LADDER) for s in out.sizes)


def test_infogain_matches_ladder_minimum_on_clean_linear():
    """The easy case must not regress: 3 points (the LOOCV minimum),
    confident, accurate."""
    mem = _deterministic_mem("lin", lambda s: 0.9 * s + 1.6e9, 0.002)
    out = drive_placement(InfoGainPlacer(), LADDER, FULL,
                          _acquire_fn(mem), lambda a, b: fit_zoo(a, b))
    assert out.early_stop and len(out.sizes) == 3
    assert out.fit.confident
    truth = 0.9 * FULL + 1.6e9
    assert abs(out.fit.predict(FULL) - truth) / truth < 0.02


def test_infogain_beats_ladder_prefix_on_curved_jobs():
    """The tentpole claim (benchmarks/point_placement.py measures it; this
    pins it): on power-law and log-linear shapes infogain profiles
    STRICTLY fewer points at equal-or-better requirement error."""
    # the exact jobs (and therefore noise draws) of
    # benchmarks/point_placement.py's curved, gate-passing set
    cases = [("powerlaw/clean", lambda s: 3.0e-4 * s ** 1.35, 0.002),
             ("powerlaw/noisy", lambda s: 3.0e-4 * s ** 1.35, 0.01),
             ("loglinear/clean", lambda s: 4e9 * math.log(s) - 60e9, 0.002)]
    for name, mem_fn, noise in cases:
        mem = _deterministic_mem(name, mem_fn, noise)
        truth = mem_fn(FULL)
        outs = {}
        for placer in (LadderPlacer(), InfoGainPlacer()):
            outs[placer.name] = drive_placement(
                placer, LADDER, FULL, _acquire_fn(mem),
                lambda a, b: fit_zoo(a, b))
        lad, inf = outs["ladder"], outs["infogain"]
        assert len(inf.sizes) < len(lad.sizes), name
        assert inf.fit.confident, name
        inf_err = abs(inf.fit.requirement(FULL) - truth) / truth
        lad_err = abs(lad.fit.requirement(FULL) - truth) / truth
        assert inf_err <= lad_err + 0.02, name


def test_infogain_stops_early_on_hopeless_noise():
    """Gate-failing data: expected gain collapses and infogain reaches the
    fallback in fewer points than ladder-prefix + escalation."""
    mem = _deterministic_mem("noisy", lambda s: 1.1 * s, 0.09)
    inf = drive_placement(InfoGainPlacer(), LADDER, FULL,
                          _acquire_fn(mem), lambda a, b: fit_zoo(a, b))
    lad = drive_placement(LadderPlacer(), LADDER, FULL,
                          _acquire_fn(mem), lambda a, b: fit_zoo(a, b))
    assert not inf.fit.confident and not lad.fit.confident
    assert inf.fit.requirement(FULL) == 0.0      # BFA fallback downstream
    assert len(inf.sizes) < len(lad.sizes)


def test_ladder_placer_reproduces_prefix_and_escalation():
    """placement="ladder" keeps PR-2 semantics: clean jobs stop on the
    smallest-first prefix; noisy jobs escalate into gap midpoints and
    never leave the calibrated range."""
    clean = _deterministic_mem("c", lambda s: 2.0 * s, 0.0)
    out = drive_placement(LadderPlacer(), LADDER, FULL,
                          _acquire_fn(clean), lambda a, b: fit_zoo(a, b))
    assert out.early_stop
    assert out.sizes == sorted(LADDER)[:len(out.sizes)]

    noisy = _deterministic_mem("n", lambda s: s, 0.09)
    out2 = drive_placement(LadderPlacer(), LADDER, FULL,
                           _acquire_fn(noisy), lambda a, b: fit_zoo(a, b))
    assert out2.escalated and len(out2.sizes) > len(LADDER)
    assert max(out2.sizes) <= max(LADDER)


def test_placement_budget_denial_returns_partial():
    budget = ProfilingBudget(max_points=2)
    mem = _deterministic_mem("cut", lambda s: 2.0 * s, 0.0)

    def acquire(s):
        if not budget.try_spend():
            return None
        r = ProfileResult(s, mem(s), 0.0, 10.0)
        budget.charge(r.wall_s)
        return r, True

    out = drive_placement(InfoGainPlacer(), LADDER, FULL, acquire,
                          lambda a, b: fit_zoo(a, b))
    assert out.budget_exhausted and len(out.sizes) == 2
    assert not out.fit.confident             # 2 points never pass LOOCV


# -- pipeline stage contracts -------------------------------------------------


def test_pipeline_run_stages_end_to_end(corpus):
    jobs, catalog, history = corpus
    km = jobs[2]
    pipeline = AllocationPipeline(catalog, history, adaptive=True)
    full = km.dataset_gib * GiB
    trace = pipeline.run(PipelineRequest(km.name, make_profile_fn(km),
                                         full, anchor=full * 0.01))
    assert trace.plan.source == "zoo"
    assert trace.plan.placement == "infogain"
    assert trace.requirement_gib > 0
    assert trace.selection.config.usable_mem_gib(2.0) > 0
    assert trace.plan.profiled == trace.plan.total_points < 5


def test_pipeline_warm_start_skips_profiling(corpus):
    from repro.allocator import ModelRegistry
    jobs, catalog, history = corpus
    km = jobs[2]
    reg = ModelRegistry()
    pipeline = AllocationPipeline(catalog, history, registry=reg)
    full = km.dataset_gib * GiB
    preq = PipelineRequest(km.name, make_profile_fn(km), full,
                           anchor=full * 0.01)
    first = pipeline.run(preq)
    assert first.plan.source == "zoo" and first.plan.registered
    again = pipeline.run(preq)
    assert again.plan.source == "registry"
    assert again.plan.profiled == 0 and again.plan.total_points == 0
    # byte-identical answers from the model either way
    assert again.requirement_gib == first.requirement_gib
    assert again.selection.config.name == first.selection.config.name


def test_point_source_cached_points_skip_budget():
    """The one acquisition rule: cache/store hits are served before the
    budget gate and never charge the envelope."""
    budget = ProfilingBudget(max_points=1, charge_s=100.0)
    cache = MemoryPointCache()
    src = PointSource("sig", lambda s: ProfileResult(s, 2.0 * s, 0.0, 10.0),
                      budget=budget, cache=cache)
    r1 = src.acquire(1e9)
    assert r1 is not None and r1[1] is True
    assert budget.points_spent == 1 and budget.charged_s == 10.0
    # repeat: served from the cache with the budget fully exhausted
    r2 = src.acquire(1e9)
    assert r2 is not None and r2[1] is False
    assert budget.points_spent == 1 and budget.charged_s == 10.0
    assert not src.stats.denied
    # a genuinely new point is denied
    assert src.acquire(2e9) is None
    assert src.stats.denied


@needs_unix_sockets
def test_shared_daemon_budget_not_charged_for_stored_points(corpus):
    """REGRESSION (budget accounting for cached points): two services
    share one daemon — profile store, registry AND budget envelope. The
    second service answers a gate-failing job (no registry warm-start)
    entirely from the first's stored ladder: the shared envelope must not
    lose a single charged second or point for it."""
    jobs, catalog, history = corpus
    noisy = jobs[6]                          # logregression: never confident
    sock = _daemon_socket()
    with CrispyDaemon(sock):
        be = DaemonBackend(sock)
        budget_a = ProfilingBudget(charge_s=10_000.0, backend=be)
        with AllocationService(catalog, history, backend=be,
                               budget=budget_a) as a:
            ra = a.allocate(_req(noisy))
            assert ra.profiled == 5
        charged = budget_a.charged_s
        points = budget_a.points_spent
        assert charged > 0 and points == 5

        be_b = DaemonBackend(sock)
        budget_b = ProfilingBudget(charge_s=10_000.0, backend=be_b)
        with AllocationService(catalog, history, backend=be_b,
                               budget=budget_b) as b:
            rb = b.allocate(_req(noisy))
            assert rb.profiled == 0
            assert rb.cache_hits == 5        # all five from the store
        assert budget_b.charged_s == charged     # not a second charged
        assert budget_b.points_spent == points   # nor a reserved point


@needs_unix_sockets
def test_one_shot_path_with_stale_store_view_charges_nothing(corpus):
    """The bug the unified acquisition stage fixes: a CrispyAllocator
    holding a ProfileStore handle opened BEFORE a sibling profiled (stale
    local index) used to re-measure the sibling's points and charge the
    shared envelope twice. Acquisition now refreshes the store first."""
    jobs, catalog, history = corpus
    km = jobs[2]                         # clean linear: prefix stops at 3
    full = km.dataset_gib * GiB
    sock = _daemon_socket()
    with CrispyDaemon(sock):
        be = DaemonBackend(sock)
        stale = ProfileStore(backend=DaemonBackend(sock))    # empty view
        with AllocationService(catalog, history, backend=be,
                               budget=ProfilingBudget(charge_s=10_000.0,
                                                      backend=be)) as a:
            a.allocate(_req(km))         # profiles + stores the full ladder
        shared = ProfilingBudget(charge_s=10_000.0,
                                 backend=DaemonBackend(sock))
        charged = shared.charged_s
        points = shared.points_spent
        assert charged > 0

        rep = CrispyAllocator(catalog, history, overhead_per_node_gib=2.0,
                              fitter=zoo_fitter()).allocate(
            km.name, make_profile_fn(km), full, anchor=full * 0.01,
            store=stale, budget=shared, placement="ladder")
        assert rep.points_profiled == 3          # prefix from the store...
        assert rep.model.confident
        assert shared.charged_s == charged       # ...without any new charge
        assert shared.points_spent == points
        assert not rep.budget_exhausted


def test_point_source_refunds_reservation_when_profiler_raises():
    """A profile run that crashes must hand its budget reservation back:
    with a shared max_points envelope, leaked reservations from transient
    failures would drain the budget with zero points measured."""
    budget = ProfilingBudget(max_points=2)

    def boom(_s):
        raise RuntimeError("profiler crashed")

    src = PointSource("sig", boom, budget=budget)
    with pytest.raises(RuntimeError, match="profiler crashed"):
        src.acquire(1e9)
    assert budget.points_spent == 0          # reservation refunded
    ok = PointSource("sig", lambda s: ProfileResult(s, s, 0.0, 1.0),
                     budget=budget)
    assert ok.acquire(1e9) is not None       # envelope still usable
    assert ok.acquire(2e9) is not None
    assert budget.points_spent == 2


def test_infogain_with_single_model_fitter_keeps_escalation():
    """CrispyAllocator's default config (paper's OLS fitter + infogain):
    a non-zoo fit has no candidate set to rank sizes by, so placement
    must fall back to FULL ladder semantics — including gap-midpoint
    escalation for an unconfident end-of-ladder fit, exactly as PR-2's
    scheduler behaved (escalate on inf disagreement)."""
    # seed chosen so the 3-point linear fit misses the paper's R2 gate
    # (the single-model gate has no LOOCV backstop at 3 points)
    mem = _deterministic_mem("d", lambda s: s, 0.09)    # gate-failing
    out = drive_placement(InfoGainPlacer(), LADDER, FULL,
                          _acquire_fn(mem),
                          lambda a, b: fit_memory_model(a, b))
    assert out.escalated
    assert len(out.sizes) > len(LADDER)
    assert max(out.sizes) <= max(LADDER)
    assert not out.fit.confident


def test_plan_cache_is_tag_aware(corpus):
    """Tags can steer the classifier, so a cached negative plan computed
    under one tag palette must not answer a request carrying another."""
    jobs, catalog, history = corpus
    logreg = jobs[6]
    with AllocationService(catalog, history) as svc:
        first = svc.allocate(_req(logreg, tags=("format:csv",)))
        assert first.source in ("classifier", "baseline")
        hits0 = svc.stats.plan_cache_hits
        # same palette: served from the plan cache
        svc.allocate(_req(logreg, tags=("format:csv",)))
        assert svc.stats.plan_cache_hits == hits0 + 1
        # different palette: re-planned, not cache-served
        fits0 = svc.stats.zoo_fits
        svc.allocate(_req(logreg, tags=("format:parquet",)))
        assert svc.stats.plan_cache_hits == hits0 + 1
        assert svc.stats.zoo_fits == fits0 + 1


def test_plan_cache_is_settings_aware(corpus):
    """A negative plan computed under adaptive acquisition must not
    answer an explicit adaptive=False request for the same signature —
    the fixed 5-point ladder could pass the gate where the adaptive
    partial ladder did not (and vice versa)."""
    jobs, catalog, history = corpus
    linreg = jobs[4]        # noisy: unconfident at 3 adaptive points
    with AllocationService(catalog, history, adaptive=True) as svc:
        first = svc.allocate(_req(linreg))
        assert first.source in ("classifier", "baseline")
        assert first.placement == "infogain"
        assert first.profiled + first.cache_hits < 5     # stopped early
        fixed = svc.allocate(_req(linreg, adaptive=False))
        # re-planned under fixed settings: the full ladder materialized
        # (partly from the LRU), no cached adaptive plan served
        assert fixed.placement is None
        assert fixed.profiled + fixed.cache_hits == 5
        assert svc.stats.plan_cache_hits == 0


# -- service purity contract --------------------------------------------------


def test_service_contains_no_pipeline_logic():
    """service.py is batching + wire ONLY: the acquisition/fit/selection
    vocabulary must not appear — the unified pipeline is the single code
    path (the parity test in test_allocator.py checks the semantics; this
    pins the structure)."""
    import repro.allocator.service as service_mod
    src = open(service_mod.__file__).read()
    forbidden = ["fit_zoo", "fit_memory_model", "ladder_from_anchor",
                 "select_crispy", "select_like", "AdaptiveLadderScheduler",
                 "gap_midpoint", "calibrate_anchor", "model_zoo",
                 "requirement("]
    hits = [word for word in forbidden if word in src]
    assert not hits, f"service.py re-grew pipeline logic: {hits}"


def test_crispy_wrapper_contains_no_pipeline_logic():
    """core/crispy.py is a thin convenience wrapper over the pipeline."""
    import repro.core.crispy as crispy_mod
    src = open(crispy_mod.__file__).read()
    for word in ("fit_zoo", "ladder_from_anchor", "select_crispy",
                 "AdaptiveLadderScheduler", "try_spend", "store.get("):
        assert word not in src, word
