"""End-to-end behaviour tests: training convergence, checkpoint/restart,
preemption, gradient compression, straggler detection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import RunConfig
from repro.data.pipeline import ShardedLoader, SyntheticLMDataset
from repro.models.model import Model
from repro.optim import AdamWConfig
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import init_train_state, make_train_step

RUN = RunConfig(attn_impl="full", remat="nothing", compute_dtype="float32")


def _setup(run=RUN, lr=1e-2, steps=30, arch="deepseek-7b"):
    cfg = get_arch(arch).reduced()
    model = Model(cfg, run)
    acfg = AdamWConfig(lr=lr, moment_dtype=run.moment_dtype)
    state = init_train_state(model, jax.random.PRNGKey(0), acfg)
    step = jax.jit(make_train_step(model, acfg, None, total_steps=steps))
    ds = SyntheticLMDataset(cfg.vocab_size, 0)
    loader = ShardedLoader(ds, 8, 32)
    return cfg, model, state, step, loader


def test_training_reduces_loss():
    _, _, state, step, loader = _setup()
    state, report = train_loop(
        state, step, loader, LoopConfig(total_steps=30, log_every=0),
        log=lambda s: None)
    first = np.mean(report.losses[:3])
    last = np.mean(report.losses[-3:])
    assert last < first * 0.8, (first, last)


def test_microbatched_step_matches_single():
    """Gradient accumulation is exact: 4 microbatches == 1 big batch."""
    cfg = get_arch("deepseek-7b").reduced()
    model1 = Model(cfg, RUN)
    model4 = Model(cfg, RUN.with_(microbatches=4))
    acfg = AdamWConfig(lr=1e-3)
    s1 = init_train_state(model1, jax.random.PRNGKey(0), acfg)
    s4 = init_train_state(model4, jax.random.PRNGKey(0), acfg)
    f1 = jax.jit(make_train_step(model1, acfg, None))
    f4 = jax.jit(make_train_step(model4, acfg, None))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    s1, m1 = f1(s1, batch)
    s4, m4 = f4(s4, batch)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)


def test_checkpoint_resume_is_exact(tmp_path):
    """Training 30 steps straight == training 15, restarting, training 15."""
    ck = str(tmp_path / "ck")
    _, _, state, step, loader = _setup()
    state_a, _ = train_loop(
        state, step, loader,
        LoopConfig(total_steps=30, log_every=0, ckpt_dir=None),
        log=lambda s: None)

    cfg = get_arch("deepseek-7b").reduced()
    ds = SyntheticLMDataset(cfg.vocab_size, 0)
    # run 1: 15 steps then "die"
    _, _, state2, step2, _ = _setup()
    loader1 = ShardedLoader(ds, 8, 32)
    train_loop(state2, step2, loader1,
               LoopConfig(total_steps=15, log_every=0, ckpt_dir=ck,
                          ckpt_every=100),
               log=lambda s: None)
    # run 2: resumes from run 1's final checkpoint (step 15), continues to 30
    _, _, state3, step3, _ = _setup()
    loader2 = ShardedLoader(ds, 8, 32)
    s_res, report = train_loop(
        state3, step3, loader2,
        LoopConfig(total_steps=30, log_every=0, ckpt_dir=ck, ckpt_every=100),
        log=lambda s: None)
    assert report.final_step == 30
    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(s_res.params)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_grad_compression_still_converges():
    run = RUN.with_(grad_compression=True)
    cfg, model, state, step, loader = _setup(run=run)
    assert state.residual is not None
    state, report = train_loop(
        state, step, loader, LoopConfig(total_steps=30, log_every=0),
        log=lambda s: None)
    assert np.mean(report.losses[-3:]) < np.mean(report.losses[:3]) * 0.85


def test_bf16_moments_still_converge():
    run = RUN.with_(moment_dtype="bfloat16")
    cfg, model, state, step, loader = _setup(run=run)
    assert jax.tree.leaves(state.opt.m)[0].dtype == jnp.bfloat16
    state, report = train_loop(
        state, step, loader, LoopConfig(total_steps=30, log_every=0),
        log=lambda s: None)
    assert np.mean(report.losses[-3:]) < np.mean(report.losses[:3]) * 0.85


def test_nan_guard_aborts():
    _, _, state, step, loader = _setup()

    def bad_step(state, batch):
        state, m = step(state, batch)
        return state, {"loss": jnp.nan}

    with pytest.raises(FloatingPointError):
        train_loop(state, bad_step, loader,
                   LoopConfig(total_steps=5, log_every=0), log=lambda s: None)


def test_straggler_detection():
    import time
    cfg, _, state, step, loader = _setup()
    # warm up jit so compile time doesn't dominate the EWMA
    import jax.random as jr
    toks = jr.randint(jr.PRNGKey(9), (8, 32), 0, cfg.vocab_size)
    step(state, {"tokens": toks, "labels": toks})
    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        out = step(state, batch)
        jax.block_until_ready(out[1]["loss"])
        if calls["n"] == 10:
            time.sleep(1.5)
        return out

    msgs = []
    state, report = train_loop(
        state, slow_step, loader,
        LoopConfig(total_steps=12, log_every=0, straggler_factor=3.0),
        log=msgs.append)
    assert report.stragglers, msgs
