"""HBM planner (Crispy-for-meshes): ladder profiling, linear gate,
extrapolation accuracy against a ground-truth full compile."""
import pytest

from repro.configs import SHAPES, get_arch
from repro.configs.base import RunConfig
from repro.core.hbm_planner import HBMPlanner, _reduced_depth
from repro.core.catalog import tpu_catalog
# AxisType only exists on newer jax; the compat helper feature-detects it so
# this module collects (and the planner tests run) on older versions too.
from repro.launch.mesh import compat_make_mesh

GiB = 1024 ** 3


@pytest.fixture(scope="module")
def mesh1():
    return compat_make_mesh((1, 1), ("data", "model"))


def _small_shape():
    import dataclasses
    return dataclasses.replace(SHAPES["train_4k"], seq_len=128,
                               global_batch=4)


def test_reduced_depth_respects_family_structure():
    z = get_arch("zamba2-7b")
    r = _reduced_depth(z, 13)
    assert r.n_layers % z.hybrid.period == 0
    v = get_arch("llama-3.2-vision-90b")
    r = _reduced_depth(v, 17)
    assert r.n_layers % v.cross_attn.period == 0


def test_planner_memory_linear_in_depth(mesh1):
    """Per-device compiled memory is linear in layer count — the premise
    that makes the paper's OLS+R2 gate transfer — and the extrapolation to
    a deeper model lands within 10% of the ground-truth compile."""
    cfg = get_arch("deepseek-7b").reduced(d_model=128, n_layers=24,
                                          vocab_size=512)
    run = RunConfig(attn_impl="full", remat="nothing",
                    compute_dtype="float32", microbatches=1)
    planner = HBMPlanner(leeway=0.0)
    shape = _small_shape()
    rep = planner.plan(cfg, shape, mesh1, run=run, anchor_layers=10,
                       select=False)
    assert rep.model.confident, f"R2={rep.model.r2}"
    truth = planner.profile_memory(cfg, shape, mesh1, run)
    pred = rep.predicted_per_dev_gib * GiB
    rel = abs(pred - truth) / truth
    assert rel < 0.10, f"extrapolation off by {rel:.2%}"


def test_planner_selects_feasible_config(mesh1):
    planner = HBMPlanner(leeway=0.0)
    sel = planner.select(requirement_gib=100.0, per_dev_gib_at_profile=1.0)
    assert sel.config.usable_mem_gib(planner.overhead) >= 100.0
    sel0 = planner.select(requirement_gib=0.0, per_dev_gib_at_profile=0.0)
    assert sel0.fell_back


def test_planner_per_chip_constraint():
    """A requirement that fits in aggregate but not per chip must push to a
    bigger slice or a bigger chip."""
    planner = HBMPlanner(leeway=0.0)
    sel = planner.select(requirement_gib=16 * 14.0, per_dev_gib_at_profile=0)
    c = sel.config
    assert c.usable_mem_gib(planner.overhead) >= 16 * 14.0
    assert (16 * 14.0) / c.scale_out <= c.node.mem_gib - planner.overhead
