"""Checkpointing: roundtrip, async, atomicity, garbage collection."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": jnp.array(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"step": 7, "note": "x"})
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    got, extra = restore_checkpoint(str(tmp_path), 7, like)
    assert extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(a, b)


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, tree())
    bad = tree()
    bad["a"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, bad)


def test_async_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        ck.save(s, tree(), extra={"step": s})
    ck.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path))
    assert steps == [30, 40]
    got, extra = restore_checkpoint(str(tmp_path), 40, tree())
    assert extra["step"] == 40


def test_tmp_dirs_are_not_latest(tmp_path):
    os.makedirs(tmp_path / "step_99.tmp")
    save_checkpoint(str(tmp_path), 5, tree())
    assert latest_step(str(tmp_path)) == 5
