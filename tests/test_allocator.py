"""Allocator subsystem: model zoo + LOOCV selection, persistent registry,
nearest-job classifier, and the batched/cached AllocationService end to end
(concurrent submitters, dedup, registry hits, classifier fallback)."""
import dataclasses
import math
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.allocator import (MODEL_KINDS, AllocationRequest,
                             AllocationService, LogLinearModel,
                             ModelRegistry, NearestJobClassifier,
                             PiecewiseLinearModel, PowerLawModel, RuntimeFit,
                             ZooFit, fit_runtime_zoo, fit_zoo,
                             model_from_dict, model_to_dict, zoo_fitter)
from repro.core.catalog import (ClusterConfig, NodeType, aws_like_catalog)
from repro.core.crispy import CrispyAllocator
from repro.core.history import ExecutionHistory
from repro.core.memory_model import fit_memory_model
from repro.core.profiler import ProfileResult
from repro.core.sampling import ladder_from_anchor
from repro.core.selector import select_crispy
from repro.core.simulator import (GiB, build_history, make_profile_fn,
                                  scout_like_jobs)
from repro.profiling import BackendModelRegistry, ProfileStore
from repro.serve.engine import AllocationEndpoint
from repro.state import InMemoryBackend

SIZES = [2e9, 4e9, 6e9, 8e9, 1e10]


def _profile_fn(mem_of_size, wall=1.0):
    def profile_at(s):
        return ProfileResult(s, mem_of_size(s), 0.0, wall)
    return profile_at


# -- model zoo ----------------------------------------------------------------


def test_zoo_linear_data_selects_linear():
    z = fit_zoo(SIZES, [0.9 * s + 1.6e9 for s in SIZES])
    assert z.candidate == "linear"
    assert z.confident
    assert z.predict(1e12) == pytest.approx(0.9e12 + 1.6e9, rel=1e-6)


def test_zoo_powerlaw_beats_linear_extrapolation():
    """The acceptance case: a superlinear job whose linear fit passes the
    paper's R2 gate yet extrapolates badly; the zoo must pick power-law and
    land near the truth."""
    mems = [3.0e-4 * s ** 1.35 for s in SIZES]
    z = fit_zoo(SIZES, mems)
    lin = fit_memory_model(SIZES, mems)
    full = 5e11
    truth = 3.0e-4 * full ** 1.35
    assert z.candidate == "powerlaw"
    assert z.confident
    zoo_err = abs(z.requirement(full) - truth) / truth
    lin_err = abs(lin.predict(full) - truth) / truth
    assert zoo_err < 0.01
    assert zoo_err < lin_err    # strictly beats the paper's only model
    assert lin_err > 0.3        # and the linear miss is material


def test_zoo_loglinear_and_piecewise_candidates():
    zl = fit_zoo(SIZES, [2e9 * math.log(s) + 1e9 for s in SIZES])
    assert zl.candidate == "loglinear" and zl.confident

    pw = [0.1 * s + 1e9 if s <= 6e9 else 2.0 * s - 1.04e10 for s in SIZES]
    zp = fit_zoo(SIZES, pw)
    assert zp.candidate == "piecewise" and zp.confident
    # extrapolation rides the right (large-size) segment
    assert zp.predict(2e10) == pytest.approx(2.0 * 2e10 - 1.04e10, rel=1e-6)


def test_zoo_noisy_data_is_not_confident():
    rng = np.random.default_rng(3)
    mems = [s * (1 + rng.normal(0, 0.09)) for s in SIZES]
    z = fit_zoo(SIZES, mems)
    assert not z.confident
    assert z.requirement(1e12) == 0.0       # degenerates like the paper


def test_zoo_prefers_simple_candidate_within_tolerance():
    """Near-linear data (0.2% noise) must NOT be stolen by piecewise."""
    rng = np.random.default_rng(0)
    mems = [(4.5 * s) * (1 + rng.normal(0, 0.002)) for s in SIZES]
    z = fit_zoo(SIZES, mems)
    assert z.candidate == "linear"
    assert z.confident


def test_zoo_fitter_is_a_crispy_drop_in():
    catalog = aws_like_catalog()
    history = build_history()
    alloc = CrispyAllocator(catalog, history, overhead_per_node_gib=2.0,
                            fitter=zoo_fitter())
    job = scout_like_jobs()[2]              # kmeans: linear profile
    rep = alloc.allocate(job.name, make_profile_fn(job),
                         job.dataset_gib * GiB,
                         anchor=job.dataset_gib * GiB * 0.01)
    assert isinstance(rep.model, ZooFit)
    assert rep.model.candidate == "linear"
    assert rep.model.confident
    assert rep.requirement_gib > 0


def test_zoo_nan_sample_filtered_not_fatal():
    """Regression: one NaN memory sample (crashed/mis-parsed profiling run)
    used to poison every LOOCV score and make candidate selection raise
    StopIteration. It must be dropped at the fit boundary instead."""
    mems = [0.9 * s + 1.6e9 for s in SIZES]
    mems[2] = float("nan")
    z = fit_zoo(SIZES, mems)
    assert isinstance(z, ZooFit)
    assert z.candidate == "linear"
    assert z.confident                      # 4 clean points remain
    assert z.n == len(SIZES) - 1
    assert z.predict(1e12) == pytest.approx(0.9e12 + 1.6e9, rel=1e-6)


def test_zoo_single_finite_survivor_degenerates_unconfident():
    z = fit_zoo(SIZES, [math.nan, math.inf, -math.inf, math.nan, 3e9])
    assert z.candidate == "linear"
    assert not z.confident
    assert z.requirement(1e12) == 0.0       # degenerates like the paper


# -- runtime zoo --------------------------------------------------------------


def test_runtime_zoo_linear_walls():
    f = fit_runtime_zoo(SIZES, [20.0 + 4e-8 * s for s in SIZES])
    assert isinstance(f, RuntimeFit)
    assert f.candidate == "runtime_linear"
    assert type(f.model).kind == "runtime_linear"   # runtime gate, not
    assert f.confident                              # the paper's 0.99 one


def test_runtime_zoo_superlinear_walls_pick_powerlaw():
    f = fit_runtime_zoo(SIZES, [1e-11 * s ** 1.35 for s in SIZES])
    assert f.candidate == "runtime_powerlaw"
    assert f.confident
    truth = 1e-11 * 5e11 ** 1.35
    assert f.predict(5e11) == pytest.approx(truth, rel=0.01)


def test_runtime_zoo_relaxed_gate_admits_mild_noise():
    """R² 0.95 < r2 < 0.99: unusable as a memory model (OOM risk), fine
    for a cost *ranking* — the runtime subclasses must stay confident."""
    rng = np.random.default_rng(3)
    walls = [(20.0 + 4e-8 * s) * (1 + rng.normal(0, 0.06)) for s in SIZES]
    f = fit_runtime_zoo(SIZES, walls)
    assert f.confident
    assert 0.95 < f.model.r2 < 0.99         # inside the relaxed band


def test_runtime_zoo_noisy_walls_not_confident():
    rng = np.random.default_rng(7)
    walls = [abs(10.0 * (1 + rng.normal(0, 0.6))) for _ in SIZES]
    f = fit_runtime_zoo(SIZES, walls)
    assert not f.confident


def test_runtime_zoo_nonfinite_wall_filtered():
    walls = [20.0 + 4e-8 * s for s in SIZES]
    walls[0] = math.inf                     # e.g. a timed-out run
    f = fit_runtime_zoo(SIZES, walls)
    assert f.candidate == "runtime_linear"
    assert f.confident


def test_model_serialization_round_trip():
    models = [fit_memory_model(SIZES, [2 * s + 1e9 for s in SIZES]),
              LogLinearModel.fit(SIZES, [1e9 * math.log(s) for s in SIZES]),
              PowerLawModel.fit(SIZES, [1e-3 * s ** 1.2 for s in SIZES]),
              PiecewiseLinearModel.fit(
                  SIZES, [s if s <= 6e9 else 3 * s - 1.2e10 for s in SIZES])]
    for m in models:
        d = model_to_dict(m)
        back = model_from_dict(d)
        assert type(back) is type(m)
        for size in (1e9, 5e10):
            assert back.predict(size) == pytest.approx(m.predict(size))
        assert back.confident == m.confident


def test_r2_score_flat_target_returns_plain_float():
    """`-inf` from the flat-target branch must be the Python float (the
    registry JSON path serializes it exactly; np.float64 also works but
    the contract is the plain builtin)."""
    from repro.core.memory_model import r2_score
    bad = r2_score(np.array([5.0, 5.0, 5.0]), np.array([4.0, 5.0, 6.0]))
    assert bad == -math.inf and type(bad) is float
    good = r2_score(np.array([5.0, 5.0, 5.0]), np.array([5.0, 5.0, 5.0]))
    assert good == 1.0 and type(good) is float


def test_registry_round_trips_unconfident_models_of_every_kind(tmp_path):
    """Every kind in MODEL_KINDS — runtime kinds included — survives the
    registry JSON path with r2 = -inf intact (json emits `-Infinity`;
    a naive str() round-trip would not parse back)."""
    path = str(tmp_path / "models.json")
    reg = ModelRegistry(path)
    lin = [2 * s + 1e9 for s in SIZES]
    for kind, cls in sorted(MODEL_KINDS.items()):
        fit = getattr(cls, "fit", None)
        m = fit(SIZES, lin) if fit else fit_memory_model(SIZES, lin)
        assert type(m) is cls, kind         # subclass fits must return cls
        u = dataclasses.replace(m, r2=-math.inf)
        assert not u.confident
        reg.put(f"job/{kind}", u, sizes=SIZES, mems=lin)
    back = ModelRegistry(path)
    for kind, cls in sorted(MODEL_KINDS.items()):
        rec = back.get(f"job/{kind}")
        assert rec is not None and type(rec.model) is cls, kind
        assert rec.model.r2 == -math.inf
        assert not rec.model.confident
        src = reg.get(f"job/{kind}", count_hit=False).model
        for size in (1e9, 5e10):
            assert rec.model.predict(size) == pytest.approx(
                src.predict(size))


# -- registry -----------------------------------------------------------------


def test_registry_persistence_round_trip(tmp_path):
    path = str(tmp_path / "models.json")
    reg = ModelRegistry(path)
    m = fit_memory_model(SIZES, [0.9 * s + 1.6e9 for s in SIZES])
    reg.put("jobA", m, sizes=SIZES, mems=[0.9 * s + 1.6e9 for s in SIZES])
    assert "jobA" in reg

    reg2 = ModelRegistry(path)              # fresh process, same file
    rec = reg2.get("jobA")
    assert rec is not None
    assert rec.candidate == "linear"
    assert rec.model.confident
    assert rec.model.predict(1e12) == pytest.approx(m.predict(1e12))
    assert rec.sizes == [float(s) for s in SIZES]
    assert rec.hits == 1                    # the get above counted


def test_registry_unconfident_models_not_persisted_by_service(tmp_path):
    """The service only registers gate-passing models."""
    path = str(tmp_path / "models.json")
    jobs = scout_like_jobs()
    catalog = aws_like_catalog()
    history = build_history(jobs, catalog)
    noisy = jobs[6]                         # logregression: noisy profile
    with AllocationService(catalog, history,
                           registry=ModelRegistry(path)) as svc:
        svc.allocate(AllocationRequest(
            noisy.name, make_profile_fn(noisy), noisy.dataset_gib * GiB,
            anchor=noisy.dataset_gib * GiB * 0.01))
        assert noisy.name not in svc.registry


# -- classifier ---------------------------------------------------------------


def test_classifier_matches_similar_shape_rejects_different():
    clf = NearestJobClassifier(max_distance=0.25)
    rng = np.random.default_rng(1)
    linear = [0.9 * s for s in SIZES]
    clf.observe("linear-job", SIZES, linear)
    clf.observe("flat-job", SIZES, [5e8] * 5)

    near = [0.95 * s * (1 + rng.normal(0, 0.01)) for s in SIZES]
    got = clf.classify(SIZES, near)
    assert got is not None and got.neighbor == "linear-job"

    # exclusion works (a job must not classify to itself)
    got2 = clf.classify(SIZES, near, exclude=("linear-job",))
    assert got2 is None or got2.neighbor != "linear-job"


def test_classifier_runtime_shape_rescues_memory_tie():
    """Two observed jobs with near-identical (linear) memory shape but
    different runtime shape: a quadratic-runtime query is misclassified
    by memory shape alone (the scan's memory curve matches exactly) and
    classified correctly once the ladder's runtime curve joins the
    feature vector."""
    clf = NearestJobClassifier(max_distance=0.25)
    smax = max(SIZES)
    scan_mem = [2.0 * s for s in SIZES]               # exactly linear
    join_mem = [2.0 * s + 0.1 * s * (s / smax) for s in SIZES]  # near-linear
    scan_rt = [10.0 * (s / smax) for s in SIZES]          # linear runtime
    join_rt = [10.0 * (s / smax) ** 2 for s in SIZES]     # quadratic runtime
    clf.observe("scan", SIZES, scan_mem, scan_rt)
    clf.observe("join", SIZES, join_mem, join_rt)

    query_mem = list(scan_mem)        # memory says "scan", exactly
    query_rt = [11.0 * (s / smax) ** 2 for s in SIZES]    # runtime says "join"

    by_mem = clf.classify(SIZES, query_mem)
    assert by_mem is not None and by_mem.neighbor == "scan"   # misclassified

    by_both = clf.classify(SIZES, query_mem, query_rt)
    assert by_both is not None and by_both.neighbor == "join"

    # a neighbor observed WITHOUT runtimes still participates (memory-only
    # distance): the feature store never fragments on mixed observations
    clf2 = NearestJobClassifier(max_distance=0.25)
    clf2.observe("legacy", SIZES, scan_mem)           # e.g. registry warmup
    got = clf2.classify(SIZES, query_mem, query_rt)
    assert got is not None and got.neighbor == "legacy"


def test_classifier_tags_break_memory_and_runtime_tie():
    """Flora-style categorical features: two observed jobs whose memory
    AND runtime curves tie exactly are indistinguishable to the numeric
    blocks — the input-format/operator tag palette must break the tie,
    and tagless neighbors must keep participating unchanged."""
    clf = NearestJobClassifier(max_distance=0.25)
    smax = max(SIZES)
    mem = [2.0 * s for s in SIZES]
    rt = [10.0 * (s / smax) for s in SIZES]
    clf.observe("etl/csv", SIZES, mem, rt,
                tags={"format:csv", "op:scan"})
    clf.observe("etl/parquet", SIZES, mem, rt,
                tags={"format:parquet", "op:join"})

    got = clf.classify(SIZES, mem, rt,
                       tags={"format:parquet", "op:join", "op:filter"})
    assert got is not None and got.neighbor == "etl/parquet"
    got2 = clf.classify(SIZES, mem, rt, tags={"format:csv", "op:scan"})
    assert got2 is not None and got2.neighbor == "etl/csv"
    # disjoint palettes push past the tie but not past the gate when the
    # curves agree this well; identical palettes tie at distance 0
    assert got2.distance == pytest.approx(0.0)

    # tie-breaker, NOT veto: even a fully disjoint palette over
    # byte-identical curves must stay under the gate (memory-only is the
    # worst case — the smallest numeric block)
    clf3 = NearestJobClassifier(max_distance=0.25)
    clf3.observe("only", SIZES, mem, tags={"format:orc", "op:window"})
    still_in = clf3.classify(SIZES, mem, tags={"format:csv", "op:scan"})
    assert still_in is not None and still_in.neighbor == "only"

    # a neighbor observed WITHOUT tags still participates on the numeric
    # blocks alone (mixed observations never fragment the store)
    clf2 = NearestJobClassifier(max_distance=0.25)
    clf2.observe("legacy", SIZES, mem, rt)
    got3 = clf2.classify(SIZES, mem, rt, tags={"format:csv"})
    assert got3 is not None and got3.neighbor == "legacy"

    # a tagless RE-observation (plan-cache miss, registry warm-up) must
    # not erase a previously observed palette
    clf.observe("etl/parquet", SIZES, mem, rt)
    still = clf.classify(SIZES, mem, rt,
                         tags={"format:parquet", "op:join", "op:filter"})
    assert still is not None and still.neighbor == "etl/parquet"


def test_service_plumbs_tags_to_classifier(corpus):
    """Request-level tags reach the classifier's feature store through
    the pipeline's observe stage."""
    jobs, catalog, history = corpus
    logreg = jobs[6]
    full = logreg.dataset_gib * GiB
    with AllocationService(catalog, history) as svc:
        svc.allocate(AllocationRequest(
            logreg.name, make_profile_fn(logreg), full, anchor=full * 0.01,
            tags=("format:csv", "op:regression")))
    assert svc.classifier._tags[logreg.name] == {"format:csv",
                                                 "op:regression"}


# -- pipeline parity contract -------------------------------------------------


def test_pipeline_parity_service_vs_one_shot(corpus):
    """CONTRACT (one decision path): AllocationService and CrispyAllocator
    over the same StateBackend — same ladder, same fitter, same history —
    return byte-identical requirement and selection for every profile
    shape. The service profiles first (fixed ladder) and the one-shot
    path answers from the same stored points; any drift between the two
    means a second pipeline grew back somewhere."""
    jobs, catalog, history = corpus
    checked = [jobs[2], jobs[0], jobs[6], jobs[10]]  # linear x2, noisy, flat
    for job in checked:
        backend = InMemoryBackend()
        full = job.dataset_gib * GiB
        with AllocationService(catalog, history,
                               registry=BackendModelRegistry(backend),
                               store=ProfileStore(backend=backend)) as svc:
            resp = svc.allocate(_req(job))
        alloc = CrispyAllocator(catalog, history, fitter=zoo_fitter())
        rep = alloc.allocate(job.name, make_profile_fn(job), full,
                             anchor=full * 0.01,
                             store=ProfileStore(backend=backend))
        assert rep.requirement_gib == resp.requirement_gib, job.name
        s1, s2 = rep.selection, resp.selection
        assert s1.config.name == s2.config.name, job.name
        assert s1.method == s2.method
        assert s1.mem_requirement_gib == s2.mem_requirement_gib
        assert s1.feasible_count == s2.feasible_count
        assert s1.fell_back == s2.fell_back


def test_pipeline_parity_adaptive_placement(corpus):
    """The parity contract holds on the adaptive path too: identical
    placement decisions (same placer, same measured values via the shared
    store) give byte-identical answers."""
    jobs, catalog, history = corpus
    km = jobs[2]
    full = km.dataset_gib * GiB
    for placement in ("infogain", "ladder"):
        backend = InMemoryBackend()
        with AllocationService(catalog, history,
                               registry=BackendModelRegistry(backend),
                               store=ProfileStore(backend=backend),
                               adaptive=True, placement=placement) as svc:
            resp = svc.allocate(_req(km))
            assert resp.placement == placement
        rep = CrispyAllocator(catalog, history, fitter=zoo_fitter()).allocate(
            km.name, make_profile_fn(km), full, anchor=full * 0.01,
            adaptive=True, placement=placement,
            store=ProfileStore(backend=backend))
        assert rep.points_profiled == resp.profiled + resp.cache_hits
        assert rep.requirement_gib == resp.requirement_gib, placement
        assert rep.selection.config.name == resp.selection.config.name


# -- selection objectives -----------------------------------------------------


def test_nothing_fits_fallback_breaks_memory_tie_by_price():
    """Regression: when no config satisfies the requirement, the largest-
    memory fallback used to resolve equal-memory ties by catalog order —
    list order could hand out a strictly costlier config."""
    dear = ClusterConfig(NodeType("dear", 8, 64.0, 9.0), 4)
    fair = ClusterConfig(NodeType("fair", 8, 64.0, 2.0), 4)
    for catalog in ([dear, fair], [fair, dear]):    # order-independent
        sel = select_crispy(catalog, ExecutionHistory(),
                            mem_requirement_gib=1e9)
        assert sel.fell_back
        assert sel.feasible_count == 1
        assert sel.config.name == "fairx4"


def test_select_crispy_rejects_unknown_objective():
    cfg = ClusterConfig(NodeType("n", 8, 64.0, 1.0), 4)
    with pytest.raises(ValueError, match="unknown objective"):
        select_crispy([cfg], ExecutionHistory(), 1.0, objective="fastest")


def test_min_cost_selects_cheaper_config_on_superlinear_runtime():
    """Acceptance: on a superlinear-runtime job min_cost picks a strictly
    cheaper-$/h config than cheapest_fit, at equal-or-lower predicted
    cost under the SAME runtime model."""
    from repro.core.selector import predicted_cost_usd, predicted_runtime_s
    catalog = aws_like_catalog()
    history = build_history()
    full = 1e11
    alloc = CrispyAllocator(catalog, history, fitter=zoo_fitter())

    def profile_at(s):
        return ProfileResult(s, 0.9 * s + 1.6e9, 0.0, 1e-11 * s ** 1.35)

    cheap = alloc.allocate("sup/cheapest", profile_at, full,
                           anchor=full * 0.01)
    cost = alloc.allocate("sup/mincost", profile_at, full,
                          anchor=full * 0.01, objective="min_cost")
    sel = cost.selection
    assert cost.runtime_model is not None and cost.runtime_model.confident
    assert not sel.objective_fell_back
    assert sel.config.usd_per_hour < cheap.selection.config.usd_per_hour
    cheap_rt = predicted_runtime_s(cost.runtime_model, full,
                                   cheap.selection.config)
    assert sel.predicted_cost_usd <= predicted_cost_usd(
        cheap_rt, cheap.selection.config) + 1e-9


def test_objective_cheapest_fit_is_byte_identical_to_default(corpus):
    """CONTRACT: objective="cheapest_fit" is the pre-objective-axis
    behavior, bit for bit — the runtime model may be fit and registered,
    but it must not touch the selection."""
    jobs, catalog, history = corpus
    for job in (jobs[2], jobs[6]):          # confident linear + noisy
        full = job.dataset_gib * GiB
        with AllocationService(catalog, history) as svc:
            default = svc.allocate(_req(job))
        with AllocationService(catalog, history) as svc:
            explicit = svc.allocate(AllocationRequest(
                job.name, make_profile_fn(job), full, anchor=full * 0.01,
                objective="cheapest_fit"))
        s1, s2 = default.selection, explicit.selection
        assert s1 == s2, job.name
        assert s2.objective == "cheapest_fit"
        assert s2.predicted_runtime_s is None
        assert s2.predicted_cost_usd is None
        assert not s2.objective_fell_back


def test_pipeline_parity_holds_on_objective_axis(corpus):
    """Service and one-shot answer identically for the runtime objectives
    too (same stored points, same runtime fit, same Pareto pick)."""
    jobs, catalog, history = corpus
    km = jobs[2]
    full = km.dataset_gib * GiB
    for objective in ("min_cost", "min_runtime"):
        backend = InMemoryBackend()
        with AllocationService(catalog, history,
                               registry=BackendModelRegistry(backend),
                               store=ProfileStore(backend=backend)) as svc:
            resp = svc.allocate(AllocationRequest(
                km.name, make_profile_fn(km), full, anchor=full * 0.01,
                objective=objective))
        rep = CrispyAllocator(catalog, history, fitter=zoo_fitter()).allocate(
            km.name, make_profile_fn(km), full, anchor=full * 0.01,
            objective=objective, store=ProfileStore(backend=backend))
        s1, s2 = rep.selection, resp.selection
        assert s1.config.name == s2.config.name, objective
        assert s1.objective == s2.objective == objective
        assert s1.predicted_runtime_s == s2.predicted_runtime_s
        assert s1.predicted_cost_usd == s2.predicted_cost_usd
        assert s1.objective_fell_back == s2.objective_fell_back


def test_min_cost_falls_back_when_runtime_unconfident(corpus):
    """Never-worse-than-BFA across the objective axis: noisy walls leave
    the runtime model unconfident, so min_cost must answer exactly what
    cheapest_fit answers (and say it fell back)."""
    jobs, catalog, history = corpus
    rng = np.random.default_rng(11)

    def profile_at(s):                      # clean memory, useless walls
        return ProfileResult(s, 0.9 * s + 1.6e9, 0.0,
                             abs(10.0 * (1 + rng.normal(0, 0.6))))

    full = 2e11
    with AllocationService(catalog, history) as svc:
        cheap = svc.allocate(AllocationRequest(
            "noisy-wall/job", profile_at, full, anchor=full * 0.01))
        cost = svc.allocate(AllocationRequest(
            "noisy-wall/job", profile_at, full, anchor=full * 0.01,
            objective="min_cost"))
        # second pass reads the shared point LRU: identical measured world
        assert cost.profiled == 0
        sel = cost.selection
        assert sel.objective == "min_cost"
        assert sel.objective_fell_back
        assert sel.predicted_runtime_s is None
        assert sel.config.name == cheap.selection.config.name
        assert svc.stats.cost_objective_requests == 1
        assert svc.stats.objective_fallbacks == 1


def test_warm_start_serves_runtime_model(corpus):
    """A registry hit must answer runtime objectives without re-profiling:
    the runtime companion model round-trips through the shared backend."""
    jobs, catalog, history = corpus
    km = jobs[2]
    full = km.dataset_gib * GiB
    backend = InMemoryBackend()
    with AllocationService(catalog, history,
                           registry=BackendModelRegistry(backend)) as svc:
        first = svc.allocate(AllocationRequest(
            km.name, make_profile_fn(km), full, anchor=full * 0.01,
            objective="min_cost"))
        assert not first.selection.objective_fell_back
    with AllocationService(catalog, history,
                           registry=BackendModelRegistry(backend)) as svc2:
        warm = svc2.allocate(AllocationRequest(
            km.name, make_profile_fn(km), full, anchor=full * 0.01,
            objective="min_cost"))
        assert warm.source == "registry"
        assert warm.profiled == 0
        assert warm.runtime_candidate == first.runtime_candidate
        assert not warm.selection.objective_fell_back
        assert warm.selection.config.name == first.selection.config.name
        assert warm.selection.predicted_cost_usd == pytest.approx(
            first.selection.predicted_cost_usd)


# -- service end-to-end -------------------------------------------------------


@pytest.fixture(scope="module")
def corpus():
    jobs = scout_like_jobs()
    catalog = aws_like_catalog()
    return jobs, catalog, build_history(jobs, catalog)


def _req(job):
    full = job.dataset_gib * GiB
    return AllocationRequest(job.name, make_profile_fn(job), full,
                             anchor=full * 0.01)


def test_service_end_to_end_concurrent(corpus, tmp_path):
    """Acceptance: N>=8 concurrent requests, repeated + novel jobs; cache
    hits on repeats, zoo beating pure-linear on a nonlinear job, classifier
    fallback when nothing is confident."""
    jobs, catalog, history = corpus
    kmeans, naivebayes, logreg, join = jobs[2], jobs[0], jobs[6], jobs[10]

    # synthetic nonlinear job: superlinear growth the linear model misses
    nl_full = 3e11
    nl_truth = 3.0e-4 * nl_full ** 1.35
    nl_req = AllocationRequest(
        "nonlinear/synthetic", _profile_fn(lambda s: 3.0e-4 * s ** 1.35),
        nl_full, anchor=nl_full * 0.01)

    # novel noisy job, shaped like the (historical) noisy logregression
    rng = np.random.default_rng(9)
    novel_noisy = AllocationRequest(
        "novel-noisy/spark/gen",
        _profile_fn(lambda s: 1.1 * s * (1 + rng.normal(0, 0.09))),
        2e11, anchor=2e9)

    with AllocationService(catalog, history,
                           registry=ModelRegistry(
                               str(tmp_path / "reg.json")),
                           batch_window_s=0.02) as svc:
        wave1 = [_req(kmeans), _req(kmeans), _req(naivebayes), _req(logreg),
                 _req(join), nl_req, _req(naivebayes), _req(jobs[4])]
        assert len(wave1) >= 8
        with ThreadPoolExecutor(len(wave1)) as ex:
            futs = [ex.submit(svc.allocate, r) for r in wave1]
            rs = [f.result(timeout=60) for f in futs]

        by_job = {}
        for r in rs:
            by_job.setdefault(r.job, []).append(r)

        # same-batch dedup: concurrent same-signature requests share one plan
        assert svc.stats.profile_calls <= 5 * 6     # 6 unique signatures

        # the zoo rescued the nonlinear job and beat the pure-linear fit
        nl = by_job["nonlinear/synthetic"][0]
        assert nl.source == "zoo" and nl.candidate == "powerlaw"
        zoo_err = abs(nl.requirement_gib * GiB - nl_truth) / nl_truth
        lin = fit_memory_model(
            ladder_from_anchor(nl_full * 0.01).sizes,
            [3.0e-4 * s ** 1.35
             for s in ladder_from_anchor(nl_full * 0.01).sizes])
        lin_err = abs(lin.predict(nl_full) - nl_truth) / nl_truth
        assert zoo_err < 0.01 < lin_err

        # linear jobs got confident models and real requirements
        km = by_job[kmeans.name][0]
        assert km.source in ("zoo", "registry")
        assert km.requirement_gib > 0

        # wave 2: repeats are served from the registry with zero profiling
        rs2 = svc.allocate_many([_req(kmeans), _req(naivebayes), nl_req])
        for r in rs2:
            assert r.source == "registry"
            assert r.profiled == 0
        assert svc.stats.registry_hits >= 3

        # noisy repeat: never confident, but never re-profiled either —
        # served from the plan cache (identical world) or refit from the
        # ladder LRU (a new model/neighbor invalidated the cached plan)
        hits_before = svc.stats.cache_hits
        plan_hits_before = svc.stats.plan_cache_hits
        r_noisy = svc.allocate(_req(logreg))
        assert r_noisy.profiled == 0
        assert (svc.stats.cache_hits - hits_before >= 5 or
                svc.stats.plan_cache_hits - plan_hits_before >= 1)

        # classifier fallback engaged for unconfident jobs with neighbors
        r_novel = svc.allocate(novel_noisy)
        assert r_novel.source == "classifier"
        assert r_novel.neighbor is not None
        assert svc.stats.classifier_fallbacks >= 1

        stats = svc.stats
        assert stats.requests == len(wave1) + 3 + 1 + 1
        # some repeat was answered without fresh profiling, via either cache
        assert stats.profile_hit_rate > 0.0 or stats.plan_cache_hits > 0


def test_service_registry_survives_restart(corpus, tmp_path):
    jobs, catalog, history = corpus
    kmeans = jobs[2]
    path = str(tmp_path / "reg.json")
    with AllocationService(catalog, history,
                           registry=ModelRegistry(path)) as svc:
        first = svc.allocate(_req(kmeans))
        assert first.source == "zoo"

    # "restart": new service over the same registry file
    with AllocationService(catalog, history,
                           registry=ModelRegistry(path)) as svc2:
        again = svc2.allocate(_req(kmeans))
        assert again.source == "registry"
        assert again.profiled == 0
        # classifier was warm-started from the persisted ladder
        assert kmeans.name in svc2.classifier.jobs()


def test_service_profile_error_fails_only_its_group(corpus):
    jobs, catalog, history = corpus

    def boom(_s):
        raise RuntimeError("profiler crashed")

    with AllocationService(catalog, history) as svc:
        bad = AllocationRequest("bad/job", boom, 1e11, anchor=1e9)
        good = _req(jobs[0])
        f_bad, f_good = svc.submit(bad), svc.submit(good)
        with pytest.raises(RuntimeError, match="profiler crashed"):
            f_bad.result(timeout=60)
        assert f_good.result(timeout=60).selection is not None


def test_cancelled_future_does_not_kill_worker(corpus):
    """A caller cancelling its pending future must not crash the worker
    thread or strand the other requests in the batch."""
    jobs, catalog, history = corpus
    with AllocationService(catalog, history, batch_window_s=0.2) as svc:
        f_cancel = svc.submit(_req(jobs[2]))
        f_live = svc.submit(_req(jobs[0]))
        assert f_cancel.cancel()            # still pending: cancel succeeds
        r = f_live.result(timeout=60)       # sibling must still resolve
        assert r.selection is not None
        # worker survived and serves subsequent traffic
        assert svc.allocate(_req(jobs[4])).selection is not None


def test_flush_failure_does_not_kill_worker(corpus, tmp_path):
    """Registry persistence failing (disk full / read-only) must not take
    the worker thread down; models stay in memory."""
    jobs, catalog, history = corpus
    reg = ModelRegistry(str(tmp_path / "reg.json"))

    def bad_flush():
        raise OSError("disk full")

    with AllocationService(catalog, history, registry=reg) as svc:
        reg.flush = bad_flush
        r = svc.allocate(_req(jobs[2]))
        assert r.source == "zoo"
        assert svc.stats.flush_errors >= 1
        # worker alive, model served from the in-memory registry
        assert svc.allocate(_req(jobs[2])).source == "registry"


def test_unconfident_repeat_uses_plan_cache(corpus):
    """A noisy job resubmitted against an unchanged world must not redo
    the zoo fit / classifier scan."""
    jobs, catalog, history = corpus
    logreg = jobs[6]
    with AllocationService(catalog, history) as svc:
        first = svc.allocate(_req(logreg))
        assert first.source in ("classifier", "baseline")
        fits_before = svc.stats.zoo_fits
        again = svc.allocate(_req(logreg))
        assert again.source == first.source
        assert again.profiled == 0
        assert svc.stats.zoo_fits == fits_before       # no refit
        assert svc.stats.plan_cache_hits >= 1
        # a new confident model invalidates the negative cache...
        svc.allocate(_req(jobs[2]))                     # kmeans -> zoo put
        fits_before = svc.stats.zoo_fits
        third = svc.allocate(_req(logreg))
        assert svc.stats.zoo_fits == fits_before + 1    # ...so it refits
        assert third.profiled == 0                      # from the LRU


def test_service_rejects_after_close(corpus):
    jobs, catalog, history = corpus
    svc = AllocationService(catalog, history)
    svc.close()
    with pytest.raises(RuntimeError):
        svc.submit(_req(jobs[0]))


# -- serving endpoint ---------------------------------------------------------


def test_allocation_endpoint_wire_format(corpus):
    jobs, catalog, history = corpus
    kmeans = jobs[2]
    with AllocationService(catalog, history) as svc:
        ep = AllocationEndpoint(svc)
        wire = ep.handle(job=kmeans.name, profile_at=make_profile_fn(kmeans),
                         full_size=kmeans.dataset_gib * GiB,
                         anchor=kmeans.dataset_gib * GiB * 0.01)
    assert wire["job"] == kmeans.name
    assert wire["source"] == "zoo"
    assert wire["candidate"] == "linear"
    assert wire["requirement_gib"] > 0
    assert isinstance(wire["config"], str) and "x" in wire["config"]
    assert wire["usd_per_hour"] > 0
    # objective axis on the wire: default request carries the runtime
    # companion fit but no runtime-derived numbers
    assert wire["objective"] == "cheapest_fit"
    assert wire["objective_fell_back"] is False
    assert wire["predicted_runtime_s"] is None
    assert wire["predicted_cost_usd"] is None
    assert wire["runtime_candidate"] == "runtime_linear"


def test_allocation_endpoint_min_cost_objective(corpus):
    jobs, catalog, history = corpus
    kmeans = jobs[2]
    with AllocationService(catalog, history) as svc:
        ep = AllocationEndpoint(svc)
        wire = ep.handle(job=kmeans.name, profile_at=make_profile_fn(kmeans),
                         full_size=kmeans.dataset_gib * GiB,
                         anchor=kmeans.dataset_gib * GiB * 0.01,
                         objective="min_cost")
        stats = ep.stats()
    assert wire["objective"] == "min_cost"
    assert wire["objective_fell_back"] is False
    assert wire["predicted_runtime_s"] > 0
    assert wire["predicted_cost_usd"] > 0
    assert stats["runtime_fits"] >= 1
    assert stats["runtime_confident"] >= 1
    assert stats["cost_objective_requests"] == 1
    assert stats["objective_fallbacks"] == 0
