"""Serving engine: continuous batching, slot reuse, sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import RunConfig
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine

RUN = RunConfig(attn_impl="full", remat="nothing", compute_dtype="float32")


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("deepseek-7b").reduced()
    m = build_model(cfg, RUN)
    return m, m.init(jax.random.PRNGKey(0))


def test_engine_serves_all_requests(small_model):
    m, p = small_model
    eng = ServeEngine(m, p, slots=2, max_len=32)
    for rid in range(5):
        eng.submit(Request(rid, prompt=[rid + 1, 2, 3], max_new_tokens=4))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)


def test_slot_reuse_matches_fresh_engine(small_model):
    """A request served in a recycled slot produces the same tokens as on a
    fresh engine — stale cache state is fully isolated."""
    m, p = small_model
    eng = ServeEngine(m, p, slots=1, max_len=32)
    eng.submit(Request(0, prompt=[9, 8, 7], max_new_tokens=5))
    eng.submit(Request(1, prompt=[3, 2, 1], max_new_tokens=5))
    done = eng.run()
    r1 = [r for r in done if r.rid == 1][0]

    fresh = ServeEngine(m, p, slots=1, max_len=32)
    fresh.submit(Request(1, prompt=[3, 2, 1], max_new_tokens=5))
    d2 = fresh.run()
    assert r1.out_tokens == d2[0].out_tokens


def test_greedy_matches_forward_argmax(small_model):
    """Engine greedy decode == argmax over model.forward logits chain."""
    m, p = small_model
    prompt = [5, 11, 2]
    eng = ServeEngine(m, p, slots=1, max_len=32)
    eng.submit(Request(0, prompt=prompt, max_new_tokens=3))
    out = eng.run()[0].out_tokens

    toks = list(prompt)
    for _ in range(3):
        lg, _ = m.forward(p, {"tokens": jnp.asarray([toks])})
        toks.append(int(jnp.argmax(lg[0, -1])))
    assert out == toks[len(prompt):]


def test_ssm_engine(small_model):
    """Attention-free arch serves through the same engine (state caches)."""
    cfg = get_arch("rwkv6-7b").reduced()
    m = build_model(cfg, RUN)
    p = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, p, slots=2, max_len=16)
    for rid in range(3):
        eng.submit(Request(rid, prompt=[rid + 1, 4], max_new_tokens=3))
    done = eng.run()
    assert len(done) == 3 and all(len(r.out_tokens) == 3 for r in done)
