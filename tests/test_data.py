"""Data pipeline: determinism, shard invariance, memmap, cursor resume."""
import numpy as np
import pytest

from repro.data.pipeline import (LoaderState, MemmapDataset, ShardedLoader,
                                 SyntheticLMDataset)


def test_synthetic_deterministic():
    ds = SyntheticLMDataset(1000, seed=3)
    a = ds.window(5, 64)
    b = ds.window(5, 64)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, ds.window(6, 64))


def test_loader_shard_invariance():
    """2 shards x B=4 see exactly the samples 1 shard x B=8 sees."""
    ds = SyntheticLMDataset(500, seed=0)
    whole = ShardedLoader(ds, 8, 16, shard=0, n_shards=1)
    s0 = ShardedLoader(ds, 4, 16, shard=0, n_shards=2)
    s1 = ShardedLoader(ds, 4, 16, shard=1, n_shards=2)
    try:
        w = next(whole)["tokens"]
        a = next(s0)["tokens"]
        b = next(s1)["tokens"]
        np.testing.assert_array_equal(np.concatenate([a, b]), w)
    finally:
        whole.close(); s0.close(); s1.close()


def test_loader_cursor_resume():
    ds = SyntheticLMDataset(500, seed=0)
    l1 = ShardedLoader(ds, 2, 16)
    try:
        batches = [next(l1) for _ in range(5)]
        cursor = l1.state.to_dict()
    finally:
        l1.close()
    l2 = ShardedLoader(ds, 2, 16, state=LoaderState.from_dict(
        {"step": cursor["step"] - 2}))
    try:
        again = next(l2)
        np.testing.assert_array_equal(again["tokens"],
                                      batches[3]["tokens"])
    finally:
        l2.close()


def test_labels_are_shifted_tokens():
    ds = SyntheticLMDataset(500, seed=0)
    l = ShardedLoader(ds, 2, 16)
    try:
        b = next(l)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)
        # label[t] == token[t+1] within the window
        w = ds.window(0, 16)
        np.testing.assert_array_equal(b["tokens"][0], w[:-1])
        np.testing.assert_array_equal(b["labels"][0], w[1:])
    finally:
        l.close()


def test_memmap_roundtrip(tmp_path):
    path = str(tmp_path / "toks.bin")
    toks = np.arange(1000, dtype=np.int32)
    MemmapDataset.write(path, toks)
    ds = MemmapDataset(path)
    w = ds.window(0, 16)
    np.testing.assert_array_equal(w, np.arange(17))
    assert ds.window(2, 16)[0] == 32
