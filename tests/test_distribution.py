"""Distribution tests that need multiple devices: run in subprocesses with
--xla_force_host_platform_device_count (NOT set globally — see dryrun.py).

Covers: sharded train step == single-device train step, MoE EP on a real
model axis, sharding rules divisibility fallback, dry-run cell lowering."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # every snippet builds meshes through the AxisType compat shim so the
    # suite runs on jax installs without jax.sharding.AxisType (< 0.5)
    code = ("from repro.launch.mesh import compat_make_mesh\n"
            + textwrap.dedent(code))
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    if "SKIP:" in out.stdout:
        pytest.skip(out.stdout.split("SKIP:", 1)[1].strip().splitlines()[0])
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch
        from repro.configs.base import RunConfig
        from repro.models.model import Model
        from repro.optim import AdamWConfig
        from repro.train.step import init_train_state, make_train_step
        from repro.sharding.rules import param_specs, opt_state_specs, named
        from repro.train.step import TrainState

        cfg = get_arch('deepseek-7b').reduced(d_model=64, n_layers=2,
                                              vocab_size=256)
        run = RunConfig(attn_impl='full', remat='nothing',
                        compute_dtype='float32')
        model = Model(cfg, run)
        acfg = AdamWConfig(lr=1e-2)
        state = init_train_state(model, jax.random.PRNGKey(0), acfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 256)
        batch = {'tokens': toks, 'labels': toks}

        # single device
        s1, m1 = jax.jit(make_train_step(model, acfg, None))(state, batch)

        # sharded over (2 data, 4 model)
        mesh = compat_make_mesh((2, 4), ('data', 'model'))
        p_specs = param_specs(state.params, mesh, run)
        o_specs = opt_state_specs(state.opt, p_specs, state.params, mesh, run)
        sh = TrainState(
            jax.tree.map(lambda s: named(mesh, s), p_specs),
            jax.tree.map(lambda s: named(mesh, s), o_specs), None)
        step = jax.jit(make_train_step(model, acfg, mesh), in_shardings=(sh, None))
        s2, m2 = step(state, batch)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(s1.params),
                                  jax.tree.leaves(s2.params)))
        print('LOSSDIFF', abs(float(m1['loss']) - float(m2['loss'])))
        print('PARAMDIFF', err)
        assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-4
        assert err < 1e-4
        print('OK')
    """)
    assert "OK" in out


def test_moe_ep_sharded_matches_dense():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_arch
        from repro.configs.base import RunConfig
        from repro.models import moe as M

        cfg = get_arch('olmoe-1b-7b').reduced()
        run = RunConfig(compute_dtype='float32')
        params = M.init_moe(jax.random.PRNGKey(0), cfg)
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        dense, aux_d = M.moe_dense(params, x, cfg)
        mesh = compat_make_mesh((2, 4), ('data', 'model'))
        cfg_hi = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
        ep, aux_e = jax.jit(lambda p, x: M.moe_ep(p, x, cfg_hi, run, mesh))(
            params, x)
        err = float(jnp.max(jnp.abs(dense - ep)))
        print('ERR', err)
        assert err < 1e-4
        print('OK')
    """)
    assert "OK" in out


def test_moe_ep_a2a_matches_dense():
    """DeepSeek-style a2a EP (experts over model x data) == dropless dense
    at ample capacity, and is differentiable."""
    out = run_sub("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_arch
        from repro.configs.base import RunConfig
        from repro.models import moe as M
        cfg = get_arch('olmoe-1b-7b').reduced()   # 8 experts
        run = RunConfig(compute_dtype='float32')
        params = M.init_moe(jax.random.PRNGKey(0), cfg)
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(1),
                                    (4, 16, cfg.d_model))
        dense, _ = M.moe_dense(params, x, cfg)
        mesh = compat_make_mesh((2, 4), ('data', 'model'))
        cfg_hi = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts * 4),
            impl='ep_a2a'))
        ep, _ = jax.jit(lambda p, x: M.moe_ep_a2a(p, x, cfg_hi, run, mesh))(
            params, x)
        err = float(jnp.max(jnp.abs(dense - ep)))
        assert err < 1e-4, err
        g = jax.grad(lambda p: M.moe_ep_a2a(p, x, cfg_hi, run, mesh)[0]
                     .sum())(params)
        assert float(jnp.abs(g['w_gate']).sum()) > 0
        print('OK', err)
    """)
    assert "OK" in out


def test_dryrun_cell_multipod_small():
    """A multi-pod (2,2,2) mesh lowers+compiles a small arch cell and the
    record carries all roofline fields."""
    out = run_sub("""
        import jax, json
        from repro.configs import get_arch, SHAPES
        from repro.launch.dryrun import run_cell
        mesh = compat_make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        rec = run_cell(get_arch('whisper-small'), SHAPES['train_4k'], mesh)
        assert rec['roofline']['dominant'] in ('compute', 'memory',
                                               'collective')
        assert rec['memory']['per_device_bytes'] > 0
        assert rec['hlo_costs']['dot_flops_per_dev'] > 0
        print('OK', rec['roofline']['dominant'])
    """, timeout=1200)
    assert "OK" in out


def test_sharding_rules_divisibility_fallback():
    out = run_sub("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.configs.base import RunConfig
        from repro.sharding.rules import param_specs
        from repro.models.model import Model
        mesh = compat_make_mesh((2, 4), ('data', 'model'))
        run = RunConfig()
        # whisper: 12 heads not divisible by 4? 12 % 4 == 0 -> sharded;
        # chatglm kv heads = 2 not divisible by 4 -> replicated
        cfg = get_arch('chatglm3-6b')
        model = Model(cfg, run)
        p_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = param_specs(p_abs, mesh, run)
        wq = specs['layers']['attn']['wq']
        wk = specs['layers']['attn']['wk']
        assert wq == P(None, None, 'model', None), wq  # 32 q heads sharded
        assert wk == P(None, None, None, None) or wk == P(), wk  # 2 kv heads
        print('OK')
    """)
    assert "OK" in out


def test_pipeline_parallelism_fwd_and_grad():
    """GPipe pipeline over a 4-stage 'pipe' axis == sequential layer stack,
    forward and backward."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from jax import lax
        from repro.sharding.pipeline import pipeline_apply
        mesh = compat_make_mesh((4,), ('pipe',))
        L, d = 8, 16
        W = 0.3 * jax.random.normal(jax.random.PRNGKey(0), (L, d, d))
        def stage_fn(stage_w, x):
            def body(c, w):
                return jnp.tanh(c @ w), None
            return lax.scan(body, x, stage_w)[0]
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, d))
        ref = stage_fn(W, x)
        got = jax.jit(lambda w, x: pipeline_apply(
            stage_fn, w, x, mesh, n_micro=4))(W, x)
        assert float(jnp.max(jnp.abs(ref - got))) < 1e-5
        g1 = jax.grad(lambda w: stage_fn(w, x).sum())(W)
        g2 = jax.jit(jax.grad(lambda w: pipeline_apply(
            stage_fn, w, x, mesh, n_micro=4).sum()))(W)
        assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-4
        print('OK')
    """, devices=4)
    assert "OK" in out


def test_elastic_checkpoint_restore_across_meshes():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, restore_checkpoint

        mesh_a = compat_make_mesh((4, 2), ('data', 'model'))
        x = jnp.arange(64.0).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh_a, P('data', 'model')))
        d = tempfile.mkdtemp()
        save_checkpoint(d, 1, {'x': xs})
        # restore onto a *different* mesh layout
        mesh_b = compat_make_mesh((2, 4), ('data', 'model'))
        like = {'x': jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        shard = {'x': NamedSharding(mesh_b, P('model', 'data'))}
        got, _ = restore_checkpoint(d, 1, like, shardings=shard)
        np.testing.assert_array_equal(np.asarray(got['x']), np.asarray(x))
        assert got['x'].sharding.spec == P('model', 'data')
        print('OK')
    """)
    assert "OK" in out
