"""HLO cost extraction: trip-count-aware FLOPs/collectives, roofline math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.launch.hlo_costs import analyze, parse_computations
from repro.launch.mesh import compat_cost_analysis
from repro.launch.roofline import (Roofline, model_flops, roofline_from_hlo,
                                   PEAK_FLOPS)
from repro.configs import get_arch, SHAPES


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_flat_scan_flops_exact():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = lax.scan(body, x, None, length=7)
        return y

    hc = analyze(_compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
                 .as_text(), 1)
    assert hc.dot_flops == 7 * 2 * 64 ** 3


def test_nested_scan_flops_exact():
    def g(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c2, _ = lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = lax.scan(outer, x, None, length=5)
        return y

    hc = analyze(_compile(g, jax.ShapeDtypeStruct((32, 32), jnp.float32))
                 .as_text(), 1)
    assert hc.dot_flops == 5 * 3 * 2 * 32 ** 3


def test_unrolled_matches_scan():
    def unrolled(x):
        for _ in range(4):
            x = x @ x
        return x

    def scanned(x):
        def body(c, _):
            return c @ c, None
        y, _ = lax.scan(body, x, None, length=4)
        return y

    spec = jax.ShapeDtypeStruct((48, 48), jnp.float32)
    a = analyze(_compile(unrolled, spec).as_text(), 1)
    b = analyze(_compile(scanned, spec).as_text(), 1)
    assert a.dot_flops == b.dot_flops == 4 * 2 * 48 ** 3


def test_xla_cost_analysis_undercounts_loops():
    """Documents WHY hlo_costs exists: XLA's cost analysis counts scan
    bodies once."""
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = lax.scan(body, x, None, length=16)
        return y

    compiled = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    xla_flops = compat_cost_analysis(compiled)["flops"]
    ours = analyze(compiled.as_text(), 1).dot_flops
    assert ours == 16 * 2 * 64 ** 3
    assert xla_flops < ours / 8          # massive undercount


def test_roofline_dominant_term():
    r = Roofline(compute_s=1.0, memory_s=2.0, collective_s=0.5,
                 flops_per_dev=1.0, bytes_per_dev=1.0, coll_bytes_per_dev=1.0,
                 model_flops=PEAK_FLOPS)
    assert r.dominant == "memory"
    assert r.bound_s == 2.0
    assert r.mfu_bound == pytest.approx(0.5)


def test_model_flops_conventions():
    cfg = get_arch("deepseek-7b")
    n = cfg.active_param_count()
    assert model_flops(cfg, SHAPES["train_4k"]) == \
        pytest.approx(6.0 * n * 4096 * 256)
    assert model_flops(cfg, SHAPES["decode_32k"]) == \
        pytest.approx(2.0 * n * 128)
    moe = get_arch("deepseek-v3-671b")
    assert model_flops(moe, SHAPES["train_4k"]) < \
        6.0 * moe.param_count() * 4096 * 256  # active, not total
