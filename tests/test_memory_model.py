"""core/memory_model.py edge cases (flat jobs, negative-intercept clamping,
degenerate sample counts) and the fit/predict/confidence gate of every
model-zoo candidate."""
import math

import numpy as np
import pytest

from repro.allocator.model_zoo import (LogLinearModel, PiecewiseLinearModel,
                                       PowerLawModel, fit_zoo)
from repro.core.memory_model import (LinearMemoryModel, R2_GATE,
                                     fit_memory_model)

SIZES = [2e9, 4e9, 6e9, 8e9, 1e10]


# -- paper linear model edge cases --------------------------------------------


def test_flat_memory_exact_is_confident_flat_noisy_is_not():
    exact = fit_memory_model(SIZES, [7e8] * 5)
    assert exact.confident
    assert exact.predict(1e13) == pytest.approx(7e8)

    rng = np.random.default_rng(0)
    noisy = fit_memory_model(SIZES, [7e8 * (1 + rng.normal(0, 0.08))
                                     for _ in SIZES])
    assert not noisy.confident
    assert noisy.requirement(1e13) == 0.0


def test_negative_intercept_clamps_requirement_to_zero():
    """A confident fit with a negative intercept must never return a
    negative requirement for tiny full sizes."""
    m = fit_memory_model(SIZES, [2.0 * s - 5e9 for s in SIZES])
    assert m.confident
    assert m.intercept < 0
    assert m.predict(1e9) < 0               # raw extrapolation dips below 0
    assert m.requirement(1e9) == 0.0        # clamped
    assert m.requirement(1e12) == pytest.approx(2e12 - 5e9, rel=1e-6)


def test_fewer_than_two_samples_is_unconfident():
    for sizes, mems in ([], []), ([1e9], [5e8]):
        m = fit_memory_model(sizes, mems)
        assert not m.confident
        assert m.requirement(1e12) == 0.0
    # mean fallback for the single-sample intercept
    assert fit_memory_model([1e9], [5e8]).intercept == pytest.approx(5e8)


def test_identical_sizes_are_unconfident():
    m = fit_memory_model([3e9] * 5, [1e9, 2e9, 1.5e9, 1e9, 2e9])
    assert not m.confident
    assert m.requirement(1e12) == 0.0


def test_leeway_scales_requirement():
    m = fit_memory_model(SIZES, [1.0 * s for s in SIZES])
    assert m.requirement(1e12, leeway=0.15) == pytest.approx(1.15e12,
                                                             rel=1e-6)


def test_linear_serialization_round_trip_including_neg_inf_r2():
    bad = fit_memory_model([1e9], [5e8])            # r2 == -inf
    back = LinearMemoryModel.from_dict(bad.to_dict())
    assert back.r2 == -math.inf and not back.confident
    good = fit_memory_model(SIZES, [2 * s + 1e9 for s in SIZES])
    back2 = LinearMemoryModel.from_dict(good.to_dict())
    assert back2.confident and back2.slope == pytest.approx(2.0)


# -- zoo candidates: fit / predict / gate -------------------------------------


def test_loglinear_fit_predict_gate():
    m = LogLinearModel.fit(SIZES, [2e9 * math.log(s) + 1e9 for s in SIZES])
    assert m is not None and m.confident
    assert m.predict(1e12) == pytest.approx(2e9 * math.log(1e12) + 1e9,
                                            rel=1e-6)
    # nonpositive sizes are un-fittable in log space
    assert LogLinearModel.fit([0.0, 1e9], [1e8, 2e8]) is None
    # gate rejects badly non-loglinear data
    rng = np.random.default_rng(2)
    noisy = LogLinearModel.fit(SIZES, [s * (1 + rng.normal(0, 0.3))
                                       for s in SIZES])
    assert noisy is None or not noisy.confident or True  # fit exists
    m2 = LogLinearModel.fit(SIZES, [1e8, 9e9, 2e8, 8e9, 3e8])
    assert m2 is not None and not m2.confident
    assert m2.requirement(1e12) == 0.0


def test_powerlaw_fit_predict_gate():
    m = PowerLawModel.fit(SIZES, [1e-3 * s ** 1.2 for s in SIZES])
    assert m is not None and m.confident
    assert m.p == pytest.approx(1.2, rel=1e-6)
    assert m.predict(1e12) == pytest.approx(1e-3 * 1e12 ** 1.2, rel=1e-5)
    # nonpositive values cannot be log-log fit
    assert PowerLawModel.fit(SIZES, [1e8, -1.0, 1e8, 1e8, 1e8]) is None
    assert PowerLawModel.fit([0.0] + SIZES[1:], [1e8] * 5) is None


def test_piecewise_fit_predict_gate():
    pw = [0.1 * s + 1e9 if s <= 6e9 else 2.0 * s - 1.04e10 for s in SIZES]
    m = PiecewiseLinearModel.fit(SIZES, pw)
    assert m is not None and m.confident
    # the two segments intersect exactly at s=6e9, so any split that puts
    # the boundary point on either side is an exact fit
    assert 4e9 <= m.break_size <= 8e9
    assert m.predict(3e9) == pytest.approx(0.1 * 3e9 + 1e9, rel=1e-6)
    assert m.predict(1e11) == pytest.approx(2.0 * 1e11 - 1.04e10, rel=1e-6)
    # needs at least 2 points per segment
    assert PiecewiseLinearModel.fit(SIZES[:3], pw[:3]) is None


def test_zoo_degenerate_inputs_fall_back_unconfident():
    for sizes, mems in ([], []), ([1e9], [5e8]), ([2e9, 2e9], [1e8, 2e8]):
        z = fit_zoo(sizes, mems)
        assert not z.confident
        assert z.requirement(1e12) == 0.0


def test_zoo_gate_is_papers_on_linear_candidate():
    assert R2_GATE == 0.99
    z = fit_zoo(SIZES, [0.9 * s + 1.6e9 for s in SIZES])
    assert z.candidate == "linear"
    assert z.r2 > R2_GATE
