"""Profiling orchestration subsystem: adaptive ladder scheduling (early
stop / escalation / budget exhaustion), the shared profiling budget, the
file-locked multi-process profile & anchor store, the locked registry's
merge-on-flush, and the AllocationService/CrispyAllocator/endpoint wiring.
"""
import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.allocator import (AllocationRequest, AllocationService,
                             ModelRegistry)
from repro.core.catalog import aws_like_catalog
from repro.core.crispy import CrispyAllocator
from repro.core.memory_model import fit_memory_model
from repro.core.profiler import ProfileResult
from repro.core.sampling import integer_ladder, ladder_from_anchor
from repro.core.simulator import (GiB, build_history, make_profile_fn,
                                  scout_like_jobs)
from repro.profiling import (AdaptiveLadderScheduler, FileLock,
                             LockedModelRegistry, ProfileStore,
                             ProfilingBudget, ProfilingExecutor,
                             calibrated_anchor)
from repro.serve.engine import AllocationEndpoint

FULL = 1e11
LADDER = ladder_from_anchor(FULL * 0.01).sizes


def _point_fn(mem_of_size, wall=10.0, calls=None):
    def profile_point(s):
        if calls is not None:
            calls.append(s)
        return ProfileResult(s, mem_of_size(s), 0.0, wall), True
    return profile_point


# -- budget -------------------------------------------------------------------


def test_budget_limits_and_refund():
    b = ProfilingBudget(max_points=2, charge_s=100.0)
    assert b.try_spend() and b.try_spend()
    assert not b.try_spend()                 # point limit
    assert b.denials == 1
    b.refund()
    assert b.try_spend()                     # refund reopened a slot
    b.charge(250.0)
    b2 = ProfilingBudget(charge_s=100.0)
    b2.charge(250.0)
    assert not b2.try_spend() and b2.exhausted()
    snap = b.snapshot()
    assert snap["points_spent"] == 2 and snap["charged_s"] == 250.0


def test_budget_thread_safety():
    b = ProfilingBudget(max_points=100)
    granted = []

    def worker():
        for _ in range(50):
            if b.try_spend():
                granted.append(1)

    ts = [threading.Thread(target=worker) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(granted) == 100               # never over-granted


# -- adaptive scheduler -------------------------------------------------------


def test_early_stop_on_clean_linear_job():
    """A perfectly linear job must stop at <= 3 of the 5 ladder points and
    still extrapolate exactly."""
    calls = []
    ap = AdaptiveLadderScheduler().run(
        LADDER, FULL, _point_fn(lambda s: 0.9 * s + 1.6e9, calls=calls))
    assert ap.early_stop
    assert ap.total_points <= 3 < len(LADDER)
    assert len(calls) == ap.points == ap.total_points
    assert ap.fit.confident
    truth = 0.9 * FULL + 1.6e9
    assert abs(ap.fit.predict(FULL) - truth) / truth < 1e-6
    # smallest-first: the points profiled are the cheapest ladder prefix
    assert ap.sizes == sorted(LADDER)[:ap.total_points]


def test_escalation_on_noisy_job():
    """Noisy data: candidates disagree at full size, the scheduler spends
    extra points beyond the base ladder, and stays unconfident."""
    rng = np.random.default_rng(3)
    noise = {}

    def mem(s):
        if s not in noise:
            noise[s] = 1 + rng.normal(0, 0.09)
        return s * noise[s]

    ap = AdaptiveLadderScheduler().run(LADDER, FULL, _point_fn(mem))
    assert ap.escalated
    assert ap.total_points > len(LADDER)
    assert not ap.early_stop
    assert not ap.fit.confident              # degrades like the paper
    assert ap.fit.requirement(FULL) == 0.0
    # escalation densifies the measured range, never extrapolates past it
    assert max(ap.sizes) <= max(LADDER)


def test_budget_exhaustion_mid_ladder_falls_back_gracefully():
    budget = ProfilingBudget(max_points=2)
    ap = AdaptiveLadderScheduler(budget=budget).run(
        LADDER, FULL, _point_fn(lambda s: 0.9 * s))
    assert ap.budget_exhausted
    assert ap.total_points == 2
    assert not ap.fit.confident              # 2 points never pass LOOCV
    assert ap.fit.requirement(FULL) == 0.0   # -> BFA fallback downstream
    # a budget that denies even the first point still returns a fit object
    ap0 = AdaptiveLadderScheduler(budget=ProfilingBudget(max_points=0)).run(
        LADDER, FULL, _point_fn(lambda s: s))
    assert ap0.budget_exhausted and ap0.total_points == 0
    assert not ap0.fit.confident


def test_budget_charges_reported_profile_seconds():
    """Simulated runs report minutes of wall time while taking micro-
    seconds; charging the *reported* seconds reproduces the envelope."""
    rng = np.random.default_rng(11)
    budget = ProfilingBudget(charge_s=25.0)
    ap = AdaptiveLadderScheduler(budget=budget).run(
        LADDER, FULL,
        _point_fn(lambda s: s * (1 + rng.normal(0, 0.2)), wall=10.0))
    # 10s per run: the third try_spend sees 20s charged < 25s, the fourth
    # sees 30s and is denied (noisy data never early-stops before then)
    assert ap.total_points == 3
    assert ap.budget_exhausted
    assert budget.charged_s == 30.0


def test_scheduler_with_papers_linear_fitter():
    """A custom (non-zoo) fitter drives the same early-stop logic."""
    ap = AdaptiveLadderScheduler(fitter=fit_memory_model).run(
        LADDER, FULL, _point_fn(lambda s: 2.0 * s))
    assert ap.early_stop and ap.total_points <= 3
    assert ap.fit.confident


def test_scheduler_knobs_apply_to_named_placement():
    """min_points/stability_rtol/... must reach the placer a placement
    NAME builds; an explicit placer instance keeps its own knobs."""
    from repro.pipeline import LadderPlacer
    s1 = AdaptiveLadderScheduler(stability_rtol=0.01, max_extra_points=0,
                                 placement="ladder")
    assert s1.placer.stability_rtol == 0.01
    assert s1.placer.max_extra_points == 0
    s2 = AdaptiveLadderScheduler(min_points=4, placement="infogain")
    assert s2.placer.name == "infogain" and s2.placer.min_points == 4
    mine = LadderPlacer(stability_rtol=0.2)
    assert AdaptiveLadderScheduler(stability_rtol=0.01,
                                   placement=mine).placer is mine


# -- persistent store ---------------------------------------------------------


def test_profile_store_round_trip_and_refresh(tmp_path):
    path = str(tmp_path / "prof.jsonl")
    s1 = ProfileStore(path)
    s1.put("sigA", 1e9, ProfileResult(1e9, 2e9, 0.0, 5.0))
    s1.put_anchor("sigA", 1e9)
    # a second handle (fresh process equivalent) sees everything
    s2 = ProfileStore(path)
    got = s2.get("sigA", 1e9)
    assert got is not None and got.peak_mem_bytes == 2e9
    assert s2.get_anchor("sigA") == 1e9
    # writes by the sibling appear after refresh, not before
    s2.put("sigB", 2e9, ProfileResult(2e9, 4e9, 0.0, 5.0))
    assert s1.get("sigB", 2e9) is None
    assert s1.refresh() >= 1
    assert s1.get("sigB", 2e9) is not None


def test_calibrated_anchor_skips_measurement_on_repeat(tmp_path):
    store = ProfileStore(str(tmp_path / "prof.jsonl"))
    runs = []

    def run_at(size):
        runs.append(size)
        return 1.0                           # lands in the target band

    a1 = calibrated_anchor(store, "sig", run_at, 1e9)
    assert runs                              # first time measures
    n = len(runs)
    a2 = calibrated_anchor(store, "sig", run_at, 1e9)
    assert a2 == a1 and len(runs) == n       # repeat skips entirely


def test_two_processes_share_locked_store_without_corruption(tmp_path):
    """Two real processes append profile points and flush registries
    concurrently; nothing is torn and no registry write is lost."""
    prof = str(tmp_path / "prof.jsonl")
    reg = str(tmp_path / "reg.json")
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    code = """
import sys
sys.path.insert(0, {src!r})
from repro.core.profiler import ProfileResult
from repro.core.memory_model import fit_memory_model
from repro.profiling import LockedModelRegistry, ProfileStore
tag = sys.argv[1]
store = ProfileStore({prof!r})
reg = LockedModelRegistry({reg!r})
sizes = [2e9, 4e9, 6e9, 8e9, 1e10]
for i in range(60):
    store.put(f"{{tag}}-{{i}}", float(i + 1),
              ProfileResult(float(i + 1), 1.0, 0.0, 0.1))
    if i % 10 == 0:
        m = fit_memory_model(sizes, [2 * s + 1e9 for s in sizes])
        reg.put(f"{{tag}}-model-{{i}}", m, defer_save=True)
        reg.flush()
""".format(src=src, prof=prof, reg=reg)
    procs = [subprocess.Popen([sys.executable, "-c", code, tag])
             for tag in ("a", "b")]
    for p in procs:
        assert p.wait() == 0
    # every JSONL row parses; both writers' rows all landed
    rows = [json.loads(line) for line in open(prof)]
    assert len(rows) == 120
    fresh = ProfileStore(prof)
    assert len(fresh) == 120
    assert fresh.get("a-0", 1.0) is not None
    assert fresh.get("b-59", 60.0) is not None
    # registry kept both processes' models (merge-on-flush, no lost writes)
    merged = LockedModelRegistry(reg)
    for tag in ("a", "b"):
        for i in (0, 50):
            assert f"{tag}-model-{i}" in merged, merged.signatures()


def test_two_service_processes_allocate_against_one_store(tmp_path):
    """Acceptance: two concurrent AllocationService *processes* over one
    ProfileStore + LockedModelRegistry complete all allocations with no
    lock errors, and neither process's registry writes are lost."""
    prof = str(tmp_path / "prof.jsonl")
    reg = str(tmp_path / "reg.json")
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    code = """
import sys
sys.path.insert(0, {src!r})
from repro.allocator import AllocationRequest, AllocationService
from repro.core.catalog import aws_like_catalog
from repro.core.simulator import (GiB, build_history, make_profile_fn,
                                  scout_like_jobs)
from repro.profiling import LockedModelRegistry, ProfileStore
which = int(sys.argv[1])
jobs = scout_like_jobs()
catalog = aws_like_catalog()
history = build_history(jobs, catalog)
# overlapping halves: [0..9] vs [6..15] -> contention on 4 signatures
mine = jobs[:10] if which == 0 else jobs[6:]
with AllocationService(catalog, history,
                       registry=LockedModelRegistry({reg!r}),
                       store=ProfileStore({prof!r}),
                       adaptive=True) as svc:
    for j in mine:
        full = j.dataset_gib * GiB
        r = svc.allocate(AllocationRequest(j.name, make_profile_fn(j),
                                           full, anchor=full * 0.01),
                         timeout=120)
        assert r.selection is not None
print("DONE", which)
""".format(src=src, prof=prof, reg=reg)
    procs = [subprocess.Popen([sys.executable, "-c", code, str(i)],
                              stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True)
             for i in (0, 1)]
    outs = [p.communicate(timeout=300) for p in procs]
    for p, (out, err) in zip(procs, outs):
        assert p.returncode == 0, err[-3000:]
        assert "DONE" in out
    # every confident-linear signature either process saw is registered
    merged = LockedModelRegistry(reg)
    jobs = scout_like_jobs()
    for j in jobs:
        if j.mem_profile == "linear":
            assert j.name in merged, (j.name, merged.signatures())
    # the shared profile JSONL is uncorrupted
    for line in open(prof):
        json.loads(line)


def test_file_lock_times_out_instead_of_deadlocking(tmp_path):
    path = str(tmp_path / "x.lock")
    with FileLock(path):
        with pytest.raises(TimeoutError):
            # same-process second fd: flock blocks -> bounded wait
            FileLock(path, timeout_s=0.2).acquire()


# -- service / crispy / endpoint wiring ---------------------------------------


@pytest.fixture(scope="module")
def corpus():
    jobs = scout_like_jobs()
    catalog = aws_like_catalog()
    return jobs, catalog, build_history(jobs, catalog)


def _req(job, **kw):
    full = job.dataset_gib * GiB
    return AllocationRequest(job.name, make_profile_fn(job), full,
                             anchor=full * 0.01, **kw)


def test_service_adaptive_uses_fewer_points(corpus, tmp_path):
    jobs, catalog, history = corpus
    linear = [j for j in jobs
              if j.mem_profile == "linear"][:3]
    fixed_req = {}
    with AllocationService(catalog, history,
                           registry=ModelRegistry()) as svc_fixed:
        for j in linear:
            fixed_req[j.name] = svc_fixed.allocate(
                _req(j)).requirement_gib
    with AllocationService(catalog, history, registry=ModelRegistry(),
                           adaptive=True) as svc:
        for j in linear:
            r = svc.allocate(_req(j))
            assert r.source == "zoo"
            assert r.early_stop
            assert r.profiled < 5            # strictly fewer than the ladder
            drift = abs(r.requirement_gib - fixed_req[j.name]) \
                / fixed_req[j.name]
            assert drift < 0.05              # within 5% of the fixed ladder
        assert svc.stats.adaptive_plans == len(linear)
        assert svc.stats.early_stops == len(linear)
        assert svc.stats.points_saved >= 2 * len(linear)


def test_service_budget_exhaustion_falls_back(corpus):
    jobs, catalog, history = corpus
    km = jobs[2]
    budget = ProfilingBudget(max_points=2)
    with AllocationService(catalog, history, registry=ModelRegistry(),
                           adaptive=True, budget=budget) as svc:
        r = svc.allocate(_req(km))
        assert r.budget_exhausted
        assert r.source in ("classifier", "baseline")
        assert r.selection is not None       # still answered
        assert svc.stats.budget_denied >= 1


def test_budget_exhausted_plan_is_not_sticky(corpus):
    """A plan cut short by the budget must not be served from the negative
    plan cache once the budget recovers."""
    jobs, catalog, history = corpus
    km = jobs[2]
    budget = ProfilingBudget(max_points=2)
    with AllocationService(catalog, history, registry=ModelRegistry(),
                           adaptive=True, budget=budget) as svc:
        first = svc.allocate(_req(km))
        assert first.budget_exhausted
        assert first.source in ("classifier", "baseline")
        budget.refund(2)                     # budget recovers
        again = svc.allocate(_req(km))
        assert not again.budget_exhausted    # re-planned, not cache-served
        assert again.source == "zoo"         # and now profiles to success


def test_exhausted_budget_still_serves_cached_points(corpus, tmp_path):
    """An exhausted budget never denies points that are already in the
    shared store — cached work is free."""
    jobs, catalog, history = corpus
    km = jobs[2]
    path = str(tmp_path / "prof.jsonl")
    with AllocationService(catalog, history, registry=ModelRegistry(),
                           store=ProfileStore(path)) as warm:
        warm.allocate(_req(km))              # populates the store
    dead = ProfilingBudget(max_points=0)
    with AllocationService(catalog, history, registry=ModelRegistry(),
                           store=ProfileStore(path), adaptive=True,
                           budget=dead) as svc:
        r = svc.allocate(_req(km))
        assert r.source == "zoo"             # full plan from cached points
        assert r.profiled == 0
        assert not r.budget_exhausted
        assert svc.stats.store_hits >= 3


def test_service_shared_store_skips_sibling_profiles(corpus, tmp_path):
    """Points profiled by one service are store-hits for the next (the
    restart / sibling-process path)."""
    jobs, catalog, history = corpus
    km = jobs[2]
    path = str(tmp_path / "prof.jsonl")
    with AllocationService(catalog, history, registry=ModelRegistry(),
                           store=ProfileStore(path)) as svc1:
        svc1.allocate(_req(km))
        assert svc1.stats.profile_calls == 5
    with AllocationService(catalog, history, registry=ModelRegistry(),
                           store=ProfileStore(path)) as svc2:
        r = svc2.allocate(_req(km))
        assert svc2.stats.profile_calls == 0
        assert svc2.stats.store_hits == 5
        assert r.profiled == 0 and r.cache_hits == 5


def test_service_persisted_anchor_shapes_repeat_ladders(corpus, tmp_path):
    jobs, catalog, history = corpus
    km = jobs[2]
    store = ProfileStore(str(tmp_path / "prof.jsonl"))
    anchor = km.dataset_gib * GiB * 0.02
    with AllocationService(catalog, history, registry=ModelRegistry(),
                           store=store) as svc:
        svc.allocate(_req(km, anchor=None, sizes=None) if False
                     else AllocationRequest(km.name, make_profile_fn(km),
                                            km.dataset_gib * GiB,
                                            anchor=anchor))
        assert store.get_anchor(km.name) == anchor
        # anchor-less repeat reuses the persisted anchor -> same ladder ->
        # pure cache hits, zero fresh profiling
        r = svc.allocate(AllocationRequest(km.name, make_profile_fn(km),
                                           km.dataset_gib * GiB))
        assert r.profiled == 0


def test_service_executor_concurrent_signatures(corpus):
    jobs, catalog, history = corpus
    with ProfilingExecutor(max_workers=4) as ex:
        with AllocationService(catalog, history, registry=ModelRegistry(),
                               executor=ex, batch_window_s=0.05) as svc:
            futs = [svc.submit(_req(j)) for j in jobs[:6]]
            rs = [f.result(timeout=120) for f in futs]
            assert all(r.selection is not None for r in rs)
            # dedup still holds under concurrent group planning
            assert svc.stats.profile_calls <= 5 * 6


def test_crispy_allocator_adaptive_path(corpus):
    from repro.allocator.model_zoo import zoo_fitter
    jobs, catalog, history = corpus
    km = jobs[2]
    full = km.dataset_gib * GiB
    alloc = CrispyAllocator(catalog, history, overhead_per_node_gib=2.0,
                            fitter=zoo_fitter())
    fixed = alloc.allocate(km.name, make_profile_fn(km), full,
                           anchor=full * 0.01)
    adapt = alloc.allocate(km.name, make_profile_fn(km), full,
                           anchor=full * 0.01, adaptive=True)
    assert adapt.early_stop
    assert adapt.points_profiled < fixed.points_profiled == 5
    assert adapt.model.confident
    drift = abs(adapt.requirement_gib - fixed.requirement_gib) \
        / fixed.requirement_gib
    assert drift < 0.05
    # passing only a budget also routes through the scheduler
    b = ProfilingBudget(max_points=2)
    cut = alloc.allocate(km.name, make_profile_fn(km), full,
                         anchor=full * 0.01, budget=b)
    assert cut.budget_exhausted and cut.points_profiled == 2


def test_endpoint_wire_and_stats_surface_adaptive_fields(corpus):
    jobs, catalog, history = corpus
    km = jobs[2]
    budget = ProfilingBudget(max_points=50)
    with AllocationService(catalog, history, registry=ModelRegistry(),
                           adaptive=True, budget=budget) as svc:
        ep = AllocationEndpoint(svc)
        wire = ep.handle(job=km.name, profile_at=make_profile_fn(km),
                         full_size=km.dataset_gib * GiB,
                         anchor=km.dataset_gib * GiB * 0.01)
        assert wire["early_stop"] is True
        assert wire["escalated"] is False
        assert wire["budget_exhausted"] is False
        assert wire["profiled"] < 5
        stats = ep.stats()
        assert stats["adaptive_plans"] == 1
        assert stats["early_stops"] == 1
        assert stats["points_saved"] >= 2
        assert stats["budget"]["points_spent"] == wire["profiled"]


def test_request_level_adaptive_override(corpus):
    """adaptive=False service, adaptive=True request (and vice versa)."""
    jobs, catalog, history = corpus
    nb = jobs[0]
    with AllocationService(catalog, history,
                           registry=ModelRegistry()) as svc:
        r = svc.allocate(_req(nb, adaptive=True))
        assert r.early_stop and r.profiled < 5
    with AllocationService(catalog, history, registry=ModelRegistry(),
                           adaptive=True) as svc:
        r = svc.allocate(_req(nb, adaptive=False))
        assert not r.early_stop and r.profiled == 5


def test_integer_ladder_clamps_small_anchor():
    """Regression: the anchor <= lo branch returned the anchor unclamped
    (dead `* 0 or` expression) — 0/negative anchors leaked through."""
    assert integer_ladder(0) == [1]
    assert integer_ladder(-4) == [1]
    assert integer_ladder(1) == [1]
    assert integer_ladder(3, lo=8) == [3]
    assert integer_ladder(40) == [1, 11, 20, 30, 40]
