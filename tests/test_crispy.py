"""Crispy core: memory model, selection, and the paper's structural claims
on the simulated corpus. Property-based tests via hypothesis when it is
installed; deterministic parametrized equivalents always run, so the tier-1
suite does not require hypothesis."""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.catalog import aws_like_catalog, medium_config
from repro.core.crispy import CrispyAllocator
from repro.core.history import ExecutionHistory
from repro.core.memory_model import R2_GATE, fit_memory_model
from repro.core.selector import (random_expected_cost, select_bfa,
                                 select_crispy, select_medium)
from repro.core.simulator import (OVERHEAD_GIB, build_history, cost_usd,
                                  make_profile_fn, scout_like_jobs)

GiB = 1024 ** 3


# -- memory model -------------------------------------------------------------


def _check_linear_confident_and_exact(slope, intercept, anchor):
    sizes = [anchor * f for f in (0.2, 0.4, 0.6, 0.8, 1.0)]
    mems = [slope * s + intercept for s in sizes]
    m = fit_memory_model(sizes, mems)
    assert m.confident
    full = anchor * 50
    assert math.isclose(m.predict(full), slope * full + intercept,
                        rel_tol=1e-6)


def _check_noisy_falls_back(noise, seed):
    rng = np.random.default_rng(seed)
    sizes = np.array([2, 4, 6, 8, 10], dtype=float) * 1e9
    mems = sizes * (1 + rng.normal(0, noise, 5)) + 1e9
    m = fit_memory_model(sizes, mems)
    # either gate rejects, or (rarely) the noise draw happens to be linear;
    # requirement(.) must be 0 whenever not confident
    if not m.confident:
        assert m.requirement(1e12) == 0.0


@pytest.mark.parametrize("slope,intercept,anchor",
                         [(0.9, 0.0, 1e9), (4.5, 1.6e9, 1e11),
                          (0.01, 1e9, 1e6), (100.0, 5e8, 1e12)])
def test_linear_data_is_confident_and_exact(slope, intercept, anchor):
    _check_linear_confident_and_exact(slope, intercept, anchor)


@pytest.mark.parametrize("noise,seed",
                         [(0.08, 0), (0.2, 7), (0.5, 42), (0.35, 999)])
def test_noisy_data_falls_back(noise, seed):
    _check_noisy_falls_back(noise, seed)


if HAVE_HYPOTHESIS:
    @given(slope=st.floats(0.01, 100), intercept=st.floats(0, 1e9),
           anchor=st.floats(1e6, 1e12))
    @settings(max_examples=50, deadline=None)
    def test_linear_data_is_confident_and_exact_prop(slope, intercept,
                                                     anchor):
        _check_linear_confident_and_exact(slope, intercept, anchor)

    @given(noise=st.floats(0.08, 0.5), seed=st.integers(0, 1000))
    @settings(max_examples=30, deadline=None)
    def test_noisy_data_falls_back_prop(noise, seed):
        _check_noisy_falls_back(noise, seed)


def test_constant_memory_is_confident():
    m = fit_memory_model([1, 2, 3, 4, 5], [7.0] * 5)
    assert m.confident
    assert m.predict(100) == pytest.approx(7.0)


def test_gate_threshold_is_papers():
    assert R2_GATE == 0.99


# -- selection ----------------------------------------------------------------


@pytest.mark.parametrize("req", [0.0, 1.0, 63.9, 500.0, 2831.0, 5000.0])
def test_crispy_selection_respects_feasibility(req):
    catalog = aws_like_catalog()
    hist = build_history()
    sel = select_crispy(catalog, hist, req, overhead_per_node_gib=2.0)
    usable = sel.config.usable_mem_gib(2.0)
    biggest = max(c.usable_mem_gib(2.0) for c in catalog)
    assert usable >= min(req, biggest) - 1e-9


if HAVE_HYPOTHESIS:
    @given(req=st.floats(0, 5000))
    @settings(max_examples=40, deadline=None)
    def test_crispy_selection_respects_feasibility_prop(req):
        test_crispy_selection_respects_feasibility(req)


def test_bfa_scores_precomputed_and_invalidated_on_add():
    """The BFA scan is one memoized table per (history state, exclude_job);
    a new execution must invalidate it, not serve stale ranks."""
    from repro.core.history import Execution, ExecutionHistory
    catalog = aws_like_catalog()[:4]
    hist = ExecutionHistory([
        Execution("j1", catalog[0].name, 100.0, 1.0),
        Execution("j1", catalog[1].name, 100.0, 2.0),
        Execution("j2", catalog[0].name, 100.0, 3.0),
        Execution("j2", catalog[1].name, 100.0, 3.0),
    ])
    s1 = hist.bfa_scores()
    assert hist.bfa_scores() is s1              # memoized (same table)
    assert select_bfa(catalog[:2], hist).name == catalog[0].name
    assert hist.mean_normalized_cost(catalog[1].name) == \
        pytest.approx((2.0 + 1.0) / 2)
    # j3 strongly prefers config 1 -> the ranking must flip after add()
    hist.add(Execution("j3", catalog[0].name, 100.0, 50.0))
    hist.add(Execution("j3", catalog[1].name, 100.0, 1.0))
    s2 = hist.bfa_scores()
    assert s2 is not s1                         # invalidated
    assert select_bfa(catalog[:2], hist).name == catalog[1].name
    # exclude_job views are cached independently and also refreshed
    excl = hist.bfa_scores(exclude_job="j3")
    assert excl[catalog[0].name] < excl[catalog[1].name]


def test_zero_requirement_degenerates_to_bfa():
    catalog = aws_like_catalog()
    hist = build_history()
    bfa = select_bfa(catalog, hist)
    sel = select_crispy(catalog, hist, 0.0)
    assert sel.config.name == bfa.name
    assert sel.fell_back


def test_medium_config_is_m4_xlarge_12():
    """Paper's Medium baseline: 12 x m4.xlarge in this catalog shape."""
    m = medium_config(aws_like_catalog())
    assert m.node.name == "m4.xlarge"
    assert m.scale_out in (12, 16)


# -- the paper's structural claims on the simulated corpus --------------------


@pytest.fixture(scope="module")
def corpus():
    jobs = scout_like_jobs()
    catalog = aws_like_catalog()
    history = build_history(jobs, catalog)
    return jobs, catalog, history


def _crispy_cost(job, catalog, history):
    alloc = CrispyAllocator(catalog, history, overhead_per_node_gib=2.0)
    profile = make_profile_fn(job)
    full = job.dataset_gib * GiB
    rep = alloc.allocate(job.name, profile, full, anchor=full * 0.01)
    nc = history.normalized_costs(job.name)
    return nc[rep.selection.config.name], rep


def test_crispy_never_worse_than_bfa(corpus):
    """Paper §IV-E: 'Crispy has shown to be as good or better than the
    baseline approach for each of the 16 jobs'."""
    jobs, catalog, history = corpus
    for job in jobs:
        bfa = select_bfa(catalog, history, exclude_job=job.name)
        nc = history.normalized_costs(job.name)
        c_crispy, rep = _crispy_cost(job, catalog, history)
        c_bfa = nc[bfa.name]
        assert c_crispy <= c_bfa + 1e-6, \
            f"{job.name}: crispy {c_crispy:.3f} > bfa {c_bfa:.3f}"


def test_crispy_beats_baselines_on_mean(corpus):
    """Paper Table I bottom row ordering: Crispy < BFA < Medium < Random."""
    jobs, catalog, history = corpus
    means = {"random": [], "medium": [], "bfa": [], "crispy": []}
    med = select_medium(catalog)
    for job in jobs:
        nc = history.normalized_costs(job.name)
        means["random"].append(random_expected_cost(catalog, history,
                                                    job.name))
        means["medium"].append(nc[med.name])
        means["bfa"].append(
            nc[select_bfa(catalog, history, exclude_job=job.name).name])
        means["crispy"].append(_crispy_cost(job, catalog, history)[0])
    m = {k: float(np.mean(v)) for k, v in means.items()}
    assert m["crispy"] < m["bfa"] < m["random"]
    assert m["crispy"] < m["medium"]


def test_bottleneck_jobs_gain_most(corpus):
    """K-Means (iterative, caching, linear profile) must see an integer-
    factor improvement from BFA — the Fig. 1 cliff."""
    jobs, catalog, history = corpus
    km = [j for j in jobs if j.name.startswith("kmeans")][0]
    nc = history.normalized_costs(km.name)
    bfa_cost = nc[select_bfa(catalog, history, exclude_job=km.name).name]
    crispy_cost, rep = _crispy_cost(km, catalog, history)
    assert rep.model.confident                      # the profile is linear
    assert rep.requirement_gib > 0
    assert bfa_cost / crispy_cost > 1.5


def test_nonlinear_jobs_fall_back(corpus):
    jobs, catalog, history = corpus
    lr = [j for j in jobs if j.name.startswith("logregression")][0]
    _, rep = _crispy_cost(lr, catalog, history)
    assert not rep.model.confident
    assert rep.selection.fell_back


def test_hadoop_jobs_flat_profile(corpus):
    jobs, catalog, history = corpus
    ts = [j for j in jobs if j.name.startswith("terasort")][0]
    _, rep = _crispy_cost(ts, catalog, history)
    assert rep.requirement_gib == 0.0 or rep.requirement_gib < 2.0


def test_memory_bottleneck_cliff_exists(corpus):
    """Ground-truth cost model shows the Fig. 1 step: for K-Means, configs
    whose memory fits are much cheaper than slightly-too-small ones of the
    same family."""
    jobs, catalog, history = corpus
    km = [j for j in jobs if j.name == "kmeans/spark/bigdata"][0]
    rs = [c for c in catalog if c.node.name == "r4.2xlarge"]
    costs = {c.scale_out: cost_usd(km, c) for c in rs}
    ws = km.working_set_gib
    fits = [s for s, c in costs.items()
            if s * (61.0 - OVERHEAD_GIB) >= ws]
    not_fits = [s for s in costs if s not in fits]
    if fits and not_fits:
        # cost per fitting config should undercut the best non-fitting one
        assert min(costs[s] for s in fits) < min(costs[s] for s in not_fits)
