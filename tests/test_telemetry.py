"""Telemetry plane (repro.telemetry): concurrent counter/histogram
exactness, span nesting + thread isolation, exporter round-trips, fleet
snapshots over a live daemon from a second process, the daemon `metrics`
op on both transports, and the <5% warm-start overhead regression pin."""
import json
import os
import socket
import subprocess
import sys
import tempfile
import threading
import time

import pytest

from repro.allocator.registry import ModelRegistry
from repro.core.catalog import aws_like_catalog
from repro.core.simulator import (GiB, build_history, make_profile_fn,
                                  scout_like_jobs)
from repro.pipeline import AllocationPipeline, PipelineRequest
from repro.state import CrispyDaemon, DaemonBackend, InMemoryBackend
from repro.telemetry import (MetricsRegistry, StructuredLogger, TraceRing,
                             aggregate_fleet, current_span, fleet_snapshot,
                             publish_snapshot, render_json,
                             render_prometheus, span, span_if)

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")
needs_unix_sockets = pytest.mark.skipif(
    not hasattr(socket, "AF_UNIX"),
    reason="unix-domain sockets unavailable")


def _daemon_socket() -> str:
    # AF_UNIX paths are length-limited (~108 bytes); use a short tempdir
    d = tempfile.mkdtemp(prefix="crispyt-")
    return os.path.join(d, "d.sock")


# -- metrics: concurrent exactness --------------------------------------------


def test_counter_and_histogram_exact_under_8_threads():
    """Per-thread shards must lose nothing: 8 threads x 5000 increments
    and observations fold to exact totals."""
    reg = MetricsRegistry()
    c = reg.counter("hammer.count")
    h = reg.histogram("hammer.seconds")
    per_thread, threads = 5000, 8
    barrier = threading.Barrier(threads)

    def work(tid):
        barrier.wait()                 # maximize interleaving
        for i in range(per_thread):
            c.inc()
            h.observe((tid + 1) * 1e-5)

    ts = [threading.Thread(target=work, args=(tid,))
          for tid in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()

    assert c.value == threads * per_thread
    s = h.summary()
    assert s["count"] == threads * per_thread
    assert s["min"] == pytest.approx(1e-5)
    assert s["max"] == pytest.approx(8e-5)
    assert s["sum"] == pytest.approx(
        sum((tid + 1) * 1e-5 for tid in range(threads)) * per_thread)
    assert sum(s["buckets"]) == s["count"]
    assert 0 < s["p50"] <= s["p99"] <= s["max"]


def test_registry_caches_instruments_and_rejects_kind_conflicts():
    reg = MetricsRegistry()
    assert reg.counter("a.b") is reg.counter("a.b")
    assert reg.histogram("a.c") is reg.histogram("a.c")
    with pytest.raises(ValueError):
        reg.histogram("a.b")           # already a counter
    with pytest.raises(ValueError):
        reg.gauge("a.c")               # already a histogram


def test_disabled_registry_is_inert():
    reg = MetricsRegistry(enabled=False)
    c, h, g = reg.counter("x"), reg.histogram("y"), reg.gauge("z")
    c.inc()
    h.observe(1.0)
    g.set(3.0)
    assert c.value == 0.0 and h.count == 0 and g.value == 0.0
    snap = reg.snapshot()
    assert snap["counters"] == {} and snap["histograms"] == {}


# -- spans: nesting + thread isolation ----------------------------------------


def test_span_nesting_builds_tree_in_private_ring():
    ring = TraceRing()
    with span("root", ring=ring, job="j1") as root:
        with span("child-a", ring=ring):
            with span("grandchild", ring=ring):
                pass
        with span("child-b", ring=ring):
            pass
    assert current_span() is None
    traces = ring.traces()
    assert [t.name for t in traces] == ["root"]
    assert root.attrs == {"job": "j1"}
    assert [c.name for c in root.children] == ["child-a", "child-b"]
    assert [g.name for g in root.children[0].children] == ["grandchild"]
    assert root.wall_s >= root.children[0].wall_s >= \
        root.children[0].children[0].wall_s >= 0.0
    d = root.to_dict()
    assert d["children"][0]["children"][0]["name"] == "grandchild"
    json.dumps(d)                      # export-safe


def test_spans_are_thread_isolated():
    """contextvars keep each thread's current-span chain private: two
    threads nesting concurrently never splice into each other's trees."""
    ring = TraceRing()
    barrier = threading.Barrier(4)

    def work(tid):
        with span(f"root-{tid}", ring=ring):
            barrier.wait()             # all four roots open at once
            with span(f"inner-{tid}", ring=ring):
                assert current_span().name == f"inner-{tid}"
        assert current_span() is None

    ts = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()

    roots = {t.name: t for t in ring.traces()}
    assert set(roots) == {f"root-{i}" for i in range(4)}
    for i in range(4):
        r = roots[f"root-{i}"]
        assert [c.name for c in r.children] == [f"inner-{i}"]


def test_span_if_disabled_is_noop():
    ring = TraceRing()
    with span_if(False, "nope", ring=ring) as s:
        assert s is None and current_span() is None
    assert len(ring) == 0


# -- exporters ----------------------------------------------------------------


def _sample_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    reg.counter("req.total").inc(7)
    reg.gauge("queue.depth").set(3)
    h = reg.histogram("req.seconds")
    for v in (0.001, 0.002, 0.004, 0.1):
        h.observe(v)
    return reg


def test_render_json_round_trips():
    reg = _sample_registry()
    snap = json.loads(render_json(reg))
    assert snap == reg.snapshot()
    assert snap["counters"]["req.total"] == 7
    assert snap["histograms"]["req.seconds"]["count"] == 4


def test_render_prometheus_exposition():
    text = render_prometheus(_sample_registry())
    lines = text.splitlines()
    assert "crispy_req_total_total 7" in lines
    assert "crispy_queue_depth 3" in lines
    assert "# TYPE crispy_req_seconds histogram" in lines
    assert "crispy_req_seconds_count 4" in lines
    # cumulative buckets: the +Inf series equals the count
    assert 'crispy_req_seconds_bucket{le="+Inf"} 4' in lines
    # every metric name survives the sanitizer (alnum + underscore only)
    for ln in lines:
        if not ln.startswith("#"):
            name = ln.split("{")[0].split(" ")[0]
            assert name.replace("_", "").isalnum(), ln


def test_fleet_publish_and_aggregate_in_memory():
    backend = InMemoryBackend()
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("req.total").inc(3)
    b.counter("req.total").inc(4)
    a.histogram("req.seconds").observe(0.001)
    b.histogram("req.seconds").observe(0.1)
    publish_snapshot(backend, "svc-a", a)
    publish_snapshot(backend, "svc-b", b)
    publish_snapshot(backend, "svc-a", a)      # later row wins per source

    fleet = fleet_snapshot(backend)
    assert set(fleet) == {"svc-a", "svc-b"}
    agg = aggregate_fleet(fleet)
    assert agg["sources"] == ["svc-a", "svc-b"]
    assert agg["counters"]["req.total"] == 7
    h = agg["histograms"]["req.seconds"]
    assert h["count"] == 2
    assert h["sum"] == pytest.approx(0.101)
    assert h["min"] == pytest.approx(0.001)
    assert h["max"] == pytest.approx(0.1)
    assert h["p50"] <= h["p99"] <= h["max"]


# -- structured logging -------------------------------------------------------


def test_structured_logger_emits_parseable_lines_and_levels():
    import io
    buf = io.StringIO()
    log = StructuredLogger("unit", stream=buf, level="info")
    log.debug("dropped")               # below threshold
    log.info("served", n=3, addr="unix:/tmp/x")
    log.error("boom", error=ValueError("nope"))    # stringified, not raised
    lines = [json.loads(ln) for ln in buf.getvalue().splitlines()]
    assert [r["event"] for r in lines] == ["served", "boom"]
    assert lines[0]["component"] == "unit" and lines[0]["n"] == 3
    assert lines[1]["level"] == "error" and "nope" in lines[1]["error"]


# -- daemon: metrics op on both transports + cross-process fleet --------------


@needs_unix_sockets
def test_daemon_metrics_op_over_unix_and_tcp():
    sock = _daemon_socket()
    with CrispyDaemon(sock, listen="127.0.0.1:0") as d:
        for target in (sock, d.tcp_address):
            be = DaemonBackend(target)
            try:
                be.append("ns", {"x": 1})
                be.metrics()
                # an op's own wall is observed AFTER its response is
                # built, so daemon.op.metrics.seconds shows up from the
                # second metrics call on
                m = be.metrics()
                assert m["counters"]["daemon.frames"] >= 3
                assert m["counters"]["daemon.bytes_in"] > 0
                assert "daemon.op.append.seconds" in m["histograms"]
                assert "daemon.op.metrics.seconds" in m["histograms"]
                assert m["histograms"]["daemon.op.append.seconds"][
                    "count"] >= 1
            finally:
                be.close()


_PUBLISHER = """
import sys
sys.path.insert(0, {src!r})
from repro.state import DaemonBackend
from repro.telemetry import MetricsRegistry, publish_snapshot
backend = DaemonBackend(sys.argv[1])
reg = MetricsRegistry()
reg.counter("child.requests").inc(11)
reg.histogram("child.seconds").observe(0.002)
publish_snapshot(backend, "svc-child", reg)
backend.close()
print("published")
"""


@needs_unix_sockets
def test_fleet_snapshot_spans_processes_via_daemon():
    """A second real process publishes its snapshot through the daemon;
    this process sees it next to its own in one fleet view."""
    sock = _daemon_socket()
    with CrispyDaemon(sock):
        proc = subprocess.run(
            [sys.executable, "-c", _PUBLISHER.format(src=SRC), sock],
            capture_output=True, text=True, timeout=60)
        assert proc.returncode == 0, proc.stderr[-3000:]
        assert "published" in proc.stdout

        mine = MetricsRegistry()
        mine.counter("parent.requests").inc(5)
        be = DaemonBackend(sock)
        try:
            publish_snapshot(be, "svc-parent", mine)
            fleet = fleet_snapshot(be)
        finally:
            be.close()

    assert set(fleet) == {"svc-child", "svc-parent"}
    agg = aggregate_fleet(fleet)
    assert agg["counters"]["child.requests"] == 11
    assert agg["counters"]["parent.requests"] == 5
    assert agg["histograms"]["child.seconds"]["count"] == 1


# -- the overhead pin ---------------------------------------------------------


def _warm_pipeline(enabled: bool):
    corpus = scout_like_jobs()
    job = next(j for j in corpus if j.mem_profile == "linear")
    catalog = aws_like_catalog()
    history = build_history(corpus, catalog)
    pipe = AllocationPipeline(catalog, history, registry=ModelRegistry(),
                              telemetry=MetricsRegistry(enabled=enabled))
    req = PipelineRequest(job.name, make_profile_fn(job),
                         job.dataset_gib * GiB)
    pipe.run(req)                              # register a confident model
    assert pipe.warm_start(job.name) is not None
    return pipe, req


def test_warm_start_overhead_within_5_percent():
    """Acceptance pin: a warm-start plan with telemetry ENABLED stays
    within 5% of a no-op'd registry. Measured as min-of-interleaved-
    rounds (the min estimator converges on the true floor and is robust
    to scheduler noise); rounds keep adding until the pin holds or the
    round budget runs out, since extra rounds can only sharpen both
    floors, never fake a pass."""
    pe, re_ = _warm_pipeline(enabled=True)
    pd, rd = _warm_pipeline(enabled=False)
    n = 400

    def round_(pipe, req):
        t0 = time.perf_counter()
        for _ in range(n):
            pipe.run(req)
        return (time.perf_counter() - t0) / n

    on = off = float("inf")
    for i in range(24):
        on = min(on, round_(pe, re_))
        off = min(off, round_(pd, rd))
        if i >= 5 and on <= off * 1.05:
            break
    assert on <= off * 1.05, (
        f"telemetry overhead {((on / off) - 1) * 100:.2f}% on the warm "
        f"path (enabled {on * 1e6:.2f}us vs disabled {off * 1e6:.2f}us) "
        f"exceeds the 5% pin")
    # and the enabled run actually recorded: exact warm-hit counters
    snap = pe.telemetry.snapshot()
    assert snap["counters"]["pipeline.warm_start.hits"] > 0
