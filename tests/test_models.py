"""Per-architecture smoke tests (reduced configs, CPU) + numerical
equivalence of the optimized attention/SSM paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import RunConfig
from repro.models import attention as A
from repro.models import build_model, analytic_param_count
from repro.models.rwkv import wkv_chunked, wkv_recurrent
from repro.models.ssm import ssd_chunked, ssd_recurrent

KEY = jax.random.PRNGKey(0)
RUN32 = RunConfig(attn_impl="full", remat="nothing", compute_dtype="float32")


def batch_for(cfg, tokens):
    b = {"tokens": tokens, "labels": tokens}
    B = tokens.shape[0]
    if cfg.family == "vlm":
        b["media"] = 0.1 * jnp.ones(
            (B, cfg.cross_attn.n_media_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        b["frames"] = 0.1 * jnp.ones(
            (B, cfg.encdec.enc_len, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one SGD-free train step on a reduced config: output
    shapes correct, loss finite, grads finite."""
    cfg = ARCHS[arch].reduced()
    m = build_model(cfg, RUN32)
    params = m.init(KEY)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = batch_for(cfg, toks)
    logits, _ = m.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, grads = jax.value_and_grad(
        lambda p: m.loss_fn(p, batch)[0])(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_decode_consistency(arch):
    """Token-by-token decode reproduces the full forward logits (f32)."""
    cfg = ARCHS[arch].reduced()
    m = build_model(cfg, RUN32)
    params = m.init(KEY)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    fb = batch_for(cfg, toks)
    lg_full, _ = m.forward(params, fb)
    caches = m.init_caches(B, S)
    lgs = []
    for t in range(S):
        db = batch_for(cfg, toks[:, t:t + 1])
        db.pop("labels")
        if cfg.family == "audio":
            import repro.models.transformer as T
            import repro.models.layers as L
            frames = fb["frames"]
            enc = frames + T._sinusoid(frames.shape[1], cfg.d_model,
                                       frames.dtype)
            enc, _ = T.stack(params["layers"]["enc"], enc, cfg, RUN32,
                             kind="dense",
                             positions=jnp.arange(frames.shape[1]),
                             causal=False)
            db["enc_out"] = L.rms_norm(enc, params["layers"]["enc_ln"],
                                       cfg.norm_eps)
            db.pop("frames", None)
        lg, caches = m.decode_step(params, db, caches)
        lgs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(lg_full - jnp.stack(lgs, 1))))
    assert err < 5e-4, f"{arch}: decode mismatch {err}"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_count_analytic_matches_init(arch):
    cfg = ARCHS[arch].reduced()
    m = build_model(cfg, RUN32)
    params = m.init(KEY)
    real = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    assert analytic_param_count(cfg) == real
    active = analytic_param_count(cfg, active_only=True)
    assert 0 < active <= real
    if cfg.moe is not None:
        assert active < real


def test_full_configs_match_spec():
    """The full configs carry the exact assigned hyperparameters."""
    c = ARCHS["deepseek-v3-671b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == \
        (61, 7168, 128, 129280)
    assert c.moe.n_experts == 256 and c.moe.top_k == 8
    assert c.mla.kv_lora_rank == 512
    c = ARCHS["mistral-large-123b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (88, 12288, 96, 8, 28672, 32768)
    # param count of mistral-large should be ~123B
    n = analytic_param_count(c)
    assert 110e9 < n < 135e9, n
    n = analytic_param_count(ARCHS["deepseek-7b"])
    assert 6e9 < n < 8e9, n
    n = analytic_param_count(ARCHS["deepseek-v3-671b"])
    assert 600e9 < n < 720e9, n
    n_act = analytic_param_count(ARCHS["deepseek-v3-671b"], active_only=True)
    assert 30e9 < n_act < 45e9, n_act


# -- numerical equivalence of optimized paths --------------------------------


def test_blocked_attention_matches_full():
    ks = jax.random.split(KEY, 3)
    B, S, H, K, D = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    want = A.full_attention(q, k, v, causal=True)
    got = A.blocked_attention(q, k, v, causal=True, block_q=16, block_kv=8)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    zz = A.blocked_attention(q, k, v, causal=True, block_q=16, block_kv=16,
                             zigzag=True)
    np.testing.assert_allclose(zz, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,chunk", [(48, 16), (40, 12), (16, 16)])
def test_ssd_chunked_matches_recurrent(S, chunk):
    ks = jax.random.split(KEY, 5)
    B, H, P, N = 2, 3, 8, 4
    xs = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Aa = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, H, N))
    Cm = jax.random.normal(ks[4], (B, S, H, N))
    y1, h1 = ssd_recurrent(xs, dt, Aa, Bm, Cm)
    y2, h2 = ssd_chunked(xs, dt, Aa, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h1, h2, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("S,chunk", [(32, 16), (50, 16), (20, 32)])
def test_wkv_chunked_matches_recurrent(S, chunk):
    ks = jax.random.split(KEY, 5)
    B, H, K = 2, 2, 8
    r, k, v = (jax.random.normal(ks[i], (B, S, H, K)) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)))
    u = 0.3 * jax.random.normal(ks[4], (H, K))
    y1, s1 = wkv_recurrent(r, k, v, lw, u)
    y2, s2 = wkv_chunked(r, k, v, lw, u, chunk=chunk)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s1, s2, atol=1e-4, rtol=1e-4)


def test_mla_absorbed_decode_matches_expanded():
    cfg = ARCHS["deepseek-v3-671b"].reduced()
    p = A.init_mla(KEY, cfg)
    B, S = 2, 8
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model))
    want = A.mla(p, x, cfg, RUN32, causal=True)
    cache = A.init_mla_cache(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = A.mla_decode(p, x[:, t:t + 1], cache, cfg, RUN32)
        outs.append(o[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), want, atol=1e-4,
                               rtol=1e-3)


def test_int8_kv_cache_decode_accuracy():
    """Quantized-KV decode tracks the f32 forward within 5% relative."""
    cfg = ARCHS["deepseek-7b"].reduced()
    runq = RUN32.with_(kv_cache_dtype="int8")
    mf = build_model(cfg, RUN32)
    mq = build_model(cfg, runq)
    p = mf.init(KEY)
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    lg_full, _ = mf.forward(p, {"tokens": toks})
    cq = mq.init_caches(B, S)
    assert jax.tree.leaves(cq["k"])[0].dtype == jnp.int8
    lgs = []
    for t in range(S):
        lg, cq = mq.decode_step(p, {"tokens": toks[:, t:t + 1]}, cq)
        lgs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(lg_full - jnp.stack(lgs, 1))))
    rel = err / float(jnp.max(jnp.abs(lg_full)))
    assert rel < 0.05, rel


def test_moe_dense_vs_ep_capacity():
    """EP sort/scatter dispatch == dropless dense path when capacity is
    ample (single device shard_map over a trivial mesh)."""
    from repro.launch.mesh import compat_make_mesh
    from repro.models import moe as M
    cfg = ARCHS["olmoe-1b-7b"].reduced()
    params = M.init_moe(KEY, cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(4), (2, 16, cfg.d_model))
    dense_out, aux_d = M.moe_dense(params, x, cfg)
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    import dataclasses
    cfg_hi = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    ep_out, aux_e = M.moe_ep(params, x, cfg_hi, RUN32, mesh)
    np.testing.assert_allclose(ep_out, dense_out, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(aux_d, aux_e, atol=1e-5)
