"""CLI launcher smoke tests (train/serve) + vocab padding + zigzag-in-model
coverage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.configs.base import RunConfig
from repro.models import build_model


def test_train_cli_runs_and_learns():
    from repro.launch.train import main
    report = main(["--arch", "chatglm3-6b", "--reduced", "--steps", "25",
                   "--batch", "8", "--seq", "32", "--lr", "1e-2"])
    assert report.final_step == 25
    assert np.mean(report.losses[-3:]) < np.mean(report.losses[:3])


def test_serve_cli_runs():
    from repro.launch.serve import main
    done = main(["--arch", "deepseek-7b", "--requests", "3",
                 "--slots", "2", "--max-new", "4"])
    assert len(done) == 3
    assert all(len(r.out_tokens) == 4 for r in done)


def test_vocab_padding_whisper():
    """whisper's 51865 vocab pads to a 128-multiple; padded columns are
    masked to -inf so they can never be sampled; CE ignores them."""
    cfg = get_arch("whisper-small").reduced(vocab_size=131)  # not 128-mult
    run = RunConfig(attn_impl="full", remat="nothing",
                    compute_dtype="float32")
    m = build_model(cfg, run)
    assert m.padded_vocab == 256
    p = m.init(jax.random.PRNGKey(0))
    assert p["embed"].shape[0] == 256
    B, S = 2, 8
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 131),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, 131),
        "frames": 0.1 * jnp.ones((B, cfg.encdec.enc_len, cfg.d_model)),
    }
    lg, _ = m.forward(p, batch)
    assert lg.shape[-1] == 256
    assert bool(jnp.all(lg[..., 131:] < -1e20))       # masked
    assert bool(jnp.all(jnp.argmax(lg, -1) < 131))    # never sampled
    loss, _ = m.loss_fn(p, batch)
    assert np.isfinite(float(loss))


def test_zigzag_model_path_matches_blocked():
    """attn_impl='zigzag' through the full model == 'blocked'."""
    cfg = get_arch("deepseek-7b").reduced()
    base = RunConfig(remat="nothing", compute_dtype="float32",
                     attn_block_q=8, attn_block_kv=8)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 32), 0,
                              cfg.vocab_size)
    outs = {}
    for impl in ("blocked", "zigzag"):
        m = build_model(cfg, base.with_(attn_impl=impl))
        p = m.init(jax.random.PRNGKey(0))
        outs[impl], _ = m.forward(p, {"tokens": toks})
    np.testing.assert_allclose(outs["blocked"], outs["zigzag"],
                               atol=2e-4, rtol=2e-4)


def test_presets_cover_all_cells():
    from repro.configs import SHAPES, grid
    from repro.configs.base import MeshConfig
    from repro.launch.presets import preset_run
    mc = MeshConfig((16, 16), ("data", "model"))
    for cfg, shape in grid():
        run = preset_run(cfg, shape, mc)
        if shape.mode == "train":
            assert run.microbatches >= 1
            assert shape.global_batch % (run.microbatches) == 0
        else:
            assert run.microbatches == 1
