"""Unified shared-state backend (repro.state): protocol semantics on every
backend, cross-process budget arbitration (the acceptance case: N
processes, ONE envelope) via FileBackend and via the crispy-daemon, daemon
crash/restart behavior, and the store/registry/service views over a
backend."""
import json
import os
import socket
import subprocess
import sys
import tempfile
import time

import pytest

from repro.allocator import AllocationRequest, AllocationService
from repro.core.catalog import aws_like_catalog
from repro.core.profiler import ProfileResult
from repro.core.simulator import (GiB, build_history, make_profile_fn,
                                  scout_like_jobs)
from repro.profiling import (BackendModelRegistry, ProfileStore,
                             ProfilingBudget)
from repro.state import (CrispyDaemon, DaemonBackend, FileBackend,
                         InMemoryBackend, StateBackendError,
                         StateBackendUnavailable)

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")
needs_unix_sockets = pytest.mark.skipif(
    not hasattr(socket, "AF_UNIX"),
    reason="unix-domain sockets unavailable")


def _daemon_socket(tmp_path) -> str:
    # AF_UNIX paths are length-limited (~108 bytes); pytest tmp dirs can
    # get long, so place sockets in a short-lived short tempdir
    d = tempfile.mkdtemp(prefix="crispyd-")
    return os.path.join(d, "d.sock")


def _backends(tmp_path):
    yield InMemoryBackend()
    yield FileBackend(str(tmp_path / "file-backend"))


# -- protocol semantics (every backend) ---------------------------------------


def test_log_append_read_cursor(tmp_path):
    for b in _backends(tmp_path):
        b.append("log", {"x": 1})
        b.append("log", {"x": 2})
        rows, cur = b.read("log")
        assert [r["x"] for r in rows] == [1, 2]
        assert b.read("log", cur) == ([], cur)
        b.append("log", {"x": 3})
        rows2, cur2 = b.read("log", cur)
        assert [r["x"] for r in rows2] == [3] and cur2 > cur


def test_doc_load_cas_conflict(tmp_path):
    for b in _backends(tmp_path):
        assert b.load("docs", "k") == (None, 0)
        won, val, ver = b.cas("docs", "k", 0, {"a": 1})
        assert won and ver == 1
        # stale version loses and gets the current state back to merge
        won, val, ver = b.cas("docs", "k", 0, {"a": 99})
        assert not won and val == {"a": 1} and ver == 1
        won, val, ver = b.cas("docs", "k", 1, {"a": 2})
        assert won and ver == 2


def test_reserve_lease_semantics(tmp_path):
    for b in _backends(tmp_path):
        # bumped fields may land exactly on the ceiling
        assert b.reserve("d", "bud", {"points": 1}, {"points": 2})[0]
        assert b.reserve("d", "bud", {"points": 1}, {"points": 2})[0]
        ok, doc = b.reserve("d", "bud", {"points": 1}, {"points": 2})
        assert not ok and doc["points"] == 2      # denied: nothing changed
        # guard fields (no delta) deny at >= limit
        b.reserve("d", "bud2", {"charged": 100.0}, {})
        assert not b.reserve("d", "bud2", {"points": 1},
                             {"charged": 100.0})[0]
        # unlimited deltas always land
        assert b.reserve("d", "bud2", {"denials": 1}, {})[0]


# -- views over a backend -----------------------------------------------------


def test_profile_store_and_registry_share_any_backend(tmp_path):
    from repro.core.memory_model import fit_memory_model
    sizes = [2e9, 4e9, 6e9, 8e9, 1e10]
    model = fit_memory_model(sizes, [2 * s + 1e9 for s in sizes])
    for b in _backends(tmp_path):
        s1 = ProfileStore(backend=b)
        s2 = ProfileStore(backend=b)
        s1.put("sigA", 1e9, ProfileResult(1e9, 2e9, 0.0, 5.0))
        s1.put_anchor("sigA", 1e9)
        assert s2.refresh() >= 2
        assert s2.get("sigA", 1e9).peak_mem_bytes == 2e9
        assert s2.get_anchor("sigA") == 1e9

        r1 = BackendModelRegistry(b)
        r2 = BackendModelRegistry(b)
        r1.put("a", model, defer_save=True)
        r1.flush()
        r2.put("b", model, defer_save=True)
        r2.flush()                        # merge-on-flush: keeps "a"
        assert "a" in r2 and "b" in r2
        r1.refresh()
        assert "b" in r1


def test_backend_registry_evict_survives_merge_on_flush(tmp_path):
    """Regression: _save_locked's merge-before-CAS must not resurrect a
    record this registry just evicted (tombstones beat the disk copy; a
    genuinely newer sibling model still supersedes the eviction)."""
    from repro.core.memory_model import fit_memory_model
    sizes = [2e9, 4e9, 6e9, 8e9, 1e10]
    model = fit_memory_model(sizes, [2 * s + 1e9 for s in sizes])
    for b in _backends(tmp_path):
        r = BackendModelRegistry(b)
        r.put("gone", model)              # autosaved to the backend doc
        assert "gone" in BackendModelRegistry(b)
        assert r.evict("gone")
        assert "gone" not in r
        r.flush()
        r.refresh()
        assert "gone" not in r            # not re-imported
        assert "gone" not in BackendModelRegistry(b)   # nor persisted
        # a NEWER record from a sibling supersedes the tombstone
        r2 = BackendModelRegistry(b)
        r2.put("gone", model)
        r.refresh()
        assert "gone" in r


def test_profile_store_keeps_legacy_jsonl_layout(tmp_path):
    """ProfileStore(path) still writes the PR-2 JSONL file at exactly
    that path (FileBackend reproduces the layout)."""
    path = str(tmp_path / "prof.jsonl")
    store = ProfileStore(path)
    store.put("sig", 1e9, ProfileResult(1e9, 2e9, 0.0, 5.0))
    rows = [json.loads(line) for line in open(path)]
    assert rows and rows[0]["kind"] == "profile"
    assert store.backend.kind == "file"


def test_no_direct_fcntl_outside_state_package():
    """Acceptance: the fcntl machinery lives only in repro/state/ —
    nothing else imports the module (docstrings may still mention it)."""
    import re
    pat = re.compile(r"^\s*(import fcntl|from fcntl)", re.MULTILINE)
    root = os.path.join(SRC, "repro")
    offenders = []
    for dirpath, _dirs, files in os.walk(root):
        for name in files:
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            rel = os.path.relpath(path, root)
            if rel.startswith("state" + os.sep):
                continue
            with open(path) as f:
                if pat.search(f.read()):
                    offenders.append(rel)
    assert not offenders, offenders


# -- compaction + eviction (regression: daemon restart survival) --------------


def _fitted_model():
    from repro.core.memory_model import fit_memory_model
    sizes = [2e9, 4e9, 6e9, 8e9, 1e10]
    return fit_memory_model(sizes, [2 * s + 1e9 for s in sizes])


@needs_unix_sockets
def test_compaction_mid_session_survives_daemon_restart(tmp_path):
    """Regression (the tentpole's acceptance case): a compaction pass on
    a daemon with shadowed log entries shrinks the on-disk log, does NOT
    resurrect an evicted registry record, and after a daemon restart
    from the same --root every non-tombstoned point is readable with
    byte-identical contents."""
    model = _fitted_model()
    sock = _daemon_socket(tmp_path)
    root = str(tmp_path / "dstate")
    with CrispyDaemon(sock, root=root):
        client = DaemonBackend(sock)
        store = ProfileStore(backend=client)
        for gen in range(6):            # 6 shadowed rewrites per point
            for i in range(4):
                store.put("sigA", float(i + 1) * 1e9,
                          ProfileResult(1e9, (gen + 1) * 1e9, 0.0, 5.0))
        store.put_anchor("sigA", 1e9)
        # a sibling that indexed everything BEFORE the eviction: the
        # compacted snapshot must still deliver it the deletion
        sibling = ProfileStore(backend=DaemonBackend(sock))
        assert sibling.get("sigA", 4e9) is not None
        store.evict("sigA", 4e9)        # tombstone one point
        registry = BackendModelRegistry(client)
        registry.put("keep-me", model)
        registry.put("evict-me", model)
        assert registry.evict("evict-me")

        log_path = FileBackend(root).log_path("profiles")
        size_before = os.path.getsize(log_path)
        stats = store.compact()         # mid-session: daemon stays up
        assert stats["dropped"] >= 15   # 5 shadowed gens x 3 points + more
        assert os.path.getsize(log_path) < size_before
        # the evicted registry record did not come back from the compact
        registry.refresh()
        assert "evict-me" not in registry and "keep-me" in registry
        # the stale sibling observes the point eviction post-compaction
        sibling.refresh()
        assert sibling.get("sigA", 4e9) is None
        points_before = {
            (sig, size): store.get(sig, size).to_dict()
            for sig, size in [("sigA", float(i + 1) * 1e9)
                              for i in range(4) if i + 1 != 4]}
        assert len(store) == 3          # 4 points - 1 tombstoned

    # daemon restart from the same root: compacted state is durable
    with CrispyDaemon(sock, root=root):
        client2 = DaemonBackend(sock)
        store2 = ProfileStore(backend=client2)
        assert len(store2) == len(points_before) == 3
        for (sig, size), before in points_before.items():
            assert store2.get(sig, size).to_dict() == before
        assert store2.get("sigA", 4e9) is None      # stays tombstoned
        assert store2.get_anchor("sigA") == 1e9
        registry2 = BackendModelRegistry(client2)
        assert "evict-me" not in registry2 and "keep-me" in registry2
        # and a sibling's forced merge-write cannot resurrect it either
        registry2.save()
        assert "evict-me" not in BackendModelRegistry(client2)


@needs_unix_sockets
def test_daemon_auto_compaction_bounds_the_log(tmp_path):
    """--compact-after N: the on-disk log stays bounded while a client
    rewrites the same points over and over."""
    sock = _daemon_socket(tmp_path)
    root = str(tmp_path / "dstate")
    with CrispyDaemon(sock, root=root, compact_after=10):
        client = DaemonBackend(sock)
        store = ProfileStore(backend=client)
        for gen in range(50):
            store.put("sig", 1e9, ProfileResult(1e9, (gen + 1) * 1e9,
                                                0.0, 5.0))
        rows, _ = client.read("profiles", 0)
        assert len(rows) <= 10          # 50 appends folded down en route
        assert len(store) == 1
        # the surviving row is the LAST generation
        fresh = ProfileStore(backend=DaemonBackend(sock))
        assert fresh.get("sig", 1e9).peak_mem_bytes == 50 * 1e9


@needs_unix_sockets
def test_daemon_registry_eviction_thresholds(tmp_path):
    """--registry-max-records N: the daemon prunes the registry document
    after each flush, tombstoning the oldest records so sibling
    registries adopt (not resurrect) the eviction."""
    import time as _time
    model = _fitted_model()
    sock = _daemon_socket(tmp_path)
    with CrispyDaemon(sock, registry_max_records=2):
        client = DaemonBackend(sock)
        registry = BackendModelRegistry(client)
        for name in ("oldest", "middle", "newest"):
            registry.put(name, model)
            _time.sleep(0.01)           # distinct created_at ordering
        # the flush that inserted "newest" tripped the daemon-side prune
        sibling = BackendModelRegistry(client)
        assert len(sibling) == 2
        assert "oldest" not in sibling
        assert "middle" in sibling and "newest" in sibling
        # the writer itself adopts the eviction on refresh...
        registry.refresh()
        assert "oldest" not in registry
        # ...and its own forced merge-write does not resurrect the record
        registry.save()
        assert "oldest" not in BackendModelRegistry(client)
        # daemon-side eviction can also be invoked explicitly
        assert client.evict_registry(max_records=1) == ["middle"]
        assert len(BackendModelRegistry(client)) == 1


@needs_unix_sockets
def test_daemon_bounds_pre_auth_frames(tmp_path):
    """An (even unauthenticated) peer streaming an over-long newline-free
    payload must cost one bounded frame, not daemon RAM: the connection
    is answered/dropped and the daemon keeps serving."""
    from repro.state.transport import MAX_FRAME_BYTES
    sock_path = _daemon_socket(tmp_path)
    with CrispyDaemon(sock_path):
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        s.connect(sock_path)
        s.settimeout(10.0)
        try:
            chunk = b"x" * 65536
            sent = 0
            with pytest.raises(OSError):
                # the daemon stops reading after MAX_FRAME_BYTES and
                # drops the connection; the send eventually fails once
                # buffers fill (2x the cap is comfortably past it)
                while sent < 2 * MAX_FRAME_BYTES + len(chunk):
                    s.sendall(chunk)
                    sent += len(chunk)
                s.sendall(b"\n")
                s.recv(1 << 16)         # EOF -> b"" -> no OSError: force
                raise ConnectionResetError("connection was dropped")
        finally:
            s.close()
        # daemon survived and still serves real clients
        live = DaemonBackend(sock_path)
        live.append("after", {"ok": 1})
        rows, _cur = live.read("after")
        assert rows == [{"ok": 1}]


# -- cross-process budget arbitration (acceptance) ----------------------------

_SPENDER = """
import json, sys
sys.path.insert(0, {src!r})
from repro.profiling import ProfilingBudget
from repro.state import DaemonBackend, FileBackend
mode, target, attempts = sys.argv[1], sys.argv[2], int(sys.argv[3])
backend = FileBackend(target) if mode == "file" else DaemonBackend(target)
budget = ProfilingBudget(max_points=20, charge_s=1000.0, backend=backend)
granted = 0
for _ in range(attempts):
    if budget.try_spend():
        granted += 1
        budget.charge(10.0)
print(json.dumps({{"granted": granted,
                   "denials_seen": budget.denials}}))
"""


def _spend_in_processes(mode: str, target: str, procs: int = 2,
                        attempts: int = 20):
    code = _SPENDER.format(src=SRC)
    ps = [subprocess.Popen([sys.executable, "-c", code, mode, target,
                            str(attempts)], stdout=subprocess.PIPE,
                           stderr=subprocess.PIPE, text=True)
          for _ in range(procs)]
    outs = [p.communicate(timeout=120) for p in ps]
    rows = []
    for p, (out, err) in zip(ps, outs):
        assert p.returncode == 0, err[-3000:]
        rows.append(json.loads(out.strip().splitlines()[-1]))
    return rows


def test_two_processes_share_one_envelope_via_file_backend(tmp_path):
    """Acceptance: 2 real processes x 20 attempts against ONE
    max_points=20 envelope grant exactly 20 in total — not 20 each, as
    the process-local budget used to allow."""
    root = str(tmp_path / "shared")
    rows = _spend_in_processes("file", root)
    total = sum(r["granted"] for r in rows)
    assert total == 20, rows
    # both processes read the same final shared state
    budget = ProfilingBudget(max_points=20, backend=FileBackend(root))
    assert budget.points_spent == 20
    assert budget.charged_s == 200.0
    assert budget.exhausted()


@needs_unix_sockets
def test_two_processes_share_one_envelope_via_daemon(tmp_path):
    sock = _daemon_socket(tmp_path)
    with CrispyDaemon(sock, root=str(tmp_path / "dstate")):
        rows = _spend_in_processes("daemon", sock)
        total = sum(r["granted"] for r in rows)
        assert total == 20, rows
        budget = ProfilingBudget(max_points=20,
                                 backend=DaemonBackend(sock))
        assert budget.points_spent == 20 and budget.exhausted()


@needs_unix_sockets
def test_daemon_refuses_to_usurp_a_live_socket(tmp_path):
    """A second daemon on the same socket must refuse to start (a silent
    takeover would split one shared envelope in two); a stale socket
    left by a crash is reclaimed."""
    sock = _daemon_socket(tmp_path)
    with CrispyDaemon(sock):
        with pytest.raises(StateBackendError):
            CrispyDaemon(sock).start()
    # the context exit unlinked the socket; simulate a crash leftover
    open(sock, "w").close()
    d = CrispyDaemon(sock).start()        # reclaims the stale path
    try:
        assert DaemonBackend(sock).ping()
    finally:
        d.stop()


@needs_unix_sockets
def test_failed_tcp_bind_tears_down_the_bound_unix_socket(tmp_path):
    """Regression: when --listen can't bind (port taken), start() must
    release the unix socket it already bound — a half-started daemon
    would otherwise leave a listening-but-unserved socket that fools
    the liveness probe forever."""
    blocker = socket.socket()
    blocker.bind(("127.0.0.1", 0))
    blocker.listen(1)
    port = blocker.getsockname()[1]
    sock = _daemon_socket(tmp_path)
    try:
        with pytest.raises(OSError):
            CrispyDaemon(sock, listen=f"127.0.0.1:{port}").start()
        assert not os.path.exists(sock)
        d = CrispyDaemon(sock).start()      # the path is reusable
        try:
            assert DaemonBackend(sock).ping()
        finally:
            d.stop()
    finally:
        blocker.close()


@needs_unix_sockets
def test_daemon_crash_surfaces_clean_error_and_restart_recovers(tmp_path):
    """Daemon dies: clients get StateBackendUnavailable (no hang, no
    garbage). Daemon restarts on the same socket + root: the same client
    object fails over transparently and the state is intact."""
    sock = _daemon_socket(tmp_path)
    root = str(tmp_path / "dstate")
    daemon = CrispyDaemon(sock, root=root).start()
    client = DaemonBackend(sock)
    budget = ProfilingBudget(max_points=5, backend=client)
    assert budget.try_spend() and budget.try_spend()
    daemon.stop()                         # "crash"

    with pytest.raises(StateBackendUnavailable):
        client.read("anything")
    with pytest.raises(StateBackendUnavailable):
        budget.try_spend()                # budget surfaces it too

    daemon2 = CrispyDaemon(sock, root=root).start()
    try:
        assert budget.points_spent == 2   # state survived via the root
        assert budget.try_spend()
        assert budget.points_spent == 3
    finally:
        daemon2.stop()


@needs_unix_sockets
def test_daemon_entrypoint_lifecycle(tmp_path):
    """python -m repro.state.daemon: start, --ping, serve a client,
    --shutdown -> foreground process exits 0 (the CI smoke contract)."""
    sock = _daemon_socket(tmp_path)
    env = {**os.environ,
           "PYTHONPATH": SRC + os.pathsep + os.environ.get("PYTHONPATH", "")}
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.state.daemon", "--socket", sock,
         "--root", str(tmp_path / "droot")],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    try:
        deadline = time.monotonic() + 10.0
        client = DaemonBackend(sock, timeout_s=2.0)
        while time.monotonic() < deadline:
            if os.path.exists(sock) and client.ping():
                break
            assert proc.poll() is None, proc.communicate()[0]
            time.sleep(0.05)
        else:
            pytest.fail("daemon never became ready")
        ping = subprocess.run(
            [sys.executable, "-m", "repro.state.daemon", "--socket", sock,
             "--ping"], env=env, capture_output=True, text=True)
        assert ping.returncode == 0 and "pong" in ping.stdout
        client.append("log", {"ok": 1})
        assert client.read("log")[0] == [{"ok": 1}]
        down = subprocess.run(
            [sys.executable, "-m", "repro.state.daemon", "--socket", sock,
             "--shutdown"], env=env, capture_output=True, text=True)
        assert down.returncode == 0
        out, _ = proc.communicate(timeout=10)
        assert proc.returncode == 0, out
        assert "clean shutdown" in out
        assert not os.path.exists(sock)
    finally:
        if proc.poll() is None:
            proc.kill()


# -- the full stack over one backend ------------------------------------------


@needs_unix_sockets
def test_two_service_processes_one_daemon_one_envelope(tmp_path):
    """Acceptance, end to end: two AllocationService *processes* pointed
    at one crispy-daemon share the profile store, the model registry AND
    one profiling envelope; the combined fresh profile runs stay within
    the shared max_points."""
    sock = _daemon_socket(tmp_path)
    code = """
import sys
sys.path.insert(0, {src!r})
from repro.allocator import AllocationRequest, AllocationService
from repro.core.catalog import aws_like_catalog
from repro.core.simulator import (GiB, build_history, make_profile_fn,
                                  scout_like_jobs)
from repro.profiling import ProfilingBudget
from repro.state import DaemonBackend
which = int(sys.argv[1])
jobs = scout_like_jobs()
catalog = aws_like_catalog()
history = build_history(jobs, catalog)
mine = jobs[:8] if which == 0 else jobs[4:12]   # 4 contended signatures
backend = DaemonBackend({sock!r})
budget = ProfilingBudget(max_points=30, backend=backend)
with AllocationService(catalog, history, backend=backend,
                       adaptive=True, budget=budget) as svc:
    for j in mine:
        full = j.dataset_gib * GiB
        r = svc.allocate(AllocationRequest(j.name, make_profile_fn(j),
                                           full, anchor=full * 0.01),
                         timeout=120)
        assert r.selection is not None
    print("PROFILED", svc.stats.profile_calls)
""".format(src=SRC, sock=sock)
    with CrispyDaemon(sock, root=str(tmp_path / "dstate")):
        procs = [subprocess.Popen([sys.executable, "-c", code, str(i)],
                                  stdout=subprocess.PIPE,
                                  stderr=subprocess.PIPE, text=True)
                 for i in (0, 1)]
        outs = [p.communicate(timeout=300) for p in procs]
        fresh = 0
        for p, (out, err) in zip(procs, outs):
            assert p.returncode == 0, err[-3000:]
            fresh += int(out.split("PROFILED")[1].strip())
        backend = DaemonBackend(sock)
        budget = ProfilingBudget(max_points=30, backend=backend)
        # the shared envelope bounds COMBINED fresh runs across processes
        assert fresh <= 30
        assert budget.points_spent == fresh
        # both processes' confident models landed in one registry
        registry = BackendModelRegistry(backend)
        jobs = scout_like_jobs()
        linear = [j.name for j in jobs[:12] if j.mem_profile == "linear"]
        assert any(name in registry for name in linear)


def test_service_backend_kind_and_shared_budget_in_stats(tmp_path):
    from repro.serve.engine import AllocationEndpoint
    jobs = scout_like_jobs()
    catalog = aws_like_catalog()
    history = build_history(jobs, catalog)
    be = InMemoryBackend()
    budget = ProfilingBudget(max_points=50, backend=be)
    with AllocationService(catalog, history, backend=be, adaptive=True,
                           budget=budget) as svc:
        ep = AllocationEndpoint(svc)
        j = jobs[0]
        wire = ep.handle(job=j.name, profile_at=make_profile_fn(j),
                         full_size=j.dataset_gib * GiB,
                         anchor=j.dataset_gib * GiB * 0.01)
        assert wire["backend"] == "memory"
        stats = ep.stats()
        assert stats["backend"] == "memory"
        assert stats["budget"]["shared"] is True
        assert stats["budget"]["backend"] == "memory"
        assert stats["budget"]["points_spent"] == wire["profiled"]
