"""bench_diff tolerates rows missing from either artifact.

Tier sets legitimately change across PRs (new tiers land, old ones
retire, CI smokes with a truncated matrix), so a (backend, tier,
threads) row present in only ONE of the two BENCH_load.json files must
be *reported* but never *gate* — and an empty intersection must exit 0.
These tests pin that contract.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.bench_diff import diff, main  # noqa: E402


def _doc(tiers):
    """BENCH_load.json-shaped doc: {tier: {threads: (rps, p99_ms)}}."""
    return {"benchmark": "load_tiers", "tiers": {
        tier: {"by_threads": {
            str(n): {"requests": 10, "throughput_rps": rps, "p99_ms": p99}
            for n, (rps, p99) in by_threads.items()}}
        for tier, by_threads in tiers.items()}}


def test_row_only_in_after_is_reported_not_gated():
    before = _doc({"warm": {1: (100.0, 2.0)}})
    after = _doc({"warm": {1: (101.0, 2.0)}, "fresh": {1: (5.0, 50.0)}})
    rows, regressed = diff(before, after)
    assert not regressed
    by_status = {r["status"] for r in rows}
    assert by_status == {"ok", "only-after"}
    only = next(r for r in rows if r["status"] == "only-after")
    assert (only["tier"], only["threads"]) == ("fresh", "1")
    # one-sided rows carry no numbers — nothing downstream can gate on
    assert "throughput_before" not in only and "throughput_pct" not in only


def test_row_only_in_before_is_reported_not_gated():
    before = _doc({"warm": {1: (100.0, 2.0)}, "retired": {4: (9.0, 9.0)}})
    after = _doc({"warm": {1: (100.0, 2.0)}})
    rows, regressed = diff(before, after)
    assert not regressed
    assert {r["status"] for r in rows} == {"ok", "only-before"}


def test_regression_still_detected_alongside_uncompared_rows():
    before = _doc({"warm": {1: (100.0, 2.0)}})
    after = _doc({"warm": {1: (10.0, 2.0)}, "fresh": {1: (5.0, 5.0)}})
    rows, regressed = diff(before, after)
    assert regressed
    warm = next(r for r in rows if r["tier"] == "warm")
    assert warm["status"] == "REGRESSED"


def test_main_exits_zero_when_baseline_misses_tiers(tmp_path, capsys):
    b = tmp_path / "before.json"
    a = tmp_path / "after.json"
    b.write_text(json.dumps(_doc({"warm": {1: (100.0, 2.0)}})))
    a.write_text(json.dumps(_doc({"warm": {1: (99.0, 2.1)},
                                  "fresh": {1: (5.0, 50.0)}})))
    assert main([str(b), str(a)]) == 0
    out = capsys.readouterr().out
    assert "only-after" in out
    assert "1 row(s) present on one side only" in out


def test_main_exits_zero_on_disjoint_tier_sets(tmp_path, capsys):
    b = tmp_path / "before.json"
    a = tmp_path / "after.json"
    b.write_text(json.dumps(_doc({"old": {1: (100.0, 2.0)}})))
    a.write_text(json.dumps(_doc({"new": {1: (50.0, 9.0)}})))
    assert main([str(b), str(a)]) == 0
    assert "nothing to gate on" in capsys.readouterr().out


def test_main_exits_two_on_unreadable_input(tmp_path):
    a = tmp_path / "after.json"
    a.write_text(json.dumps(_doc({"warm": {1: (1.0, 1.0)}})))
    assert main([str(tmp_path / "missing.json"), str(a)]) == 2


@pytest.mark.parametrize("markdown", [False, True])
def test_table_renders_one_sided_rows_as_dashes(tmp_path, capsys, markdown):
    b = tmp_path / "before.json"
    a = tmp_path / "after.json"
    b.write_text(json.dumps(_doc({"warm": {1: (100.0, 2.0)}})))
    a.write_text(json.dumps(_doc({"warm": {1: (100.0, 2.0)},
                                  "fresh": {1: (5.0, 50.0)}})))
    argv = [str(b), str(a)] + (["--markdown"] if markdown else [])
    assert main(argv) == 0
    out = capsys.readouterr().out
    assert "local/fresh" in out and "only-after" in out
