"""Sharded state plane: routing, batch fan-out, replication, failover.

The conformance suite (tests/test_state_conformance.py) already runs the
full StateBackend contract over `ShardedBackend`; this file covers what
is specific to the sharded plane — the hash ring's stability and
balance, batch split/reassembly and per-shard degradation, the
replicate protocol's idempotency and gap handling, the topology doc,
and the headline guarantee: killing a shard's primary loses zero
acknowledged appends that replication delivered, and the client fails
over to the standby without call-site changes.
"""
import os
import socket
import tempfile
import threading

import pytest

from repro.state import (CrispyDaemon, DaemonBackend, FileBackend,
                         HashRing, InMemoryBackend, ReplicationApplier,
                         ReplicationShipper, ShardedBackend,
                         StateBackendError, StateBackendUnavailable,
                         TOPOLOGY_KEY, TOPOLOGY_NS, load_topology,
                         publish_topology)
from repro.state.sharding import stable_hash

HAS_UNIX = hasattr(socket, "AF_UNIX")
needs_unix = pytest.mark.skipif(not HAS_UNIX,
                                reason="unix-domain sockets unavailable")


def _short_socket() -> str:
    return os.path.join(tempfile.mkdtemp(prefix="crispyd-"), "d.sock")


# -- hash ring ----------------------------------------------------------------


def test_stable_hash_is_process_independent():
    # pinned values: PYTHONHASHSEED must never be able to re-route a
    # namespace (md5, not the salted builtin hash)
    assert stable_hash("profiles") == stable_hash("profiles")
    assert stable_hash("profiles") != stable_hash("profiles2")
    assert 0 <= stable_hash("x") < 2 ** 64


def test_ring_routing_is_deterministic_and_name_based():
    a = HashRing(["shard-0", "shard-1", "shard-2"])
    b = HashRing(["shard-0", "shard-1", "shard-2"])
    for ns in ("profiles", "registry", "budget", "__traces__", "log-17"):
        assert a.owner(ns) == b.owner(ns)      # two instances agree
        assert a.owner(ns) in a.names


def test_ring_growth_moves_only_a_fraction_of_namespaces():
    """Consistent hashing's point: adding a shard re-homes roughly 1/n of
    the keyspace, not all of it."""
    nss = [f"ns-{i}" for i in range(400)]
    two = HashRing(["shard-0", "shard-1"])
    three = HashRing(["shard-0", "shard-1", "shard-2"])
    moved = sum(1 for ns in nss if two.owner(ns) != three.owner(ns))
    # ideal is 1/3; anything under half proves it's not modulo hashing
    assert moved < len(nss) / 2
    # and every moved namespace landed on the NEW shard
    assert all(three.owner(ns) == "shard-2" for ns in nss
               if two.owner(ns) != three.owner(ns))


def test_ring_balance_within_tolerance():
    nss = [f"ns-{i}" for i in range(600)]
    for n in (2, 3, 4):
        ring = HashRing([f"shard-{i}" for i in range(n)])
        counts = [0] * n
        for ns in nss:
            counts[ring.owner_index(ns)] += 1
        assert max(counts) <= 1.4 * len(nss) / n, (n, counts)
        assert min(counts) > 0


def test_ring_rejects_empty_and_duplicate_names():
    with pytest.raises(ValueError):
        HashRing([])
    with pytest.raises(ValueError):
        HashRing(["a", "a"])


def test_sharded_backend_names_are_index_based_not_address_based():
    """Routing must survive a failover that swaps a shard's address:
    only the shard COUNT may matter."""
    m = ShardedBackend([InMemoryBackend(), InMemoryBackend()])
    assert m.names == ["shard-0", "shard-1"]
    for ns in ("profiles", "budget", "reg-9"):
        assert m.shard_index(ns) == HashRing(m.names).owner_index(ns)


# -- routing + single-namespace ops -------------------------------------------


def _ns_owned_by(backend: ShardedBackend, idx: int, prefix="pick") -> str:
    for i in range(10_000):
        ns = f"{prefix}-{i}"
        if backend.shard_index(ns) == idx:
            return ns
    raise AssertionError(f"no namespace routed to shard {idx}")


def test_each_namespace_lives_on_exactly_one_child():
    children = [InMemoryBackend(), InMemoryBackend(), InMemoryBackend()]
    sb = ShardedBackend(children)
    for i in range(30):
        sb.append(f"route-{i}", {"i": i})
    for i in range(30):
        ns = f"route-{i}"
        holders = [c for c in children if c.read(ns)[0]]
        assert len(holders) == 1
        assert holders[0] is children[sb.shard_index(ns)]


def test_sharded_topology_descriptor():
    sb = ShardedBackend([InMemoryBackend(), InMemoryBackend()])
    topo = sb.topology()
    assert topo["vnodes"] == sb.ring.vnodes
    assert [s["name"] for s in topo["shards"]] == ["shard-0", "shard-1"]
    assert all(s["kind"] == "memory" for s in topo["shards"])
    assert "shard-0=" in sb.describe()


# -- batch fan-out ------------------------------------------------------------


def test_batch_splits_by_shard_and_reassembles_in_order():
    children = [InMemoryBackend(), InMemoryBackend()]
    sb = ShardedBackend(children)
    ns_a = _ns_owned_by(sb, 0, "ba")
    ns_b = _ns_owned_by(sb, 1, "bb")
    results = sb.batch([
        {"op": "append", "ns": ns_a, "record": {"i": 0}},
        {"op": "append", "ns": ns_b, "record": {"i": 1}},
        {"op": "append", "ns": ns_a, "record": {"i": 2}},
        {"op": "read", "ns": ns_a, "cursor": 0},
        {"op": "read", "ns": ns_b, "cursor": 0},
    ])
    assert [r["ok"] for r in results] == [True] * 5
    # per-namespace order survives the concurrent fan-out, and each
    # read observes the batch's own earlier writes on its shard
    assert [r["i"] for r in results[3]["rows"]] == [0, 2]
    assert [r["i"] for r in results[4]["rows"]] == [1]
    # the rows really live on their owning children only
    assert children[sb.shard_index(ns_a)].read(ns_a)[0] != []
    assert children[sb.shard_index(ns_b)].read(ns_b)[0] != []


def test_batch_unroutable_ops_get_error_slots_not_exceptions():
    sb = ShardedBackend([InMemoryBackend(), InMemoryBackend()])
    ns = _ns_owned_by(sb, 1, "iso")
    results = sb.batch([
        "not-even-a-dict",
        {"op": "append", "ns": ns, "record": {"i": 1}},
        {"op": "nope", "ns": ns},
    ])
    assert not results[0]["ok"]
    assert results[1]["ok"]
    assert not results[2]["ok"] and "nope" in results[2]["error"]


class _DownChild(InMemoryBackend):
    def batch(self, ops):
        raise StateBackendUnavailable("shard is down")


def test_batch_degrades_per_shard_without_poisoning_others():
    """A shard whose primary AND standby are gone answers with per-op
    error slots; the other shard's sub-frame still lands."""
    sb = ShardedBackend([InMemoryBackend(), _DownChild()])
    ns_up = _ns_owned_by(sb, 0, "up")
    ns_down = _ns_owned_by(sb, 1, "down")
    results = sb.batch([
        {"op": "append", "ns": ns_up, "record": {"i": 0}},
        {"op": "append", "ns": ns_down, "record": {"i": 1}},
        {"op": "read", "ns": ns_up, "cursor": 0},
    ])
    assert results[0]["ok"] and results[2]["ok"]
    assert not results[1]["ok"]
    assert "shard-1" in results[1]["error"]
    assert [r["i"] for r in results[2]["rows"]] == [0]


def test_batch_empty_frame_is_noop():
    assert ShardedBackend([InMemoryBackend()]).batch([]) == []


# -- enumeration hooks (what the shipper reads) -------------------------------


@pytest.mark.parametrize("factory", [
    lambda tmp: InMemoryBackend(),
    lambda tmp: FileBackend(str(tmp / "fb")),
], ids=["memory", "file"])
def test_log_namespaces_and_doc_snapshot(factory, tmp_path):
    b = factory(tmp_path)
    assert b.log_namespaces() == []
    assert b.doc_snapshot() == []
    b.append("logs-a", {"i": 1})
    b.append("logs-b", {"i": 2})
    b.cas("docs", "k1", 0, {"v": 1})
    b.cas("docs", "k2", 0, {"v": 2})
    assert sorted(b.log_namespaces()) == ["logs-a", "logs-b"]
    snap = b.doc_snapshot()
    assert ("docs", "k1", {"v": 1}, 1) in snap
    assert ("docs", "k2", {"v": 2}, 1) in snap


# -- replication: applier ------------------------------------------------------


def test_applier_is_idempotent_by_cursor():
    standby = InMemoryBackend()
    ap = ReplicationApplier(standby)
    frame = {"log": {"ns": "log", "rows": [{"i": 0}, {"i": 1}],
                     "base": 0, "cursor": 2}}
    first = ap.apply(frame)
    assert first == {"ok": True, "applied": 2, "cursor": 2}
    again = ap.apply(frame)                    # duplicate delivery
    assert again["ok"] and again["applied"] == 0
    assert [r["i"] for r in standby.read("log")[0]] == [0, 1]


def test_applier_skips_overlapping_prefix():
    standby = InMemoryBackend()
    ap = ReplicationApplier(standby)
    ap.apply({"log": {"ns": "log", "rows": [{"i": 0}, {"i": 1}],
                      "base": 0, "cursor": 2}})
    # retransmission overlaps one already-applied row
    resp = ap.apply({"log": {"ns": "log",
                             "rows": [{"i": 1}, {"i": 2}, {"i": 3}],
                             "base": 1, "cursor": 4}})
    assert resp["ok"] and resp["applied"] == 2
    assert [r["i"] for r in standby.read("log")[0]] == [0, 1, 2, 3]


def test_applier_demands_resync_on_gap():
    ap = ReplicationApplier(InMemoryBackend())
    resp = ap.apply({"log": {"ns": "log", "rows": [{"i": 9}],
                             "base": 7, "cursor": 8}})
    assert not resp["ok"] and "replication gap" in resp["error"]


def test_applier_doc_versions_are_monotone():
    standby = InMemoryBackend()
    ap = ReplicationApplier(standby)
    assert ap.apply({"doc": {"ns": "d", "key": "k", "value": {"v": 2},
                             "version": 2}})["applied"] is True
    # a stale (or duplicate) doc never regresses the standby's copy
    assert ap.apply({"doc": {"ns": "d", "key": "k", "value": {"v": 1},
                             "version": 1}})["applied"] is False
    assert standby.load("d", "k")[0] == {"v": 2}


def test_applier_rejects_malformed_frames():
    ap = ReplicationApplier(InMemoryBackend())
    assert not ap.apply({})["ok"]
    assert not ap.apply({"log": {"rows": []}})["ok"]
    assert not ap.apply({"doc": {"ns": "d"}})["ok"]


# -- replication: shipper end-to-end ------------------------------------------


class _LoopbackStandby(InMemoryBackend):
    """In-process standby: routes batch frames through a real applier,
    like the daemon's replicate dispatch does."""

    def __init__(self):
        super().__init__()
        self.applier = ReplicationApplier(self)

    def batch(self, ops):
        return [self.applier.apply(op) for op in ops]


def test_shipper_ships_tails_and_docs_idempotently():
    primary = InMemoryBackend()
    standby = _LoopbackStandby()
    shipper = ReplicationShipper(primary, standby="unused", period_s=30)
    shipper._client = standby                   # no wire: loopback standby
    for i in range(5):
        primary.append("log", {"i": i})
    primary.cas("docs", "k", 0, {"v": 1})
    first = shipper.ship_once()
    assert first["rows"] == 5 and first["docs"] == 1
    assert [r["i"] for r in standby.read("log")[0]] == list(range(5))
    assert standby.load("docs", "k")[0] == {"v": 1}
    # a quiet round ships nothing (cursors + doc versions held back)
    assert shipper.ship_once() == {"ops": 0, "rows": 0, "docs": 0,
                                   "errors": 0}
    # incremental: only the new tail goes over
    primary.append("log", {"i": 5})
    assert shipper.ship_once()["rows"] == 1
    assert len(standby.read("log")[0]) == 6
    assert shipper.stats["shipped_rows"] == 6
    assert shipper.stats["rounds"] == 3


def test_shipper_resyncs_after_standby_restart():
    """A standby that lost its state (fresh applier cursors ahead of a
    compacted primary base) answers 'replication gap'; the next round
    re-ships the folded log from the head."""
    primary = InMemoryBackend()
    shipper = ReplicationShipper(primary, standby="unused", period_s=30)
    standby = _LoopbackStandby()
    shipper._client = standby
    for i in range(4):
        primary.append("log", {"kind": "profile", "sig": "s",
                               "size": 1.0, "gen": i})
    assert shipper.ship_once()["rows"] == 4
    primary.compact("log")                     # folds to 1 row, moves base
    primary.append("log", {"kind": "profile", "sig": "t", "size": 9.0})
    # simulate standby restart: empty state, fresh cursors
    fresh = _LoopbackStandby()
    shipper._client = fresh
    gap_round = shipper.ship_once()
    assert gap_round["errors"] == 1            # gap reported, cursor reset
    assert shipper.stats["resyncs"] == 1
    recovery = shipper.ship_once()
    assert recovery["errors"] == 0 and recovery["rows"] == 2
    sigs = sorted(r["sig"] for r in fresh.read("log")[0])
    assert sigs == ["s", "t"]                  # folded snapshot + new tail


# -- topology doc -------------------------------------------------------------


def test_publish_and_load_topology_on_every_shard():
    children = [InMemoryBackend(), InMemoryBackend()]
    sb = ShardedBackend(children)
    doc = publish_topology(sb)
    assert doc["version"] == 1
    assert set(doc["shards"]) == {"shard-0", "shard-1"}
    for child in children:                     # every node can answer
        assert load_topology(child) == doc
    # republish bumps the version everywhere
    assert publish_topology(sb)["version"] == 2
    assert load_topology(children[1])["version"] == 2


def test_publish_topology_skips_down_nodes():
    class _Down(InMemoryBackend):
        def load(self, ns, key):
            raise StateBackendUnavailable("down")

    up = InMemoryBackend()
    doc = publish_topology(ShardedBackend([up, _Down()]))
    assert doc["version"] == 1
    assert load_topology(up) == doc


# -- failover against live daemons --------------------------------------------


@needs_unix
def test_kill_primary_loses_no_acknowledged_appends():
    """The headline guarantee: acknowledged appends that replication
    delivered survive a hard primary death, and the SAME client object
    keeps working against the standby — reads, new writes, CAS."""
    s_primary, s_standby = _short_socket(), _short_socket()
    with CrispyDaemon(s_standby, shard_name="shard-0"):
        primary = CrispyDaemon(s_primary, standby=s_standby,
                               replicate_interval_s=30.0,
                               shard_name="shard-0")
        primary.start(background=True)
        client = DaemonBackend(s_primary, timeout_s=10.0,
                               standby=s_standby, shard_name="shard-0")
        try:
            for i in range(20):
                client.append("jobs", {"i": i})        # acknowledged
            won, _v, _ver = client.cas("docs", "plan", 0, {"v": 42})
            assert won
            primary.shipper.ship_once()    # replication barrier
            # hard death: no graceful drain, no final ship
            primary.shipper.stop(final_ship=False)
            primary.shipper = None
            primary.stop()

            rows, _ = client.read("jobs", 0)           # fails over
            assert [r["i"] for r in rows] == list(range(20))
            assert client.failovers == 1
            assert client.load("docs", "plan") == ({"v": 42}, 1)
            client.append("jobs", {"i": 20})           # writes continue
            assert len(client.read("jobs", 0)[0]) == 21
        finally:
            client.close()
            client.close()                 # idempotent (satellite: close)
            primary.stop()                 # idempotent when already dead


@needs_unix
def test_failover_adopts_new_standby_from_topology_doc():
    """After failing over, the client re-resolves from the on-ring
    topology doc: the dead primary becomes the shard's standby, so a
    LATER failover can bounce back once it recovers."""
    s_primary, s_standby = _short_socket(), _short_socket()
    with CrispyDaemon(s_standby, shard_name="shard-0") as standby_daemon:
        topo = {"version": 1,
                "shards": {"shard-0": {"primary": s_standby,
                                       "standby": s_primary}}}
        over = DaemonBackend(s_standby)
        assert over.cas(TOPOLOGY_NS, TOPOLOGY_KEY, 0, topo)[0]
        over.close()

        primary = CrispyDaemon(s_primary, shard_name="shard-0")
        primary.start(background=True)
        client = DaemonBackend(s_primary, timeout_s=10.0,
                               standby=s_standby, shard_name="shard-0")
        try:
            client.append("jobs", {"i": 0})
            primary.stop()
            assert client.ping()                       # failover to standby
            assert client.failovers == 1
            assert client.address == s_standby
            assert client.standby_address == s_primary # adopted from doc
            assert standby_daemon is not None
        finally:
            client.close()


@needs_unix
def test_shutdown_op_never_fails_over():
    """`shutdown` aimed at a dead primary must not kill the standby."""
    s_primary, s_standby = _short_socket(), _short_socket()
    with CrispyDaemon(s_standby):
        client = DaemonBackend(s_primary, timeout_s=2.0, standby=s_standby)
        try:
            with pytest.raises(StateBackendUnavailable):
                client.shutdown_daemon()
        finally:
            client.close()
        probe = DaemonBackend(s_standby)
        assert probe.ping()                  # standby survived
        probe.close()


@needs_unix
def test_sharded_fleet_survives_one_primary_kill():
    """Two shards, one standby: after shard-1's primary dies, the
    ShardedBackend keeps serving EVERY namespace — shard-0 untouched,
    shard-1 through its standby — including batch frames."""
    s0, s1, s1b = _short_socket(), _short_socket(), _short_socket()
    with CrispyDaemon(s0, shard_name="shard-0"), \
            CrispyDaemon(s1b, shard_name="shard-1"):
        shard1 = CrispyDaemon(s1, standby=s1b, replicate_interval_s=30.0,
                              shard_name="shard-1")
        shard1.start(background=True)
        with ShardedBackend.from_addresses([s0, s1],
                                           standbys=[None, s1b]) as sb:
            ns0 = _ns_owned_by(sb, 0, "fleet")
            ns1 = _ns_owned_by(sb, 1, "fleet")
            for i in range(10):
                sb.append(ns0, {"i": i})
                sb.append(ns1, {"i": i})
            shard1.shipper.ship_once()       # replication barrier
            shard1.shipper.stop(final_ship=False)
            shard1.shipper = None
            shard1.stop()                    # hard death of one primary

            assert [r["i"] for r in sb.read(ns0, 0)[0]] == list(range(10))
            assert [r["i"] for r in sb.read(ns1, 0)[0]] == list(range(10))
            results = sb.batch([
                {"op": "append", "ns": ns0, "record": {"i": 10}},
                {"op": "append", "ns": ns1, "record": {"i": 10}},
                {"op": "read", "ns": ns1, "cursor": 0},
            ])
            assert all(r["ok"] for r in results)
            assert len(results[2]["rows"]) == 11
            assert sb.children[1].failovers == 1


# -- daemon-side shipper wiring -----------------------------------------------


@needs_unix
def test_daemon_ships_to_standby_periodically():
    """The primary's own replication thread (no explicit barrier) gets
    acknowledged rows onto the standby within a few periods."""
    s_primary, s_standby = _short_socket(), _short_socket()
    with CrispyDaemon(s_standby), \
            CrispyDaemon(s_primary, standby=s_standby,
                         replicate_interval_s=0.05):
        writer = DaemonBackend(s_primary)
        observer = DaemonBackend(s_standby)
        try:
            for i in range(5):
                writer.append("period-log", {"i": i})
            deadline = threading.Event()
            for _ in range(100):
                if len(observer.read("period-log", 0)[0]) == 5:
                    break
                deadline.wait(0.05)
            assert [r["i"] for r in observer.read("period-log", 0)[0]] \
                == list(range(5))
        finally:
            writer.close()
            observer.close()


def test_replicate_op_rejected_for_unknown_body_over_wire():
    # replicate is a normal admitted-connection op: malformed bodies get
    # per-op errors, not connection teardown
    with CrispyDaemon(listen="127.0.0.1:0") as d:
        client = DaemonBackend(d.tcp_address)
        try:
            results = client.batch([{"op": "replicate"}])
            assert not results[0]["ok"]
            assert "log" in results[0]["error"]
        finally:
            client.close()
