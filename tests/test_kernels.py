"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode
executes the Pallas kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.rwkv import wkv_chunked
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(7)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,D", [
    (1, 32, 32, 2, 2, 16),
    (2, 64, 64, 4, 2, 32),
    (1, 96, 48, 4, 1, 64),     # ragged + MQA
    (2, 33, 65, 2, 2, 16),     # non-divisible block sizes
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, Sq, Sk, H, Hkv, D, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_kv=16)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **tol(dtype))


@pytest.mark.parametrize("shape", [(4, 64), (3, 17, 96), (2, 5, 7, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    sc = 1.0 + 0.1 * jax.random.normal(ks[1], shape[-1:])
    got = ops.rmsnorm(x, sc, block_rows=4)
    want = ref.rmsnorm_ref(x, sc)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), **tol(dtype))


def test_rmsnorm_residual():
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (8, 64))
    r = jax.random.normal(ks[1], (8, 64))
    sc = jnp.ones((64,))
    got = ops.rmsnorm(x, sc, residual=r, block_rows=8)
    want = ref.rmsnorm_ref(x, sc, residual=r)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("B,S,H,K,chunk", [
    (1, 16, 1, 8, 16),
    (2, 40, 3, 16, 16),
    (1, 33, 2, 32, 8),        # padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_kernel(B, S, H, K, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, S, H, K), dtype)
    k = jax.random.normal(ks[1], (B, S, H, K), dtype)
    v = jax.random.normal(ks[2], (B, S, H, K), dtype)
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K))).astype(jnp.float32)
    u = 0.3 * jax.random.normal(ks[4], (H, K))
    got, st = ops.wkv6(r, k, v, lw, u, chunk=chunk)
    want = ref.wkv6_ref(r, k, v, lw, u)
    # the chunked kernel re-associates the recurrence (intra-chunk matmul
    # + exp-decayed cross-chunk state) vs the reference's sequential scan;
    # in float32 that summation-order difference leaves O(1e-4) relative
    # noise on isolated elements (observed: 1/3840 elements at rel 3.2e-4
    # on jax 0.4.37), so the float32 gate is wider than the generic 2e-5
    wkv_tol = dict(atol=1e-4, rtol=5e-4) if dtype == jnp.float32 \
        else tol(dtype)
    np.testing.assert_allclose(got, want, **wkv_tol)
    # state matches the chunked-jnp second oracle
    _, st2 = wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), lw, u, chunk=chunk)
    np.testing.assert_allclose(st, st2, atol=1e-3, rtol=1e-3)


def test_wkv6_with_incoming_state():
    ks = jax.random.split(KEY, 6)
    B, S, H, K = 1, 24, 2, 8
    r, k, v = (jax.random.normal(ks[i], (B, S, H, K)) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)))
    u = 0.3 * jax.random.normal(ks[4], (H, K))
    st0 = jax.random.normal(ks[5], (B, H, K, K))
    got, st = ops.wkv6(r, k, v, lw, u, state=st0)
    want, st_want = wkv_chunked(r, k, v, lw, u, chunk=16, state=st0)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(st, st_want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 32, 2, 16, 8, 16),
    (2, 50, 3, 8, 16, 16),    # padding path
    (1, 16, 1, 32, 4, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    xs = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, H, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, H, N), dtype)
    got, _ = ops.ssd(xs, dt, A, Bm, Cm, chunk=chunk)
    want = ref.ssd_ref(xs, dt, A, Bm, Cm)
    np.testing.assert_allclose(got, want, **tol(dtype))


def test_xla_paths_match_kernels():
    """The XLA fallback paths (models/) and the Pallas kernels implement the
    same contract."""
    ks = jax.random.split(KEY, 5)
    B, S, H, K = 2, 32, 2, 16
    r, k, v = (jax.random.normal(ks[i], (B, S, H, K)) for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)))
    u = 0.3 * jax.random.normal(ks[4], (H, K))
    y_k, _ = ops.wkv6(r, k, v, lw, u)
    y_x, _ = wkv_chunked(r, k, v, lw, u, chunk=16)
    np.testing.assert_allclose(y_k, y_x, atol=1e-4, rtol=1e-4)

    xs = jax.random.normal(ks[0], (B, S, H, 8))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, H, 4))
    Cm = jax.random.normal(ks[4], (B, S, H, 4))
    y_k, _ = ops.ssd(xs, dt, A, Bm, Cm, chunk=8)
    y_x, _ = ssd_chunked(xs, dt, A, Bm, Cm, chunk=8)
    np.testing.assert_allclose(y_k, y_x, atol=1e-5, rtol=1e-5)
