"""Cross-backend StateBackend conformance suite.

ONE contract, six implementations: every test in this file runs
identically against `InMemoryBackend`, `FileBackend`, `DaemonBackend`
over a unix socket, `DaemonBackend` over TCP (with the shared-token
auth handshake), and `ShardedBackend` over two memory children and two
live unix daemons — the guarantee that lets every view (ProfileStore,
BackendModelRegistry, shared ProfilingBudget) treat the transport AND
the fleet topology as implementation details. Covered contract:

  * append/read ordering + incremental cursor semantics;
  * versioned-document CAS conflict behavior (stale writers lose and get
    the current state back; versions are strictly monotone);
  * `reserve` never over-grants an envelope, under thread contention;
  * compaction: folding keeps the LAST row per identity, tombstoned
    identities stay dead (through compaction AND for stale cursors),
    generic rows never fold, cursors stay monotone across a compact;
  * `batch`: ordered per-op results, a batch reads its own earlier
    writes, per-op failures are isolated, tombstones stay visible
    through batched reads, auth still gates the whole frame on TCP —
    and frames WITHOUT the batch op stay byte-identical to the legacy
    single-op protocol (pinned below).

Property-based variants run when hypothesis is installed; deterministic
seeded equivalents always run, so tier-1 does not require hypothesis.
"""
import os
import random
import socket
import tempfile
import threading

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.state import (CrispyDaemon, DaemonBackend, FileBackend,
                         InMemoryBackend, ShardedBackend,
                         StateBackendError, StateBackendUnavailable)

HAS_UNIX = hasattr(socket, "AF_UNIX")
BACKENDS = ("memory", "file", "daemon-unix", "daemon-tcp",
            "sharded-memory", "sharded-daemon")
AUTH_TOKEN = "conformance-secret"


def _short_socket() -> str:
    # AF_UNIX paths are length-limited (~108 bytes); pytest tmp dirs can
    # get long, so place sockets in a short-lived short tempdir
    return os.path.join(tempfile.mkdtemp(prefix="crispyd-"), "d.sock")


@pytest.fixture(params=BACKENDS)
def backend(request, tmp_path):
    """One StateBackend per param — the same contract must hold on all."""
    kind = request.param
    if kind == "memory":
        yield InMemoryBackend()
    elif kind == "file":
        yield FileBackend(str(tmp_path / "file-backend"))
    elif kind == "daemon-unix":
        if not HAS_UNIX:
            pytest.skip("unix-domain sockets unavailable")
        sock = _short_socket()
        with CrispyDaemon(sock):
            client = DaemonBackend(sock, timeout_s=10.0)
            yield client
            client.close()
    elif kind == "daemon-tcp":              # auth required
        with CrispyDaemon(listen="127.0.0.1:0",
                          auth_token=AUTH_TOKEN) as daemon:
            client = DaemonBackend(daemon.tcp_address, timeout_s=10.0,
                                   auth_token=AUTH_TOKEN)
            yield client
            client.close()
    elif kind == "sharded-memory":
        with ShardedBackend([InMemoryBackend(), InMemoryBackend()]) as b:
            yield b
    else:                                   # sharded-daemon: 2 live shards
        if not HAS_UNIX:
            pytest.skip("unix-domain sockets unavailable")
        s0, s1 = _short_socket(), _short_socket()
        with CrispyDaemon(s0), CrispyDaemon(s1):
            with ShardedBackend.from_addresses([s0, s1],
                                               timeout_s=10.0) as b:
                yield b


# -- append/read ordering -----------------------------------------------------


def test_append_read_ordering_and_cursors(backend):
    assert backend.read("log") == ([], 0) or backend.read("log")[0] == []
    for i in range(5):
        backend.append("log", {"i": i})
    rows, cur = backend.read("log")
    assert [r["i"] for r in rows] == [0, 1, 2, 3, 4]
    # caught-up cursor sees nothing new
    assert backend.read("log", cur)[0] == []
    backend.append("log", {"i": 5})
    rows2, cur2 = backend.read("log", cur)
    assert [r["i"] for r in rows2] == [5]
    assert cur2 > cur
    # namespaces are independent
    assert backend.read("other-log")[0] == []


def test_concurrent_appends_never_drop_or_interleave(backend):
    n, threads = 25, 4

    def writer(tag):
        for i in range(n):
            backend.append("clog", {"tag": tag, "i": i})

    ts = [threading.Thread(target=writer, args=(t,)) for t in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    rows, _ = backend.read("clog")
    assert len(rows) == n * threads
    # per-writer order is preserved even though writers interleave
    for tag in range(threads):
        mine = [r["i"] for r in rows if r["tag"] == tag]
        assert mine == list(range(n))


# -- versioned documents ------------------------------------------------------


def test_cas_conflict_returns_current_state(backend):
    assert backend.load("docs", "k") == (None, 0)
    won, val, ver = backend.cas("docs", "k", 0, {"a": 1})
    assert won and ver == 1
    # stale version loses and gets the current state back to merge
    won, val, ver = backend.cas("docs", "k", 0, {"a": 99})
    assert not won and val == {"a": 1} and ver == 1
    won, val, ver = backend.cas("docs", "k", 1, {"a": 2})
    assert won and ver == 2
    assert backend.load("docs", "k") == ({"a": 2}, 2)


def test_cas_versions_strictly_monotone_under_retries(backend):
    """N threads CAS-increment one counter; every won version is unique,
    the version sequence is gapless, and no increment is lost."""
    wins_per_thread, threads = 10, 3
    won_versions = []
    lock = threading.Lock()

    def bump():
        for _ in range(wins_per_thread):
            while True:
                value, version = backend.load("docs", "ctr")
                doc = dict(value or {"n": 0})
                doc["n"] += 1
                won, _cur, new_ver = backend.cas("docs", "ctr", version, doc)
                if won:
                    with lock:
                        won_versions.append(new_ver)
                    break

    ts = [threading.Thread(target=bump) for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    total = wins_per_thread * threads
    assert sorted(won_versions) == list(range(1, total + 1))
    value, version = backend.load("docs", "ctr")
    assert value["n"] == total and version == total


# -- lease reservations -------------------------------------------------------


def test_reserve_semantics(backend):
    # bumped fields may land exactly on the ceiling
    assert backend.reserve("d", "bud", {"points": 1}, {"points": 2})[0]
    assert backend.reserve("d", "bud", {"points": 1}, {"points": 2})[0]
    ok, doc = backend.reserve("d", "bud", {"points": 1}, {"points": 2})
    assert not ok and doc["points"] == 2      # denied: nothing changed
    # guard fields (no delta) deny at >= limit
    backend.reserve("d", "bud2", {"charged": 100.0}, {})
    assert not backend.reserve("d", "bud2", {"points": 1},
                               {"charged": 100.0})[0]
    # unlimited deltas always land
    assert backend.reserve("d", "bud2", {"denials": 1}, {})[0]


def test_reserve_never_overgrants_under_contention(backend):
    limit, threads, attempts = 17, 4, 10
    granted = [0] * threads

    def spender(idx):
        for _ in range(attempts):
            ok, _doc = backend.reserve("d", "env", {"points": 1},
                                       {"points": float(limit)})
            if ok:
                granted[idx] += 1

    ts = [threading.Thread(target=spender, args=(i,))
          for i in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert sum(granted) == limit              # == not <=: no lost grants
    value, _ = backend.load("d", "env")
    assert value["points"] == limit


# -- compaction ---------------------------------------------------------------


def _fill_profile_log(backend, ns="prof"):
    """5 shadowed rewrites of (a, 1.0), a live (a, 2.0), an anchor, a
    tombstoned (b, 9.0), and two generic (unfoldable) rows."""
    for i in range(5):
        backend.append(ns, {"kind": "profile", "sig": "a", "size": 1.0,
                            "gen": i})
    backend.append(ns, {"kind": "profile", "sig": "a", "size": 2.0})
    backend.append(ns, {"kind": "anchor", "sig": "a", "anchor": 3.0})
    backend.append(ns, {"kind": "profile", "sig": "b", "size": 9.0})
    backend.append(ns, {"kind": "profile", "sig": "b", "size": 9.0,
                        "tombstone": True})
    backend.append(ns, {"note": "generic-1"})
    backend.append(ns, {"note": "generic-2"})


def test_compaction_folds_keeps_last_and_drops_tombstoned(backend):
    _fill_profile_log(backend)
    stats = backend.compact("prof")
    assert stats["before"] == 11
    # survivors: (a,1.0) last rewrite, (a,2.0), anchor, (b,9.0)'s
    # TOMBSTONE (the identity's last word — kept so stale readers still
    # observe the deletion), 2 generic rows
    assert stats["after"] == 6 and stats["dropped"] == 5
    rows, _ = backend.read("prof")
    assert len(rows) == 6
    a1 = [r for r in rows if r.get("sig") == "a" and r.get("size") == 1.0]
    assert len(a1) == 1 and a1[0]["gen"] == 4       # the LAST rewrite won
    b_rows = [r for r in rows if r.get("sig") == "b"]
    assert [bool(r.get("tombstone")) for r in b_rows] == [True]
    assert [r["note"] for r in rows if "note" in r] == \
        ["generic-1", "generic-2"]                  # generic rows never fold
    # compaction is idempotent
    assert backend.compact("prof")["dropped"] == 0


def test_stale_reader_still_observes_tombstone_after_compaction(backend):
    """Regression: a sibling that indexed a point BEFORE it was evicted
    and compacted must still see the deletion when its stale cursor
    re-reads the folded snapshot — folding must not erase tombstones."""
    backend.append("prof", {"kind": "profile", "sig": "b", "size": 9.0})
    _rows, stale = backend.read("prof")     # sibling is now caught up
    backend.append("prof", {"kind": "profile", "sig": "b", "size": 9.0,
                            "tombstone": True})
    backend.compact("prof")
    rows, _ = backend.read("prof", stale)   # pre-compaction cursor
    dead = [r for r in rows if r.get("sig") == "b"]
    assert dead and all(r.get("tombstone") for r in dead)
    # a re-put AFTER the tombstone shadows it again
    backend.append("prof", {"kind": "profile", "sig": "b", "size": 9.0,
                            "back": True})
    backend.compact("prof")
    rows2, _ = backend.read("prof")
    assert [bool(r.get("back")) for r in rows2
            if r.get("sig") == "b"] == [True]


def test_compaction_keeps_cursors_monotone(backend):
    _fill_profile_log(backend)
    rows, cur = backend.read("prof")
    backend.compact("prof")
    # a pre-compaction cursor re-reads the folded snapshot — idempotent
    # under "later rows win" — and advances; it never tears or loses rows
    rows2, cur2 = backend.read("prof", cur)
    assert cur2 >= cur
    assert len(rows2) == 6
    # rows appended after the compact are visible from the new cursor
    backend.append("prof", {"kind": "profile", "sig": "c", "size": 4.0})
    rows3, cur3 = backend.read("prof", cur2)
    assert [r.get("sig") for r in rows3] == ["c"] and cur3 > cur2


def test_compaction_of_missing_namespace_is_empty(backend):
    assert backend.compact("never-written") == \
        {"before": 0, "after": 0, "dropped": 0}


# -- random interleavings (property-based + deterministic equivalent) ---------


def _run_reserve_release_schedule(backend, schedule_a, schedule_b,
                                  limit=7, ns="d", key="prop"):
    """Two threads interleave reserve/release ops; returns total granted
    minus released. The envelope invariant: the doc's `points` never
    exceeds `limit` and equals grants - releases at quiescence."""
    outstanding = [0, 0]

    def runner(idx, schedule):
        for op in schedule:
            if op == "reserve":
                ok, _doc = backend.reserve(ns, key, {"points": 1},
                                           {"points": float(limit)})
                if ok:
                    outstanding[idx] += 1
            elif outstanding[idx] > 0:      # release via negative delta
                backend.reserve(ns, key, {"points": -1}, {})
                outstanding[idx] -= 1

    ts = [threading.Thread(target=runner, args=(i, s))
          for i, s in enumerate((schedule_a, schedule_b))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    value, _ = backend.load(ns, key)
    points = value["points"] if value else 0
    assert 0 <= points <= limit
    assert points == sum(outstanding)
    return points


def test_reserve_release_interleavings_never_exceed_limit(backend):
    rng = random.Random(1234)
    for trial in range(3):
        key = f"prop-{trial}"
        schedules = [[rng.choice(("reserve", "reserve", "release"))
                      for _ in range(12)] for _ in range(2)]
        _run_reserve_release_schedule(backend, *schedules, key=key)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_reserve_release_interleavings_hypothesis():
    ops = st.lists(st.sampled_from(("reserve", "release")),
                   min_size=1, max_size=16)

    @settings(max_examples=25, deadline=None)
    @given(a=ops, b=ops)
    def run(a, b):
        _run_reserve_release_schedule(InMemoryBackend(), a, b)

    run()


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_cas_versions_monotone_hypothesis():
    @settings(max_examples=20, deadline=None)
    @given(increments=st.lists(st.integers(1, 5), min_size=1, max_size=8))
    def run(increments):
        b = InMemoryBackend()
        versions = []
        for inc in increments:
            value, version = b.load("docs", "k")
            doc = dict(value or {"n": 0})
            doc["n"] += inc
            won, _c, new_ver = b.cas("docs", "k", version, doc)
            assert won
            versions.append(new_ver)
        assert versions == sorted(set(versions))    # strictly monotone
        assert b.load("docs", "k")[0]["n"] == sum(increments)

    run()


# -- batched ops --------------------------------------------------------------


def test_batch_ordering_and_reads_own_writes(backend):
    """One batch: results come back one per op, in order, and a read
    later in the batch observes the batch's own earlier appends."""
    results = backend.batch([
        {"op": "append", "ns": "blog", "record": {"i": 0}},
        {"op": "append", "ns": "blog", "record": {"i": 1}},
        {"op": "read", "ns": "blog", "cursor": 0},
        {"op": "cas", "ns": "bdocs", "key": "k", "version": 0,
         "value": {"a": 1}},
        {"op": "load", "ns": "bdocs", "key": "k"},
        {"op": "reserve", "ns": "bd", "key": "env", "deltas": {"points": 1},
         "limits": {"points": 2.0}},
    ])
    assert len(results) == 6
    assert all(r["ok"] for r in results)
    assert [r["i"] for r in results[2]["rows"]] == [0, 1]
    # cursors are backend-opaque; the batched read must land on the
    # same caught-up cursor a single-op read reports
    assert results[2]["cursor"] == backend.read("blog")[1]
    assert results[3]["won"] and results[3]["version"] == 1
    assert results[4] == {"ok": True, "value": {"a": 1}, "version": 1}
    assert results[5]["granted"] and results[5]["doc"]["points"] == 1.0
    # the batch's writes are durable for ordinary single-op reads
    assert [r["i"] for r in backend.read("blog")[0]] == [0, 1]
    assert backend.load("bdocs", "k") == ({"a": 1}, 1)


def test_batch_partial_failure_isolation(backend):
    """A failing op yields its own error slot; neighbors before AND
    after it still execute."""
    results = backend.batch([
        {"op": "append", "ns": "flog", "record": {"i": 0}},
        {"op": "nope"},
        "not-even-a-dict",
        {"op": "append", "ns": "flog", "record": {"i": 1}},
        {"op": "read", "ns": "flog", "cursor": 0},
    ])
    assert len(results) == 5
    assert results[0]["ok"] and results[3]["ok"]
    assert not results[1]["ok"] and "nope" in results[1]["error"]
    assert not results[2]["ok"]
    assert [r["i"] for r in results[4]["rows"]] == [0, 1]


def test_batch_empty_is_a_valid_noop(backend):
    assert backend.batch([]) == []


def test_tombstones_visible_through_batched_reads(backend):
    """An eviction tombstone appended via batch stays the identity's
    last word for batched readers, through compaction included."""
    results = backend.batch([
        {"op": "append", "ns": "tlog",
         "record": {"kind": "profile", "sig": "x", "size": 1.0}},
        {"op": "append", "ns": "tlog",
         "record": {"kind": "profile", "sig": "x", "size": 1.0,
                    "tombstone": True}},
        {"op": "compact", "ns": "tlog",
         "key_fields": ["kind", "sig", "size"]},
        {"op": "read", "ns": "tlog", "cursor": 0},
    ])
    assert all(r["ok"] for r in results)
    assert results[2]["after"] == 1          # folded to the tombstone
    rows = results[3]["rows"]
    assert [bool(r.get("tombstone")) for r in rows] == [True]


def test_batch_rejects_nested_and_connection_scoped_ops(backend):
    """auth / shutdown / batch may not ride inside a batch — each gets
    an error slot, state-changing neighbors still run."""
    if backend.kind != "daemon":
        pytest.skip("connection-scoped ops exist only on the daemon")
    excluded = [{"op": "auth", "token": "x"},
                {"op": "batch", "ops": []},
                {"op": "shutdown"}]
    results = backend.batch(
        excluded + [{"op": "append", "ns": "xlog", "record": {"i": 7}}])
    for r in results[:-1]:
        assert not r["ok"] and "not allowed inside a batch" in r["error"]
    assert results[-1]["ok"]
    assert backend.read("xlog")[0] == [{"i": 7}]


def test_auth_gates_batch_frames_on_tcp():
    """An unauthenticated TCP connection cannot smuggle writes inside a
    batch frame: the whole frame is rejected before dispatch."""
    import json as _json
    with CrispyDaemon(listen="127.0.0.1:0", auth_token=AUTH_TOKEN) as d:
        host, port = d.tcp_address.rsplit(":", 1)
        raw = socket.create_connection((host, int(port)), timeout=5.0)
        try:
            raw.sendall(_json.dumps(
                {"op": "batch",
                 "ops": [{"op": "append", "ns": "log",
                          "record": {"sneak": 1}}]}).encode() + b"\n")
            resp = _json.loads(raw.makefile("rb").readline())
            assert resp["ok"] is False
        finally:
            raw.close()
        good = DaemonBackend(d.tcp_address, auth_token=AUTH_TOKEN)
        assert good.read("log")[0] == []        # nothing snuck in
        # and an authenticated client's batch works over TCP
        results = good.batch([
            {"op": "append", "ns": "log", "record": {"i": 1}},
            {"op": "read", "ns": "log", "cursor": 0}])
        assert results[1]["rows"] == [{"i": 1}]
        good.close()


# -- legacy frames stay byte-identical ----------------------------------------


@pytest.mark.skipif(not HAS_UNIX, reason="unix-domain sockets unavailable")
def test_legacy_frames_byte_identical_pin():
    """The pre-batching wire protocol, pinned byte for byte: a frame
    without the batch op (or trace field) must produce EXACTLY the
    response bytes the legacy daemon produced — old clients never see
    the new protocol."""
    pinned = [
        (b'{"op": "ping"}\n',
         b'{"ok": true, "kind": "memory"}\n'),
        (b'{"op": "append", "ns": "log", "record": {"i": 1}}\n',
         b'{"ok": true}\n'),
        (b'{"op": "read", "ns": "log", "cursor": 0}\n',
         b'{"ok": true, "rows": [{"i": 1}], "cursor": 1}\n'),
        (b'{"op": "load", "ns": "docs", "key": "k"}\n',
         b'{"ok": true, "value": null, "version": 0}\n'),
        (b'{"op": "cas", "ns": "docs", "key": "k", "version": 0, '
         b'"value": {"a": 1}}\n',
         b'{"ok": true, "won": true, "value": {"a": 1}, "version": 1}\n'),
        (b'{"op": "reserve", "ns": "d", "key": "b", '
         b'"deltas": {"points": 1}, "limits": {"points": 2.0}}\n',
         b'{"ok": true, "granted": true, "doc": {"points": 1.0}}\n'),
        (b'{"op": "compact", "ns": "log"}\n',
         b'{"ok": true, "before": 1, "after": 1, "dropped": 0}\n'),
        (b'{"op": "evict_registry", "ns": "registry", "key": "records"}\n',
         b'{"ok": true, "evicted": []}\n'),
        (b'{"op": "nope"}\n',
         b'{"ok": false, "error": "unknown op \'nope\'"}\n'),
    ]
    sock_path = _short_socket()
    with CrispyDaemon(sock_path):
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        s.settimeout(5.0)
        s.connect(sock_path)
        try:
            f = s.makefile("rb")
            for request, expected in pinned:
                s.sendall(request)
                assert f.readline() == expected, request
        finally:
            s.close()


# -- daemon-transport specifics ----------------------------------------------


def test_daemon_read_timeout_surfaces_unavailable_not_hang():
    """A daemon that accepts but never replies must surface
    StateBackendUnavailable within the read timeout, not wedge the
    caller forever."""
    listener = socket.socket()
    listener.bind(("127.0.0.1", 0))
    listener.listen(1)
    host, port = listener.getsockname()
    accepted = []

    def acceptor():
        try:
            conn, _ = listener.accept()
            accepted.append(conn)           # read nothing, reply nothing
        except OSError:
            pass

    t = threading.Thread(target=acceptor, daemon=True)
    t.start()
    client = DaemonBackend(f"{host}:{port}", timeout_s=5.0,
                           read_timeout_s=0.4)
    try:
        with pytest.raises(StateBackendUnavailable) as e:
            client.read("log")
        assert "did not answer" in str(e.value)
        assert "0.4" in str(e.value)
    finally:
        client.close()
        for conn in accepted:
            conn.close()
        listener.close()
        t.join(timeout=2.0)


def test_daemon_backend_sweeps_dead_thread_connections():
    """Connections cached for exited threads are closed on the next call
    from any thread (the per-thread-cache leak), and close() releases
    every live connection too."""
    sock = _short_socket()
    if not HAS_UNIX:
        pytest.skip("unix-domain sockets unavailable")
    with CrispyDaemon(sock):
        client = DaemonBackend(sock)

        barrier = threading.Barrier(4)

        def worker():
            barrier.wait()          # all four connect concurrently, so
            assert client.ping()    # no worker's connect sweeps another
            barrier.wait()

        threads = [threading.Thread(target=worker) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # 4 dead threads' connections are still cached...
        assert len(client._conn_registry) == 4
        dead_socks = [files[0] for _t, files in
                      client._conn_registry.values()]
        assert client.ping()                 # ...until any call sweeps them
        assert len(client._conn_registry) == 1
        assert all(s.fileno() == -1 for s in dead_socks)
        client.close()
        assert len(client._conn_registry) == 0


def test_daemon_connect_error_names_the_unix_path():
    missing = os.path.join(tempfile.mkdtemp(prefix="crispyd-"), "gone.sock")
    client = DaemonBackend(missing, timeout_s=1.0)
    with pytest.raises(StateBackendUnavailable) as e:
        client.read("log")
    assert missing in str(e.value) and "unix socket" in str(e.value)


def test_daemon_connect_error_names_the_tcp_address():
    # a bound-then-closed ephemeral port: nothing is listening there
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    host, port = probe.getsockname()
    probe.close()
    client = DaemonBackend(f"{host}:{port}", timeout_s=1.0)
    with pytest.raises(StateBackendUnavailable) as e:
        client.read("log")
    msg = str(e.value)
    assert f"{host}:{port}" in msg and "tcp address" in msg


def test_tcp_daemon_rejects_wrong_or_missing_token():
    with CrispyDaemon(listen="127.0.0.1:0", auth_token=AUTH_TOKEN) as d:
        good = DaemonBackend(d.tcp_address, auth_token=AUTH_TOKEN)
        good.append("log", {"ok": 1})
        assert good.read("log")[0] == [{"ok": 1}]
        for bad_token in ("wrong", None):
            bad = DaemonBackend(d.tcp_address, auth_token=bad_token)
            # an unauthenticated connection gets exactly one error frame
            with pytest.raises(StateBackendError):
                bad.append("log", {"sneak": 1})
            bad.close()
        assert good.read("log", 0)[0] == [{"ok": 1}]    # nothing snuck in


def test_tcp_and_unix_clients_share_one_daemon(tmp_path):
    """The tentpole in one assertion: the SAME daemon state is visible
    over both transports at once."""
    if not HAS_UNIX:
        pytest.skip("unix-domain sockets unavailable")
    sock = _short_socket()
    with CrispyDaemon(sock, listen="127.0.0.1:0") as d:
        over_unix = DaemonBackend(sock)
        over_tcp = DaemonBackend(d.tcp_address)
        assert over_unix.transport == "unix" and over_tcp.transport == "tcp"
        over_unix.append("log", {"from": "unix"})
        over_tcp.append("log", {"from": "tcp"})
        rows, _ = over_unix.read("log")
        assert [r["from"] for r in rows] == ["unix", "tcp"]
        won, _v, ver = over_tcp.cas("docs", "k", 0, {"via": "tcp"})
        assert won
        assert over_unix.load("docs", "k") == ({"via": "tcp"}, 1)
        # one envelope across transports
        assert over_unix.reserve("d", "b", {"points": 1}, {"points": 2})[0]
        assert over_tcp.reserve("d", "b", {"points": 1}, {"points": 2})[0]
        assert not over_tcp.reserve("d", "b", {"points": 1},
                                    {"points": 2})[0]
