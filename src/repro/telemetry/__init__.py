r"""Telemetry plane: spans + metrics through the whole allocation stack.

Crispy's premise is quantified self-knowledge — extrapolating a job's
memory need from a ten-minute profiling envelope — and this package
gives the SYSTEM the same property: every layer reports where its wall
time goes and how hot its caches run, with zero dependencies beyond the
stdlib and a hot-path cost low enough to leave on in production (a
warm-start plan with telemetry enabled is pinned within 5% of a no-op'd
registry by tests/test_telemetry.py).

  metrics.py   `MetricsRegistry` of counters / gauges / fixed-bucket
               histograms (p50/p95/p99). Lock-free fast path: each
               thread writes its own shard; shards fold on `snapshot()`.
               `MetricsRegistry(enabled=False)` hands out shared no-op
               instruments — the off switch. Buckets keep the most
               recent on-trace (value, trace_id) as an EXEMPLAR.
  spans.py     `span(name, **attrs)` context manager -> nested,
               thread-aware span trees via `contextvars`, recorded into
               a bounded `TraceRing` when the root closes. Every span
               carries trace/span/parent ids; `span(..., parent=ctx)`
               adopts a REMOTE parent across process edges.
  sampling.py  `AdaptiveSampler`: raises the pipeline's warm-path
               1-in-8 sampling toward 1-in-1 while windowed stage p99
               drifts past a gate, decays back on recovery (hysteresis);
               `FixedSampler` keeps a constant rate.
  export.py    snapshots as JSON (`render_json`) or Prometheus text
               (`render_prometheus`, with OpenMetrics exemplars); fleet
               aggregation by publishing periodic snapshots into the
               reserved `__telemetry__` namespace of any
               `repro.state.StateBackend` (`publish_snapshot` /
               `TelemetryPublisher` / `fleet_snapshot` /
               `aggregate_fleet`), trace forests into `__traces__`
               (`publish_traces` / `fleet_traces`), and cross-process
               stitching (`stitch_fleet_traces`).
  logs.py      `StructuredLogger`: leveled one-line-JSON events on
               stderr (the daemon's server-side logging); stamps
               `trace_id`/`span_id` automatically inside an active span.
  trace_tool.py  `python -m repro.telemetry.trace_tool` — connect to a
               crispy-daemon, pull fleet snapshots + trace forests,
               print stitched cross-process trees and slowest-span
               tables.

Distributed tracing (how one request becomes ONE tree):

      service process                        daemon process
  ---------------------------          ------------------------
  endpoint.request  <- root: mints     |
    service.plan       trace_id T     |
      pipeline.acquire                 |
        [DaemonBackend.read] --frame {"op": .., "trace": {T, S}}-->
                                       daemon.op.read   <- local ROOT,
                                       |   trace_id=T, parent_id=S
                                       |   (recorded in daemon ring)
  each ring publishes roots            |
  (publish_traces / `traces` op)       |
           \                          /
            stitch_fleet_traces: graft daemon roots under span S
            => one tree, every span annotated with its source

  * Identity: every span gets a 64-bit hex trace_id (minted at the
    trace root, inherited by descendants) and span_id; the propagation
    token is `current_trace_context()` == {"trace_id", "span_id"}.
  * Wire: clients stamp the token as a `trace` field on newline-JSON
    frames (repro.state.transport.TRACE_FIELD, unix AND tcp); a frame
    WITHOUT the field is an old client and gets byte-identical legacy
    behavior. `AllocationEndpoint.handle(trace=ctx)` is the same hop
    one level up, and replies carry `trace_id`.
  * Clock: each local trace anchors (epoch, perf_counter) ONCE at its
    root; descendants derive `started_at` monotonically, so sibling
    offsets survive NTP steps. Remote spans re-anchor on their own
    host's clock (stitching joins by ids, never by timestamps).
  * Sampling policy: cold pipeline stages always span/observe; warm
    stages sample 1-in-`(mask+1)` and only span when nested. The mask
    is 7 under `FixedSampler` (default) and breathes 7 -> 0 -> 7 under
    `AdaptiveSampler` as windowed p99 crosses/recovers its gate.
  * Exemplars: a histogram bucket remembers its most recent on-trace
    (value, trace_id, ts); exporters render them (OpenMetrics suffix in
    `render_prometheus`), so "p99 got worse" links to a concrete
    stitched trace.

Where each span/metric hangs (the observability map):

  AllocationPipeline   histograms `pipeline.stage.<stage>.seconds`;
  (repro.pipeline)     counters `pipeline.warm_start.{hits,misses}`;
                       spans `pipeline.warm_start` / `.acquire` / `.fit`
                       / `.extrapolate` / `.select`. Warm-path economics
                       (a registry hit answers in tens of us): cold
                       stages (acquire/fit/classify) always span and
                       observe; warm stages (warm_start/extrapolate/
                       select) sample their histograms 1-in-8 and open
                       spans only when nested inside a caller's span.
                       Counters are exact, and exact per-request walls
                       always land on `PipelinePlan.stage_walls` ->
                       `PipelineTrace.stage_walls` (opt-in on the wire
                       via `AllocationEndpoint.handle(include_trace=
                       True)`).
  PointSource          counters `acquisition.{fresh,lru_hits,
  (repro.pipeline)     store_hits,denied}` + `acquisition.profile_
                       seconds` — the LRU -> store -> fresh tier heat.
  ProfilingBudget      counters `budget.{reserved_points,refunded_
  (repro.profiling)    points,charged_seconds,denials}` — envelope
                       accounting is auditable: charged vs refunded.
  AllocationService    histograms `service.batch.size`, `service.queue_
  (repro.allocator)    wait.seconds`, `service.request.seconds`;
                       counters `service.*` (the legacy `stats`
                       dataclass is now a compatibility VIEW over these
                       counters — one thread-safe source of truth).
                       `service.metrics()` returns the snapshot;
                       `AllocationEndpoint.metrics()` is the wire form.
  CrispyDaemon         histograms `daemon.op.<op>.seconds` per request
  (repro.state)        op — batch frames time each sub-op into the same
                       histograms and record their width (ops per
                       frame) in `daemon.batch.size`; counters
                       `daemon.{frames,bytes_in,auth_
                       failures,compactions}` (a batch frame counts
                       once in `daemon.frames`). Served over BOTH
                       transports as the `{"op": "metrics"}` wire op
                       (`DaemonBackend.metrics()`), and optionally
                       auto-published to the daemon's own backend with
                       `--telemetry-interval S`.

`benchmarks/load_tiers.py` drives the instrumented service across
request-mix tiers and records p50/p99 latency + throughput (plus key
counters) to `BENCH_load.json` — the perf trajectory across PRs.
"""
from repro.telemetry.export import (KEY_FIELDS, TELEMETRY_NS, TRACES_NS,
                                    TelemetryPublisher, aggregate_fleet,
                                    fleet_snapshot, fleet_traces,
                                    publish_snapshot, publish_traces,
                                    render_json, render_prometheus,
                                    shard_heat, stitch_fleet_traces)
from repro.telemetry.logs import StructuredLogger
from repro.telemetry.metrics import (DEFAULT_BUCKETS, Counter, Gauge,
                                     Histogram, MetricsRegistry,
                                     NULL_COUNTER, NULL_GAUGE,
                                     NULL_HISTOGRAM, default_registry,
                                     quantile_from_buckets,
                                     set_default_registry)
from repro.telemetry.sampling import (AdaptiveSampler, FixedSampler,
                                      resolve_sampler)
from repro.telemetry.spans import (Span, TraceRing, current_span,
                                   current_trace_context, default_ring,
                                   new_span_id, span, span_if)

__all__ = [
    "AdaptiveSampler", "Counter", "DEFAULT_BUCKETS", "FixedSampler",
    "Gauge", "Histogram", "KEY_FIELDS", "MetricsRegistry",
    "NULL_COUNTER", "NULL_GAUGE", "NULL_HISTOGRAM", "Span",
    "StructuredLogger", "TELEMETRY_NS", "TRACES_NS",
    "TelemetryPublisher", "TraceRing", "aggregate_fleet",
    "current_span", "current_trace_context", "default_registry",
    "default_ring", "fleet_snapshot", "fleet_traces", "new_span_id",
    "publish_snapshot", "publish_traces", "quantile_from_buckets",
    "render_json", "render_prometheus", "resolve_sampler",
    "set_default_registry", "shard_heat", "span", "span_if",
    "stitch_fleet_traces",
]
