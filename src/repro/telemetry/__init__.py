"""Telemetry plane: spans + metrics through the whole allocation stack.

Crispy's premise is quantified self-knowledge — extrapolating a job's
memory need from a ten-minute profiling envelope — and this package
gives the SYSTEM the same property: every layer reports where its wall
time goes and how hot its caches run, with zero dependencies beyond the
stdlib and a hot-path cost low enough to leave on in production (a
warm-start plan with telemetry enabled is pinned within 5% of a no-op'd
registry by tests/test_telemetry.py).

  metrics.py   `MetricsRegistry` of counters / gauges / fixed-bucket
               histograms (p50/p95/p99). Lock-free fast path: each
               thread writes its own shard; shards fold on `snapshot()`.
               `MetricsRegistry(enabled=False)` hands out shared no-op
               instruments — the off switch.
  spans.py     `span(name, **attrs)` context manager -> nested,
               thread-aware span trees via `contextvars`, recorded into
               a bounded `TraceRing` when the root closes.
  export.py    snapshots as JSON (`render_json`) or Prometheus text
               (`render_prometheus`); fleet aggregation by publishing
               periodic snapshots into the reserved `__telemetry__`
               namespace of any `repro.state.StateBackend`
               (`publish_snapshot` / `TelemetryPublisher` /
               `fleet_snapshot` / `aggregate_fleet`).
  logs.py      `StructuredLogger`: leveled one-line-JSON events on
               stderr (the daemon's server-side logging).

Where each span/metric hangs (the observability map):

  AllocationPipeline   histograms `pipeline.stage.<stage>.seconds`;
  (repro.pipeline)     counters `pipeline.warm_start.{hits,misses}`;
                       spans `pipeline.warm_start` / `.acquire` / `.fit`
                       / `.extrapolate` / `.select`. Warm-path economics
                       (a registry hit answers in tens of us): cold
                       stages (acquire/fit/classify) always span and
                       observe; warm stages (warm_start/extrapolate/
                       select) sample their histograms 1-in-8 and open
                       spans only when nested inside a caller's span.
                       Counters are exact, and exact per-request walls
                       always land on `PipelinePlan.stage_walls` ->
                       `PipelineTrace.stage_walls` (opt-in on the wire
                       via `AllocationEndpoint.handle(include_trace=
                       True)`).
  PointSource          counters `acquisition.{fresh,lru_hits,
  (repro.pipeline)     store_hits,denied}` + `acquisition.profile_
                       seconds` — the LRU -> store -> fresh tier heat.
  ProfilingBudget      counters `budget.{reserved_points,refunded_
  (repro.profiling)    points,charged_seconds,denials}` — envelope
                       accounting is auditable: charged vs refunded.
  AllocationService    histograms `service.batch.size`, `service.queue_
  (repro.allocator)    wait.seconds`, `service.request.seconds`;
                       counters `service.*` (the legacy `stats`
                       dataclass is now a compatibility VIEW over these
                       counters — one thread-safe source of truth).
                       `service.metrics()` returns the snapshot;
                       `AllocationEndpoint.metrics()` is the wire form.
  CrispyDaemon         histograms `daemon.op.<op>.seconds` per request
  (repro.state)        op; counters `daemon.{frames,bytes_in,auth_
                       failures,compactions}`. Served over BOTH
                       transports as the `{"op": "metrics"}` wire op
                       (`DaemonBackend.metrics()`), and optionally
                       auto-published to the daemon's own backend with
                       `--telemetry-interval S`.

`benchmarks/load_tiers.py` drives the instrumented service across
request-mix tiers and records p50/p99 latency + throughput (plus key
counters) to `BENCH_load.json` — the perf trajectory across PRs.
"""
from repro.telemetry.export import (KEY_FIELDS, TELEMETRY_NS,
                                    TelemetryPublisher, aggregate_fleet,
                                    fleet_snapshot, publish_snapshot,
                                    render_json, render_prometheus)
from repro.telemetry.logs import StructuredLogger
from repro.telemetry.metrics import (DEFAULT_BUCKETS, Counter, Gauge,
                                     Histogram, MetricsRegistry,
                                     NULL_COUNTER, NULL_GAUGE,
                                     NULL_HISTOGRAM, default_registry,
                                     quantile_from_buckets,
                                     set_default_registry)
from repro.telemetry.spans import (Span, TraceRing, current_span,
                                   default_ring, span, span_if)

__all__ = [
    "Counter", "DEFAULT_BUCKETS", "Gauge", "Histogram", "KEY_FIELDS",
    "MetricsRegistry", "NULL_COUNTER", "NULL_GAUGE", "NULL_HISTOGRAM",
    "Span", "StructuredLogger", "TELEMETRY_NS", "TelemetryPublisher",
    "TraceRing", "aggregate_fleet", "current_span", "default_registry",
    "default_ring", "fleet_snapshot", "publish_snapshot",
    "quantile_from_buckets", "render_json", "render_prometheus",
    "set_default_registry", "span", "span_if",
]
