"""trace_tool: inspect fleet-wide distributed traces from a terminal.

    python -m repro.telemetry.trace_tool --daemon /tmp/crispy.sock
    python -m repro.telemetry.trace_tool --daemon host:7421 --slowest 10
    python -m repro.telemetry.trace_tool --daemon ... --trace <id> --json
    python -m repro.telemetry.trace_tool \
        --daemon /tmp/s0.sock,/tmp/s1.sock --fleet     # sharded fleet

Connects to one or more crispy-daemons (comma-separated unix paths or
host:port addresses, token auth via --auth-token /
$CRISPY_DAEMON_TOKEN), pulls every trace source it can reach, stitches
them into cross-process trees, and prints:

  * the stitched trees (indented; per-span wall ms, attrs, [source]),
    newest last — or one tree with `--trace <id>`;
  * a slowest-span table (`--slowest N`) across every stitched tree,
    the "where did the time go" answer sorted by self-time;
  * with `--fleet`, the aggregated fleet metrics snapshot, a per-shard
    `daemon.op.*` heat table (shard-qualified daemon sources, so
    hot-shard skew is visible at a glance) and any histogram exemplars,
    each linking a bucket to a trace id that can be fed straight back
    into `--trace`.

Trace sources, all merged under their source labels:

  1. each daemon's OWN ring, over the `traces` wire op (`daemon.op.*`
     spans adopted from traced callers), labeled by the daemon's
     shard-qualified source ("crispy-daemon@shard-0" under
     --shard-name, plain "crispy-daemon" otherwise);
  2. every forest published into the backends' `__traces__` namespaces
     by service-side `TelemetryPublisher(ring=...)` / `publish_traces`.

`--expect-cross-process` exits non-zero unless at least one stitched
tree contains spans from two or more sources — the CI assertion that
trace propagation over the live wire actually works.

Everything here is read-only against the daemon; `main(argv)` returns
an exit code and prints to stdout, so tests drive it in-process.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

from repro.telemetry.export import (aggregate_fleet, fleet_snapshot,
                                    fleet_traces, shard_heat,
                                    stitch_fleet_traces)

DAEMON_SOURCE = "crispy-daemon"


def collect_fleet(backend) -> Dict[str, List[Dict]]:
    """Every reachable trace forest: published `__traces__` rows plus
    the daemon's own ring (daemon wins its label on conflict — its ring
    is fresher than anything it published). The daemon's label is its
    shard-qualified source when it announces one (a --shard-name fleet
    member), the historical DAEMON_SOURCE otherwise."""
    fleet = dict(fleet_traces(backend))
    traces_op = getattr(backend, "traces", None)
    if callable(traces_op):
        try:
            try:
                source, roots = traces_op(with_source=True)
            except TypeError:       # pre-sharding DaemonBackend
                source, roots = DAEMON_SOURCE, traces_op()
            fleet[source] = roots
        except Exception:
            pass                    # daemon without the op: published only
    return fleet


def collect_fleet_metrics(backend) -> Dict[str, Dict]:
    """Every reachable metrics snapshot: published `__telemetry__` rows
    plus the daemon's own live registry over the `metrics` wire op
    (shard-qualified label, same rule as `collect_fleet`)."""
    fleet = dict(fleet_snapshot(backend))
    metrics_op = getattr(backend, "metrics", None)
    if callable(metrics_op):
        try:
            try:
                source, snap = metrics_op(with_source=True)
            except TypeError:       # pre-sharding DaemonBackend
                source, snap = DAEMON_SOURCE, metrics_op()
            fleet[source] = {"ts": None, "metrics": snap}
        except Exception:
            pass
    return fleet


def _walk(span_dict: Dict, depth: int = 0):
    yield depth, span_dict
    for child in span_dict.get("children", ()):
        yield from _walk(child, depth + 1)


def self_seconds(span_dict: Dict) -> float:
    """Wall seconds not accounted for by children — the span's own
    time. Children may overlap (concurrent ladder points), so this is
    clamped at zero rather than pretending overlap is negative work."""
    child_wall = sum(c.get("wall_s", 0.0)
                     for c in span_dict.get("children", ()))
    return max(0.0, span_dict.get("wall_s", 0.0) - child_wall)


def render_trace(root: Dict) -> str:
    """One stitched tree as indented text."""
    lines = [f"trace {root.get('trace_id')}"]
    for depth, s in _walk(root):
        attrs = s.get("attrs") or {}
        attr_txt = ("  " + " ".join(f"{k}={v}" for k, v in attrs.items())
                    if attrs else "")
        lines.append(
            f"  {'  ' * depth}{s.get('name')}  "
            f"{s.get('wall_s', 0.0) * 1e3:9.3f} ms  "
            f"[{s.get('source', '?')}]{attr_txt}")
    return "\n".join(lines)


def slowest_spans(trees: List[Dict], n: int) -> List[Dict]:
    rows = []
    for root in trees:
        for _depth, s in _walk(root):
            rows.append({"name": s.get("name"),
                         "source": s.get("source", "?"),
                         "trace_id": s.get("trace_id"),
                         "wall_s": s.get("wall_s", 0.0),
                         "self_s": self_seconds(s)})
    rows.sort(key=lambda r: r["self_s"], reverse=True)
    return rows[:n]


def render_slowest(rows: List[Dict]) -> str:
    lines = ["slowest spans (by self time):",
             f"  {'self ms':>10}  {'total ms':>10}  "
             f"{'span':<28} {'source':<16} trace"]
    for r in rows:
        lines.append(f"  {r['self_s'] * 1e3:10.3f}  "
                     f"{r['wall_s'] * 1e3:10.3f}  "
                     f"{r['name']:<28} {r['source']:<16} {r['trace_id']}")
    return "\n".join(lines)


def cross_process_trees(trees: List[Dict]) -> List[Dict]:
    out = []
    for root in trees:
        sources = {s.get("source") for _d, s in _walk(root)}
        if len(sources) > 1:
            out.append(root)
    return out


def _exemplar_rows(metrics: Dict) -> List[Dict]:
    rows = []
    for name, h in sorted(metrics.get("histograms", {}).items()):
        for ex in h.get("exemplars", []):
            rows.append({"histogram": name, "le": ex.get("le"),
                         "value": ex.get("value"),
                         "trace_id": ex.get("trace_id")})
    return rows


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.trace_tool",
        description="Pull + stitch distributed traces from a "
                    "crispy-daemon fleet (see module docstring).")
    ap.add_argument("--daemon", required=True, metavar="ADDR[,ADDR...]",
                    help="daemon address: unix socket path or host:port; "
                         "comma-separate several to pull a sharded fleet")
    ap.add_argument("--auth-token", default=None,
                    help="shared daemon token "
                         "(default: $CRISPY_DAEMON_TOKEN)")
    ap.add_argument("--timeout", type=float, default=10.0, metavar="S",
                    help="socket timeout in seconds")
    ap.add_argument("--trace", default=None, metavar="ID",
                    help="print only the stitched tree with this trace id")
    ap.add_argument("--slowest", type=int, default=0, metavar="N",
                    help="also print the N slowest spans by self time")
    ap.add_argument("--fleet", action="store_true",
                    help="also print aggregated fleet metrics, per-shard "
                         "daemon op heat, and exemplars")
    ap.add_argument("--json", action="store_true",
                    help="machine form: one JSON object instead of text")
    ap.add_argument("--expect-cross-process", action="store_true",
                    help="exit 1 unless some stitched tree spans >= 2 "
                         "sources (CI assertion)")
    args = ap.parse_args(argv)

    # deferred import: repro.state imports repro.telemetry
    from repro.state.daemon import DaemonBackend

    addresses = [a.strip() for a in args.daemon.split(",") if a.strip()]
    fleet: Dict[str, List[Dict]] = {}
    metrics_by_source: Dict[str, Dict] = {}
    for address in addresses:
        with DaemonBackend(address, timeout_s=args.timeout,
                           auth_token=args.auth_token) as backend:
            # merge across daemons: each shard contributes its own ring
            # under its shard-qualified label, plus whatever was
            # published into the namespaces IT owns on the hash ring
            fleet.update(collect_fleet(backend))
            if args.fleet:
                metrics_by_source.update(collect_fleet_metrics(backend))
    trees = stitch_fleet_traces(fleet)
    if args.trace:
        trees = [t for t in trees if t.get("trace_id") == args.trace]
    fleet_metrics = heat = None
    if args.fleet:
        fleet_metrics = aggregate_fleet(metrics_by_source)
        heat = shard_heat(metrics_by_source)

    crossed = cross_process_trees(trees)

    if args.json:
        out = {"sources": sorted(fleet), "traces": trees,
               "cross_process_traces": len(crossed)}
        if args.slowest:
            out["slowest"] = slowest_spans(trees, args.slowest)
        if fleet_metrics is not None:
            out["fleet"] = fleet_metrics
            out["shard_heat"] = heat
            out["exemplars"] = _exemplar_rows(fleet_metrics)
        print(json.dumps(out, indent=2, sort_keys=True))
    else:
        print(f"sources: {', '.join(sorted(fleet)) or '(none)'}")
        print(f"stitched traces: {len(trees)} "
              f"({len(crossed)} cross-process)")
        for root in trees:
            print()
            print(render_trace(root))
        if args.slowest:
            print()
            print(render_slowest(slowest_spans(trees, args.slowest)))
        if fleet_metrics is not None:
            print()
            rows = _exemplar_rows(fleet_metrics)
            print(f"fleet sources: "
                  f"{', '.join(fleet_metrics.get('sources', []))}")
            if heat:
                print("per-shard daemon op heat:")
                for source in sorted(heat):
                    entry = heat[source]
                    ops = " ".join(f"{op}={n}" for op, n in
                                   entry["ops"].items())
                    print(f"  {source:<28} total={entry['total']:<8} {ops}")
            print(f"exemplars: {len(rows)}")
            for r in rows:
                print(f"  {r['histogram']} le={r['le']} "
                      f"value={r['value']:g} trace={r['trace_id']}")

    if args.expect_cross_process and not crossed:
        print("FAIL: no stitched trace spans more than one source",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
