"""Process-local metrics: counters, gauges, fixed-bucket histograms.

Hot paths in the allocation stack (warm-start lookups, point acquisition,
daemon request dispatch) run at microsecond scale, so every instrument
here is built around a lock-free fast path: each thread writes its own
shard (a plain list only that thread mutates — safe under the GIL) and
shards are folded only when a snapshot is taken. The registry lock is
touched once per (metric, thread) pair, never per observation.

Instruments:

  Counter     monotonically increasing float (`inc(n)`); folded `value`.
  Gauge       last-write-wins float (`set(v)`), e.g. queue depth.
  Histogram   fixed-bucket distribution (`observe(v)`): per-bucket
              counts + sum/count/min/max, with p50/p95/p99 estimated by
              linear interpolation inside the winning bucket. Default
              bucket bounds cover 1us..60s — the latency range of
              everything from an LRU hit to a fresh profile run. When an
              observation happens inside an active trace span, the
              bucket keeps the most recent (value, trace_id) pair as an
              EXEMPLAR — a p99 outlier in a dashboard links straight to
              the stitched distributed trace that produced it (see
              repro.telemetry.spans / export.stitch_fleet_traces).

`MetricsRegistry` names and caches instruments (`counter("a.b")`,
`histogram("a.b.seconds")`); `snapshot()` folds every shard into one
JSON-safe dict (the exporters in `repro.telemetry.export` render it).
A registry constructed with `enabled=False` hands out shared no-op
instruments — the whole telemetry plane compiles down to attribute
lookups, which is what the <5% warm-start overhead regression test pins
against.
"""
from __future__ import annotations

import math
import threading
import time
from bisect import bisect_right
from typing import Dict, List, Optional, Sequence, Tuple

from repro.telemetry.spans import current_span

# 1us .. 60s, roughly 4 buckets per decade: wide enough for an LRU hit
# and a minutes-long profile run to land in *different* buckets
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-6, 2.5e-6, 5e-6, 1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4,
    1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class Counter:
    """Monotonic counter with a per-thread-shard fast path."""

    def __init__(self, name: str):
        self.name = name
        self._local = threading.local()
        self._shards: List[List[float]] = []
        self._lock = threading.Lock()

    def _cell(self) -> List[float]:
        cell = getattr(self._local, "cell", None)
        if cell is None:
            cell = [0.0]
            self._local.cell = cell
            with self._lock:            # once per (counter, thread)
                self._shards.append(cell)
        return cell

    def inc(self, n: float = 1.0) -> None:
        self._cell()[0] += n

    @property
    def value(self) -> float:
        with self._lock:
            return sum(cell[0] for cell in self._shards)


class Gauge:
    """Last-write-wins value (a plain attribute store is atomic under
    the GIL; gauges are too rare to shard)."""

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        # best-effort (gauges tolerate lost updates; use a Counter when
        # exactness matters)
        self._value += n

    @property
    def value(self) -> float:
        return self._value


class _HistShard:
    __slots__ = ("counts", "sum", "count", "min", "max", "exemplars")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf
        # bucket index -> (value, trace_id, epoch_ts); latest wins
        self.exemplars: Dict[int, Tuple[float, str, float]] = {}


class Histogram:
    """Fixed-bucket histogram; per-thread shards folded on snapshot."""

    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.bounds: Tuple[float, ...] = tuple(sorted(float(b)
                                                      for b in buckets))
        self._n = len(self.bounds) + 1          # +1: overflow bucket
        self._local = threading.local()
        self._shards: List[_HistShard] = []
        self._lock = threading.Lock()

    def _shard(self) -> _HistShard:
        s = getattr(self._local, "shard", None)
        if s is None:
            s = _HistShard(self._n)
            self._local.shard = s
            with self._lock:
                self._shards.append(s)
        return s

    def observe(self, v: float) -> None:
        v = float(v)
        s = self._shard()
        idx = bisect_right(self.bounds, v)
        s.counts[idx] += 1
        s.sum += v
        s.count += 1
        if v < s.min:
            s.min = v
        if v > s.max:
            s.max = v
        sp = current_span()          # one contextvar get; None off-trace
        if sp is not None and sp.trace_id is not None:
            s.exemplars[idx] = (v, sp.trace_id, time.time())

    def time(self):
        """Context manager observing the block's wall seconds."""
        return _Timer(self)

    # -- folding ------------------------------------------------------------
    def _fold(self) -> Tuple[List[int], float, int, float, float,
                             Dict[int, Tuple[float, str, float]]]:
        counts = [0] * self._n
        total = 0.0
        n = 0
        lo, hi = math.inf, -math.inf
        exemplars: Dict[int, Tuple[float, str, float]] = {}
        with self._lock:
            shards = list(self._shards)
        for s in shards:
            for i, c in enumerate(s.counts):
                counts[i] += c
            total += s.sum
            n += s.count
            lo = min(lo, s.min)
            hi = max(hi, s.max)
            for i, ex in s.exemplars.items():
                cur = exemplars.get(i)
                if cur is None or ex[2] >= cur[2]:     # latest ts wins
                    exemplars[i] = ex
        return counts, total, n, lo, hi, exemplars

    def summary(self) -> Dict:
        counts, total, n, lo, hi, exemplars = self._fold()
        out = {"count": n, "sum": total,
               "min": lo if n else 0.0, "max": hi if n else 0.0,
               "buckets": counts, "bounds": list(self.bounds)}
        for q, label in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
            out[label] = quantile_from_buckets(self.bounds, counts, q,
                                               lo=lo, hi=hi)
        out["exemplars"] = [
            {"bucket": i,
             "le": (f"{self.bounds[i]:g}" if i < len(self.bounds)
                    else "+Inf"),
             "value": ex[0], "trace_id": ex[1], "ts": ex[2]}
            for i, ex in sorted(exemplars.items())]
        return out

    def percentile(self, q: float) -> float:
        counts, _total, n, lo, hi, _ex = self._fold()
        if not n:
            return 0.0
        return quantile_from_buckets(self.bounds, counts, q, lo=lo, hi=hi)

    @property
    def count(self) -> int:
        return self._fold()[2]


class _Timer:
    __slots__ = ("_hist", "_t0")

    def __init__(self, hist):
        self._hist = hist

    def __enter__(self):
        import time
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        import time
        self._hist.observe(time.perf_counter() - self._t0)


def quantile_from_buckets(bounds: Sequence[float], counts: Sequence[int],
                          q: float, lo: float = math.inf,
                          hi: float = -math.inf) -> float:
    """Estimate the q-quantile of a folded bucket distribution by linear
    interpolation inside the winning bucket (clamped to observed
    min/max where known). Shared by Histogram.summary and the fleet
    aggregator, so merged snapshots report percentiles the same way."""
    n = sum(counts)
    if n == 0:
        return 0.0
    rank = q * n
    seen = 0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        if seen + c >= rank:
            b_lo = bounds[i - 1] if i > 0 else 0.0
            b_hi = bounds[i] if i < len(bounds) else (
                hi if hi > -math.inf else bounds[-1])
            if lo < math.inf:
                b_lo = max(b_lo, min(lo, b_hi))
            if hi > -math.inf:
                b_hi = min(b_hi, hi) if b_hi > hi else b_hi
            frac = (rank - seen) / c
            return b_lo + (b_hi - b_lo) * min(1.0, max(0.0, frac))
        seen += c
    return hi if hi > -math.inf else float(bounds[-1])


# -- no-op instruments (shared singletons; enabled=False registries) ----------

class _NullCounter:
    name = "<null>"
    value = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass


class _NullGauge:
    name = "<null>"
    value = 0.0

    def set(self, v: float) -> None:
        pass

    def inc(self, n: float = 1.0) -> None:
        pass


class _NullHistogram:
    name = "<null>"
    count = 0
    bounds: Tuple[float, ...] = ()

    def observe(self, v: float) -> None:
        pass

    def time(self):
        return _NULL_TIMER

    def summary(self) -> Dict:
        return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                "buckets": [], "bounds": [], "p50": 0.0, "p95": 0.0,
                "p99": 0.0, "exemplars": []}

    def percentile(self, q: float) -> float:
        return 0.0


class _NullTimer:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        pass


_NULL_TIMER = _NullTimer()
NULL_COUNTER = _NullCounter()
NULL_GAUGE = _NullGauge()
NULL_HISTOGRAM = _NullHistogram()


class MetricsRegistry:
    """Named instrument factory + snapshot point (see module docstring).

    `counter/gauge/histogram` return the same instrument for the same
    name (a name may carry only one kind). With `enabled=False` every
    accessor returns a shared no-op instrument and `snapshot()` is
    empty — instrumented code needs no branches of its own."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- factories ----------------------------------------------------------
    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return NULL_COUNTER
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                self._check_free_locked(name, self._counters)
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return NULL_GAUGE
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                self._check_free_locked(name, self._gauges)
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        if not self.enabled:
            return NULL_HISTOGRAM
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                self._check_free_locked(name, self._histograms)
                h = self._histograms[name] = Histogram(name, buckets)
            return h

    def _check_free_locked(self, name: str, own: Dict) -> None:
        for kind in (self._counters, self._gauges, self._histograms):
            if kind is not own and name in kind:
                raise ValueError(
                    f"metric {name!r} already registered as a different "
                    f"instrument kind")

    # -- reporting ----------------------------------------------------------
    def snapshot(self) -> Dict:
        """Fold every shard into one JSON-safe dict."""
        with self._lock:
            counters = list(self._counters.values())
            gauges = list(self._gauges.values())
            hists = list(self._histograms.values())
        return {
            "counters": {c.name: c.value for c in counters},
            "gauges": {g.name: g.value for g in gauges},
            "histograms": {h.name: h.summary() for h in hists},
        }


# -- process default ----------------------------------------------------------

_default = MetricsRegistry()
_default_lock = threading.Lock()


def default_registry() -> MetricsRegistry:
    """The process-wide registry instrumented components fall back to
    when no explicit `telemetry=` is passed."""
    return _default


def set_default_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process default (tests; embedders that want isolation).
    Returns the previous default so callers can restore it."""
    global _default
    with _default_lock:
        prev, _default = _default, registry
        return prev
