"""Exporters: registry snapshots as JSON, Prometheus text, and fleet
snapshots published through any `repro.state.StateBackend`.

Local forms:

  render_json(registry)        one JSON object (the raw `snapshot()`).
  render_prometheus(registry)  Prometheus text exposition: counters as
                               `<name>_total`, gauges verbatim,
                               histograms as cumulative `_bucket{le=..}`
                               series plus `_sum`/`_count` — scrapeable
                               by anything that speaks the format.
                               Bucket lines carry OpenMetrics EXEMPLAR
                               suffixes (`# {trace_id="<id>"} v ts`)
                               when the bucket saw an on-trace
                               observation, so a latency outlier links
                               straight to its stitched trace.
                               `style="flat"` keeps a label-free,
                               non-cumulative per-bucket form for
                               humans and line-oriented diffing.

Fleet form — N service processes plus the daemon aggregate into one
view. Each participant periodically appends its snapshot to a reserved
`__telemetry__` log namespace on the shared backend (the same
append-only shape as the profile store, so daemon compaction folds it);
readers take latest-per-source and can merge sources into fleet totals:

  publish_snapshot(backend, "svc-4711", registry)   # one push
  TelemetryPublisher(backend, "svc-4711", registry,
                     period_s=10.0).start()         # periodic pushes
  fleet_snapshot(backend)       {source: {"ts": .., "metrics": snap}}
  aggregate_fleet(fleet)        counters summed, histogram buckets
                                merged, percentiles recomputed from the
                                merged buckets, exemplars latest-per-
                                bucket

Trace form — the same machinery for finished span trees. Each process
publishes its `TraceRing` roots (span dicts, see Span.to_dict) into a
reserved `__traces__` namespace; `stitch_fleet_traces` then joins the
per-process forests into cross-process trees by grafting any root whose
`parent_id` names a span in ANOTHER process's forest under that span
(remote-parent adoption: the daemon opens its `daemon.op.*` spans as
local roots carrying the caller's trace_id/parent_id — see
repro.telemetry.spans and repro.state.daemon):

  publish_traces(backend, "svc-4711")         # push default_ring roots
  fleet_traces(backend)                       # {source: [root, ...]}
  stitch_fleet_traces(fleet)                  # [cross-process trees]

Every span in a stitched tree is annotated with the `source` that
produced it, so a printed tree reads "this 40 ms request spent 31 ms in
svc-4711 and 9 ms across 3 daemon round-trips".
"""
from __future__ import annotations

import copy
import json
import threading
import time
from typing import Dict, List, Optional

from repro.telemetry.metrics import (MetricsRegistry, quantile_from_buckets)
from repro.telemetry.spans import TraceRing, default_ring

TELEMETRY_NS = "__telemetry__"
TRACES_NS = "__traces__"

# identity fields the state-plane compactor folds the telemetry log on
# (later snapshot per source wins; see repro.state.compaction.fold_log)
KEY_FIELDS = ("source",)


# -- local renderers ----------------------------------------------------------

def render_json(registry: MetricsRegistry, indent: Optional[int] = None,
                ) -> str:
    return json.dumps(registry.snapshot(), indent=indent, sort_keys=True)


def _prom_name(name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if ch.isalnum() or ch == "_" else "_")
    s = "".join(out)
    return s if not s[:1].isdigit() else "_" + s


def render_prometheus(registry: MetricsRegistry, prefix: str = "crispy",
                      style: str = "prom") -> str:
    """Text exposition of a registry snapshot.

    style="prom" (default): the real Prometheus/OpenMetrics shape —
    cumulative `le`-labeled buckets including `+Inf`, `_sum`/`_count`,
    and per-bucket exemplar suffixes (`# {trace_id="..."} value ts`)
    where an on-trace observation was captured.

    style="flat": label-free, NON-cumulative per-bucket lines
    (`<name>_bucket_<i>`) — not scrapeable, but stable for humans and
    line diffs."""
    if style not in ("prom", "flat"):
        raise ValueError(f"unknown prometheus style: {style!r}")
    snap = registry.snapshot()
    lines = []
    for name, value in sorted(snap.get("counters", {}).items()):
        m = f"{prefix}_{_prom_name(name)}_total"
        lines.append(f"# TYPE {m} counter")
        lines.append(f"{m} {value:g}")
    for name, value in sorted(snap.get("gauges", {}).items()):
        m = f"{prefix}_{_prom_name(name)}"
        lines.append(f"# TYPE {m} gauge")
        lines.append(f"{m} {value:g}")
    for name, s in sorted(snap.get("histograms", {}).items()):
        m = f"{prefix}_{_prom_name(name)}"
        lines.append(f"# TYPE {m} histogram")
        exemplars = {ex["bucket"]: ex for ex in s.get("exemplars", [])}
        if style == "flat":
            for i, count in enumerate(s["buckets"]):
                lines.append(f"{m}_bucket_{i} {count}")
        else:
            cum = 0
            n_bounds = len(s["bounds"])
            for i, (bound, count) in enumerate(zip(s["bounds"],
                                                   s["buckets"])):
                cum += count
                lines.append(f'{m}_bucket{{le="{bound:g}"}} {cum}'
                             + _exemplar_suffix(exemplars.get(i)))
            lines.append(f'{m}_bucket{{le="+Inf"}} {s["count"]}'
                         + _exemplar_suffix(exemplars.get(n_bounds)))
        lines.append(f"{m}_sum {s['sum']:g}")
        lines.append(f"{m}_count {s['count']}")
    return "\n".join(lines) + "\n"


def _exemplar_suffix(ex: Optional[Dict]) -> str:
    if not ex:
        return ""
    return (f' # {{trace_id="{ex["trace_id"]}"}} '
            f'{ex["value"]:g} {ex["ts"]:.6f}')


# -- fleet publishing ---------------------------------------------------------

def publish_snapshot(backend, source: str, registry: MetricsRegistry,
                     namespace: str = TELEMETRY_NS) -> Dict:
    """Append one labelled snapshot to the shared telemetry log. Returns
    the published row."""
    row = {"source": source, "ts": time.time(),
           "metrics": registry.snapshot()}
    backend.append(namespace, row)
    return row


def fleet_snapshot(backend, namespace: str = TELEMETRY_NS
                   ) -> Dict[str, Dict]:
    """Latest snapshot per source across every process publishing to
    this backend: {source: {"ts": epoch, "metrics": snapshot}}."""
    rows, _cursor = backend.read(namespace, 0)
    latest: Dict[str, Dict] = {}
    for row in rows:                       # later rows win per source
        src = row.get("source")
        if src is not None:
            latest[src] = {"ts": row.get("ts"),
                           "metrics": row.get("metrics", {})}
    return latest


def aggregate_fleet(fleet: Dict[str, Dict]) -> Dict:
    """Merge per-source snapshots into fleet totals: counters summed,
    histogram buckets merged (bounds must agree — they do, every
    instrument uses DEFAULT_BUCKETS unless deliberately overridden),
    percentiles recomputed from the merged buckets."""
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    hists: Dict[str, Dict] = {}
    for entry in fleet.values():
        snap = entry.get("metrics", {})
        for name, v in snap.get("counters", {}).items():
            counters[name] = counters.get(name, 0.0) + v
        for name, v in snap.get("gauges", {}).items():
            gauges[name] = gauges.get(name, 0.0) + v
        for name, s in snap.get("histograms", {}).items():
            agg = hists.get(name)
            if agg is None:
                hists[name] = {"count": s["count"], "sum": s["sum"],
                               "min": s["min"], "max": s["max"],
                               "buckets": list(s["buckets"]),
                               "bounds": list(s["bounds"]),
                               "exemplars": [dict(ex) for ex in
                                             s.get("exemplars", [])]}
                continue
            if agg["bounds"] != list(s["bounds"]):
                continue                   # incompatible; keep the first
            agg["count"] += s["count"]
            agg["sum"] += s["sum"]
            if s["count"]:
                agg["min"] = (min(agg["min"], s["min"])
                              if agg["count"] - s["count"] else s["min"])
                agg["max"] = max(agg["max"], s["max"])
            agg["buckets"] = [a + b for a, b in zip(agg["buckets"],
                                                    s["buckets"])]
            by_bucket = {ex["bucket"]: ex for ex in agg["exemplars"]}
            for ex in s.get("exemplars", []):
                cur = by_bucket.get(ex["bucket"])
                if cur is None or ex.get("ts", 0) >= cur.get("ts", 0):
                    by_bucket[ex["bucket"]] = dict(ex)
            agg["exemplars"] = [by_bucket[i] for i in sorted(by_bucket)]
    for s in hists.values():
        for q, label in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
            s[label] = quantile_from_buckets(s["bounds"], s["buckets"], q,
                                             lo=s["min"], hi=s["max"])
    return {"counters": counters, "gauges": gauges, "histograms": hists,
            "sources": sorted(fleet)}


def shard_heat(fleet: Dict[str, Dict],
               prefix: str = "daemon.op.") -> Dict[str, Dict]:
    """Per-source daemon op-load breakdown from a fleet snapshot map
    (the same {source: {"metrics": ...}} shape `fleet_snapshot` and
    trace_tool.collect_fleet_metrics return). `aggregate_fleet` sums
    sources together, which is exactly wrong for spotting a hot shard —
    this keeps them apart: {source: {"ops": {op: count}, "total": n}},
    counting observations of each `daemon.op.<op>.seconds` histogram.
    Sources without daemon op histograms (plain services) are omitted,
    so over a sharded fleet the keys are the shard-qualified daemon
    labels ("crispy-daemon@shard-0", ...) and skew is one dict away."""
    suffix = ".seconds"
    heat: Dict[str, Dict] = {}
    for source, entry in fleet.items():
        snap = (entry or {}).get("metrics", {})
        ops: Dict[str, int] = {}
        for name, h in snap.get("histograms", {}).items():
            if name.startswith(prefix) and name.endswith(suffix):
                op = name[len(prefix):-len(suffix)]
                ops[op] = ops.get(op, 0) + int(h.get("count", 0))
        if ops:
            heat[source] = {"ops": dict(sorted(ops.items())),
                            "total": sum(ops.values())}
    return heat


# -- fleet traces -------------------------------------------------------------

def publish_traces(backend, source: str, ring: Optional[TraceRing] = None,
                   namespace: str = TRACES_NS) -> Dict:
    """Append this process's finished root spans (as dicts) to the
    shared trace log. Defaults to the process `default_ring()`. Returns
    the published row."""
    if ring is None:
        ring = default_ring()
    row = {"source": source, "ts": time.time(),
           "traces": [s.to_dict() for s in ring.traces()]}
    backend.append(namespace, row)
    return row


def fleet_traces(backend, namespace: str = TRACES_NS
                 ) -> Dict[str, List[Dict]]:
    """Latest trace forest per source: {source: [root_span_dict, ...]}."""
    rows, _cursor = backend.read(namespace, 0)
    latest: Dict[str, List[Dict]] = {}
    for row in rows:                       # later rows win per source
        src = row.get("source")
        if src is not None:
            latest[src] = row.get("traces", [])
    return latest


def _annotate_source(span_dict: Dict, source: str) -> None:
    span_dict["source"] = source
    for child in span_dict.get("children", ()):
        _annotate_source(child, source)


def _index_spans(span_dict: Dict, root_key: int,
                 index: Dict[str, tuple]) -> None:
    sid = span_dict.get("span_id")
    if sid and sid not in index:           # first definition wins
        index[sid] = (span_dict, root_key)
    for child in span_dict.get("children", ()):
        _index_spans(child, root_key, index)


def stitch_fleet_traces(fleet: Dict[str, List[Dict]]) -> List[Dict]:
    """Join per-process trace forests into cross-process trees.

    A root whose `parent_id` names a span living in another root's tree
    is grafted under that span (this is how a daemon's `daemon.op.*`
    roots — opened with the caller's remote trace context — rejoin the
    caller's `endpoint.request` tree). Roots whose parent never made it
    into any ring stay top-level: an orphan is still a trace. Every
    span is annotated with its producing `source`; children are kept
    sorted by `started_at` so grafted remote spans interleave with local
    ones in causal order."""
    roots: List[Dict] = []
    for source, forest in sorted(fleet.items()):
        for root in forest:
            root = copy.deepcopy(root)
            _annotate_source(root, source)
            roots.append(root)

    index: Dict[str, tuple] = {}
    for key, root in enumerate(roots):
        _index_spans(root, key, index)

    # owner[k] = index of the root that root k was grafted into (path-
    # compressed on walk) — the cycle guard for mutually-parented rings
    owner: Dict[int, int] = {}

    def _resolve(k: int) -> int:
        seen = []
        while k in owner:
            seen.append(k)
            k = owner[k]
        for s in seen:
            owner[s] = k
        return k

    grafted = set()
    for key, root in enumerate(roots):
        pid = root.get("parent_id")
        if not pid or pid not in index:
            continue
        parent_span, parent_key = index[pid]
        if _resolve(parent_key) == key:    # would close a cycle
            continue
        parent_span.setdefault("children", []).append(root)
        parent_span["children"].sort(
            key=lambda s: s.get("started_at", 0.0))
        owner[key] = parent_key
        grafted.add(key)

    out = [r for k, r in enumerate(roots) if k not in grafted]
    out.sort(key=lambda s: s.get("started_at", 0.0))
    return out


class TelemetryPublisher:
    """Background thread pushing periodic snapshots — and, when given a
    `ring`, trace forests — to a backend's telemetry logs. `stop()`
    publishes one final round so short-lived processes still land their
    totals. Publish failures are swallowed: losing a telemetry push must
    never take a service down."""

    def __init__(self, backend, source: str, registry: MetricsRegistry,
                 period_s: float = 10.0, namespace: str = TELEMETRY_NS,
                 ring: Optional[TraceRing] = None,
                 traces_namespace: str = TRACES_NS):
        self.backend = backend
        self.source = source
        self.registry = registry
        self.period_s = period_s
        self.namespace = namespace
        self.ring = ring
        self.traces_namespace = traces_namespace
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _run(self) -> None:
        while not self._stop.wait(self.period_s):
            self._publish()

    def _publish(self) -> None:
        try:
            publish_snapshot(self.backend, self.source, self.registry,
                             self.namespace)
        except Exception:
            pass
        if self.ring is not None:
            try:
                publish_traces(self.backend, self.source, self.ring,
                               self.traces_namespace)
            except Exception:
                pass

    def start(self) -> "TelemetryPublisher":
        if self._thread is None:
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        self._publish()                     # final totals

    def __enter__(self) -> "TelemetryPublisher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
