"""Exporters: registry snapshots as JSON, Prometheus text, and fleet
snapshots published through any `repro.state.StateBackend`.

Local forms:

  render_json(registry)        one JSON object (the raw `snapshot()`).
  render_prometheus(registry)  Prometheus text exposition: counters as
                               `<name>_total`, gauges verbatim,
                               histograms as cumulative `_bucket{le=..}`
                               series plus `_sum`/`_count` — scrapeable
                               by anything that speaks the format.

Fleet form — N service processes plus the daemon aggregate into one
view. Each participant periodically appends its snapshot to a reserved
`__telemetry__` log namespace on the shared backend (the same
append-only shape as the profile store, so daemon compaction folds it);
readers take latest-per-source and can merge sources into fleet totals:

  publish_snapshot(backend, "svc-4711", registry)   # one push
  TelemetryPublisher(backend, "svc-4711", registry,
                     period_s=10.0).start()         # periodic pushes
  fleet_snapshot(backend)       {source: {"ts": .., "metrics": snap}}
  aggregate_fleet(fleet)        counters summed, histogram buckets
                                merged, percentiles recomputed from the
                                merged buckets
"""
from __future__ import annotations

import json
import threading
import time
from typing import Dict, Optional

from repro.telemetry.metrics import (MetricsRegistry, quantile_from_buckets)

TELEMETRY_NS = "__telemetry__"

# identity fields the state-plane compactor folds the telemetry log on
# (later snapshot per source wins; see repro.state.compaction.fold_log)
KEY_FIELDS = ("source",)


# -- local renderers ----------------------------------------------------------

def render_json(registry: MetricsRegistry, indent: Optional[int] = None,
                ) -> str:
    return json.dumps(registry.snapshot(), indent=indent, sort_keys=True)


def _prom_name(name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if ch.isalnum() or ch == "_" else "_")
    s = "".join(out)
    return s if not s[:1].isdigit() else "_" + s


def render_prometheus(registry: MetricsRegistry,
                      prefix: str = "crispy") -> str:
    snap = registry.snapshot()
    lines = []
    for name, value in sorted(snap.get("counters", {}).items()):
        m = f"{prefix}_{_prom_name(name)}_total"
        lines.append(f"# TYPE {m} counter")
        lines.append(f"{m} {value:g}")
    for name, value in sorted(snap.get("gauges", {}).items()):
        m = f"{prefix}_{_prom_name(name)}"
        lines.append(f"# TYPE {m} gauge")
        lines.append(f"{m} {value:g}")
    for name, s in sorted(snap.get("histograms", {}).items()):
        m = f"{prefix}_{_prom_name(name)}"
        lines.append(f"# TYPE {m} histogram")
        cum = 0
        for bound, count in zip(s["bounds"], s["buckets"]):
            cum += count
            lines.append(f'{m}_bucket{{le="{bound:g}"}} {cum}')
        lines.append(f'{m}_bucket{{le="+Inf"}} {s["count"]}')
        lines.append(f"{m}_sum {s['sum']:g}")
        lines.append(f"{m}_count {s['count']}")
    return "\n".join(lines) + "\n"


# -- fleet publishing ---------------------------------------------------------

def publish_snapshot(backend, source: str, registry: MetricsRegistry,
                     namespace: str = TELEMETRY_NS) -> Dict:
    """Append one labelled snapshot to the shared telemetry log. Returns
    the published row."""
    row = {"source": source, "ts": time.time(),
           "metrics": registry.snapshot()}
    backend.append(namespace, row)
    return row


def fleet_snapshot(backend, namespace: str = TELEMETRY_NS
                   ) -> Dict[str, Dict]:
    """Latest snapshot per source across every process publishing to
    this backend: {source: {"ts": epoch, "metrics": snapshot}}."""
    rows, _cursor = backend.read(namespace, 0)
    latest: Dict[str, Dict] = {}
    for row in rows:                       # later rows win per source
        src = row.get("source")
        if src is not None:
            latest[src] = {"ts": row.get("ts"),
                           "metrics": row.get("metrics", {})}
    return latest


def aggregate_fleet(fleet: Dict[str, Dict]) -> Dict:
    """Merge per-source snapshots into fleet totals: counters summed,
    histogram buckets merged (bounds must agree — they do, every
    instrument uses DEFAULT_BUCKETS unless deliberately overridden),
    percentiles recomputed from the merged buckets."""
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    hists: Dict[str, Dict] = {}
    for entry in fleet.values():
        snap = entry.get("metrics", {})
        for name, v in snap.get("counters", {}).items():
            counters[name] = counters.get(name, 0.0) + v
        for name, v in snap.get("gauges", {}).items():
            gauges[name] = gauges.get(name, 0.0) + v
        for name, s in snap.get("histograms", {}).items():
            agg = hists.get(name)
            if agg is None:
                hists[name] = {"count": s["count"], "sum": s["sum"],
                               "min": s["min"], "max": s["max"],
                               "buckets": list(s["buckets"]),
                               "bounds": list(s["bounds"])}
                continue
            if agg["bounds"] != list(s["bounds"]):
                continue                   # incompatible; keep the first
            agg["count"] += s["count"]
            agg["sum"] += s["sum"]
            if s["count"]:
                agg["min"] = (min(agg["min"], s["min"])
                              if agg["count"] - s["count"] else s["min"])
                agg["max"] = max(agg["max"], s["max"])
            agg["buckets"] = [a + b for a, b in zip(agg["buckets"],
                                                    s["buckets"])]
    for s in hists.values():
        for q, label in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
            s[label] = quantile_from_buckets(s["bounds"], s["buckets"], q,
                                             lo=s["min"], hi=s["max"])
    return {"counters": counters, "gauges": gauges, "histograms": hists,
            "sources": sorted(fleet)}


class TelemetryPublisher:
    """Background thread pushing periodic snapshots to a backend's
    telemetry log. `stop()` publishes one final snapshot so short-lived
    processes still land their totals. Publish failures are swallowed:
    losing a telemetry push must never take a service down."""

    def __init__(self, backend, source: str, registry: MetricsRegistry,
                 period_s: float = 10.0, namespace: str = TELEMETRY_NS):
        self.backend = backend
        self.source = source
        self.registry = registry
        self.period_s = period_s
        self.namespace = namespace
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _run(self) -> None:
        while not self._stop.wait(self.period_s):
            self._publish()

    def _publish(self) -> None:
        try:
            publish_snapshot(self.backend, self.source, self.registry,
                             self.namespace)
        except Exception:
            pass

    def start(self) -> "TelemetryPublisher":
        if self._thread is None:
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        self._publish()                     # final totals

    def __enter__(self) -> "TelemetryPublisher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
