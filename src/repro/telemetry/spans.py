"""Nested, thread-aware wall-time spans over `contextvars`.

A `span("name", **attrs)` block times itself and attaches to whatever
span is current in this context; the outermost span of a context becomes
a root and is recorded into a bounded in-memory `TraceRing` when it
closes. `contextvars` gives thread isolation for free: each thread (and
each asyncio task, should one ever appear) sees its own current-span
chain, so concurrent pipeline plans never splice into each other's
trees.

    with span("pipeline.plan", signature=sig):
        with span("pipeline.acquire"):
            ...
    for root in default_ring().traces():
        print(root.to_dict())   # {"name": ..., "wall_s": ..., "children": ...}

Spans are deliberately tiny (one object, two perf_counter calls, one
contextvar set/reset) — cheap enough to leave on in production hot
paths; instrumented code that wants a zero-cost off switch uses
`span_if(enabled, ...)`, which degrades to a shared no-op context
manager.
"""
from __future__ import annotations

import contextvars
import threading
import time
from collections import deque
from typing import Dict, List, Optional

_current: "contextvars.ContextVar[Optional[Span]]" = \
    contextvars.ContextVar("crispy_current_span", default=None)


class Span:
    """One timed block: name, attributes, children, wall seconds."""

    __slots__ = ("name", "attrs", "started_at", "wall_s", "children",
                 "thread")

    def __init__(self, name: str, attrs: Dict):
        self.name = name
        self.attrs = attrs
        self.started_at = time.time()        # epoch, for export
        self.wall_s = 0.0
        self.children: List[Span] = []
        self.thread = threading.current_thread().name

    def to_dict(self) -> Dict:
        out = {"name": self.name, "started_at": self.started_at,
               "wall_s": self.wall_s, "thread": self.thread}
        if self.attrs:
            out["attrs"] = dict(self.attrs)
        if self.children:
            out["children"] = [c.to_dict() for c in self.children]
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, wall_s={self.wall_s:.6f}, "
                f"children={len(self.children)})")


class TraceRing:
    """Bounded ring of finished ROOT spans (children live inside their
    roots). Thread-safe; oldest traces fall off the end."""

    def __init__(self, cap: int = 256):
        self.cap = cap
        self._ring: "deque[Span]" = deque(maxlen=cap)
        self._lock = threading.Lock()

    def record(self, span_: Span) -> None:
        with self._lock:
            self._ring.append(span_)

    def traces(self) -> List[Span]:
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)


_default_ring = TraceRing()


def default_ring() -> TraceRing:
    return _default_ring


def current_span() -> Optional[Span]:
    """The innermost open span of this thread/context, or None."""
    return _current.get()


class _SpanContext:
    """The `span(...)` context manager (a class, not @contextmanager:
    ~2x cheaper to enter and exit, and this sits on hot paths)."""

    __slots__ = ("_span", "_ring", "_token", "_t0")

    def __init__(self, name: str, ring: Optional[TraceRing], attrs: Dict):
        self._span = Span(name, attrs)
        self._ring = ring

    def __enter__(self) -> Span:
        self._token = _current.set(self._span)
        self._t0 = time.perf_counter()
        return self._span

    def __exit__(self, *exc) -> None:
        s = self._span
        s.wall_s = time.perf_counter() - self._t0
        _current.reset(self._token)
        parent = _current.get()
        if parent is not None:
            parent.children.append(s)
        else:
            (self._ring if self._ring is not None
             else _default_ring).record(s)


def span(name: str, ring: Optional[TraceRing] = None,
         **attrs) -> _SpanContext:
    """Open a timed span; nested calls build a tree, the outermost lands
    in `ring` (default: the process ring) when it exits."""
    return _SpanContext(name, ring, attrs)


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        pass


_NULL_SPAN = _NullSpan()


def span_if(enabled: bool, name: str, ring: Optional[TraceRing] = None,
            **attrs):
    """`span(...)` when `enabled`, else a shared no-op context manager —
    the branch instrumented hot paths use so a disabled registry costs
    one attribute load."""
    if not enabled:
        return _NULL_SPAN
    return _SpanContext(name, ring, attrs)
