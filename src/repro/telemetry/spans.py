"""Nested, thread-aware wall-time spans over `contextvars`, with
distributed-trace identity.

A `span("name", **attrs)` block times itself and attaches to whatever
span is current in this context; the outermost span of a context becomes
a root and is recorded into a bounded in-memory `TraceRing` when it
closes. `contextvars` gives thread isolation for free: each thread (and
each asyncio task, should one ever appear) sees its own current-span
chain, so concurrent pipeline plans never splice into each other's
trees.

Every span carries distributed-tracing identity:

  trace_id    16 hex chars, minted when a trace's first span opens and
              inherited by every descendant — including descendants in
              OTHER processes (the daemon wire protocol forwards it).
  span_id     16 hex chars, unique per span.
  parent_id   the parent's span_id. For a local child this is implied by
              tree position; for a span adopted from a REMOTE parent
              (`span(..., parent={"trace_id": .., "span_id": ..})`) it
              is the only link — `stitch_fleet_traces` in
              repro.telemetry.export grafts such roots back under their
              cross-process parent.

Clock discipline: each trace anchors wall-clock time ONCE — the root
span records an `(epoch, perf_counter)` pair when it opens, and every
descendant derives `started_at = epoch + (perf_counter_now - anchor)`.
Sibling spans therefore can never disagree with their walls after an
NTP step mid-trace: `time.time()` is consulted exactly once per local
trace, all offsets come from the monotonic clock.

    with span("pipeline.plan", signature=sig):
        with span("pipeline.acquire"):
            ...
    for root in default_ring().traces():
        print(root.to_dict())   # {"name", "trace_id", "span_id", ...}

Spans are deliberately tiny (one object, two perf_counter calls, one
contextvar set/reset, one 64-bit id draw) — cheap enough to leave on in
production hot paths; instrumented code that wants a zero-cost off
switch uses `span_if(enabled, ...)`, which degrades to a shared no-op
context manager.

`current_trace_context()` returns the innermost open span's
`{"trace_id", "span_id"}` (or None) — the propagation token clients
stamp onto wire frames (see repro.state.transport.TRACE_FIELD) and
`StructuredLogger` stamps onto log lines.
"""
from __future__ import annotations

import contextvars
import random
import threading
import time
from collections import deque
from typing import Dict, List, Optional

_current: "contextvars.ContextVar[Optional[Span]]" = \
    contextvars.ContextVar("crispy_current_span", default=None)

# id source: a private urandom-seeded Mersenne instance. getrandbits on
# a shared Random is a single C call (atomic under the GIL) and ~10x
# cheaper than os.urandom per span — collisions at 64 bits are
# negligible for bounded rings of short-lived traces.
_ids = random.Random()


def new_span_id() -> str:
    """A fresh 64-bit hex id (used for both trace and span ids)."""
    return f"{_ids.getrandbits(64):016x}"


class Span:
    """One timed block: identity, name, attributes, children, wall
    seconds. `anchor` is the trace's (epoch, perf_counter) pair — see
    the module docstring for the clock discipline."""

    __slots__ = ("name", "attrs", "trace_id", "span_id", "parent_id",
                 "started_at", "wall_s", "children", "thread", "anchor")

    def __init__(self, name: str, attrs: Dict):
        self.name = name
        self.attrs = attrs
        self.trace_id: Optional[str] = None
        self.span_id: Optional[str] = None
        self.parent_id: Optional[str] = None
        self.started_at = 0.0
        self.wall_s = 0.0
        self.children: List[Span] = []
        self.thread = threading.current_thread().name
        self.anchor = None          # (epoch_s, perf_counter_s) of the trace

    def context(self) -> Dict[str, str]:
        """The propagation token for this span: {"trace_id", "span_id"}."""
        return {"trace_id": self.trace_id, "span_id": self.span_id}

    def to_dict(self) -> Dict:
        out = {"name": self.name, "trace_id": self.trace_id,
               "span_id": self.span_id, "started_at": self.started_at,
               "wall_s": self.wall_s, "thread": self.thread}
        if self.parent_id is not None:
            out["parent_id"] = self.parent_id
        if self.attrs:
            out["attrs"] = dict(self.attrs)
        if self.children:
            out["children"] = [c.to_dict() for c in self.children]
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, trace={self.trace_id}, "
                f"wall_s={self.wall_s:.6f}, "
                f"children={len(self.children)})")


class TraceRing:
    """Bounded ring of finished ROOT spans (children live inside their
    roots). Thread-safe; oldest traces fall off the end. `recorded` is
    the monotonic count of roots ever recorded — ring wrap-around never
    hides throughput from the load benchmarks."""

    def __init__(self, cap: int = 256):
        self.cap = cap
        self._ring: "deque[Span]" = deque(maxlen=cap)
        self._recorded = 0
        self._lock = threading.Lock()

    def record(self, span_: Span) -> None:
        with self._lock:
            self._ring.append(span_)
            self._recorded += 1

    def traces(self) -> List[Span]:
        with self._lock:
            return list(self._ring)

    @property
    def recorded(self) -> int:
        with self._lock:
            return self._recorded

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)


_default_ring = TraceRing()


def default_ring() -> TraceRing:
    return _default_ring


def current_span() -> Optional[Span]:
    """The innermost open span of this thread/context, or None."""
    return _current.get()


def current_trace_context() -> Optional[Dict[str, str]]:
    """The innermost open span's {"trace_id", "span_id"}, or None —
    what wire clients stamp onto outgoing frames so remote work joins
    this trace."""
    s = _current.get()
    if s is None or s.trace_id is None:
        return None
    return {"trace_id": s.trace_id, "span_id": s.span_id}


class _SpanContext:
    """The `span(...)` context manager (a class, not @contextmanager:
    ~2x cheaper to enter and exit, and this sits on hot paths)."""

    __slots__ = ("_span", "_ring", "_parent", "_token", "_t0")

    def __init__(self, name: str, ring: Optional[TraceRing],
                 parent: Optional[Dict], attrs: Dict):
        self._span = Span(name, attrs)
        self._ring = ring
        self._parent = parent

    def __enter__(self) -> Span:
        s = self._span
        local_parent = _current.get()
        t0 = time.perf_counter()
        if local_parent is not None and local_parent.anchor is not None:
            # inherit the trace: identity AND its one clock anchor
            s.trace_id = local_parent.trace_id
            s.parent_id = local_parent.span_id
            s.anchor = local_parent.anchor
        else:
            remote = self._parent
            if remote:
                # adopted from another process/thread: same trace id,
                # remote span as parent — but a FRESH local clock anchor
                # (the remote one lives on a different host clock)
                s.trace_id = remote.get("trace_id") or new_span_id()
                s.parent_id = remote.get("span_id")
            else:
                s.trace_id = new_span_id()
            s.anchor = (time.time(), t0)
        s.span_id = new_span_id()
        s.started_at = s.anchor[0] + (t0 - s.anchor[1])
        self._token = _current.set(s)
        self._t0 = t0
        return s

    def __exit__(self, *exc) -> None:
        s = self._span
        s.wall_s = time.perf_counter() - self._t0
        _current.reset(self._token)
        parent = _current.get()
        if parent is not None:
            parent.children.append(s)
        else:
            (self._ring if self._ring is not None
             else _default_ring).record(s)


def span(name: str, ring: Optional[TraceRing] = None,
         parent: Optional[Dict] = None, **attrs) -> _SpanContext:
    """Open a timed span; nested calls build a tree, the outermost lands
    in `ring` (default: the process ring) when it exits. `parent` is an
    optional REMOTE trace context ({"trace_id", "span_id"}, e.g. taken
    off a wire frame): the span joins that trace as a cross-process
    child — ignored when a local parent span is already open."""
    return _SpanContext(name, ring, parent, attrs)


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        pass


_NULL_SPAN = _NullSpan()


def span_if(enabled: bool, name: str, ring: Optional[TraceRing] = None,
            parent: Optional[Dict] = None, **attrs):
    """`span(...)` when `enabled`, else a shared no-op context manager —
    the branch instrumented hot paths use so a disabled registry costs
    one attribute load."""
    if not enabled:
        return _NULL_SPAN
    return _SpanContext(name, ring, parent, attrs)
