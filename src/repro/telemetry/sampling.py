"""Adaptive warm-path sampling: spend observation budget where latency
is misbehaving.

The pipeline's warm path (registry hit -> extrapolate -> select) answers
in tens of microseconds, so PR-6 sampled its stage histograms 1-in-8 to
keep telemetry off the critical path. That rate is a blind spot exactly
when it matters: if warm-path p99 starts drifting, 7 of 8 samples —
and 7 of 8 would-be exemplars — are thrown away while the regression
is live. `AdaptiveSampler` closes the loop:

  * it watches `pipeline.stage.<stage>.seconds` p99 computed over a
    WINDOW (bucket-count deltas between ticks — the histograms
    themselves are cumulative, so raw p99 would never recover after a
    single bad burst);
  * when windowed p99 crosses `gate_p99_s`, the sampling mask halves
    (1-in-8 -> 1-in-4 -> ... -> 1-in-1), one step per tick;
  * when p99 falls back under `recover_p99_s` (default: gate/2 —
    hysteresis, so a p99 hovering at the gate doesn't flap the rate),
    the mask decays one step back toward `base_mask`.

Masks are `2**k - 1` values used as `counter & mask == 0` tests by the
pipeline, so "rate" here is always a power of two. The sampler itself
is instrumented: counters `sampling.{escalations,decays}` and gauge
`sampling.mask` make rate changes visible in every fleet snapshot.

`tick()` is called from the pipeline's sampled (1-in-mask) branches and
is interval-gated, so its steady-state cost is one clock read + compare.
The clock is injectable for deterministic tests.

`FixedSampler` keeps the PR-6 behavior (constant mask) for callers that
want it; `resolve_sampler` maps the `sampler=` constructor argument
(None | "adaptive" | "fixed" | int | instance) to an instance.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence

from repro.telemetry.metrics import (MetricsRegistry, default_registry,
                                     quantile_from_buckets)

DEFAULT_STAGES = ("warm_start", "extrapolate", "select")


class FixedSampler:
    """Constant-rate sampler: `mask` is forever what you constructed it
    with (7 -> 1-in-8, 0 -> every observation)."""

    def __init__(self, mask: int = 7):
        if mask < 0 or (mask & (mask + 1)) != 0:
            raise ValueError(f"mask must be 2**k - 1, got {mask}")
        self.mask = mask

    def tick(self, force: bool = False) -> int:
        return self.mask


class AdaptiveSampler:
    """Escalate the warm-path sampling rate while stage p99 drifts past
    a gate; decay it back once latency recovers (see module docstring).

    Parameters:
      telemetry      registry whose `pipeline.stage.<stage>.seconds`
                     histograms are watched (default: process default).
      stages         stage names to watch (the warm-path trio).
      gate_p99_s     windowed p99 above this escalates sampling.
      recover_p99_s  windowed p99 below this (on every watched stage)
                     decays sampling; default gate/2.
      interval_s     min seconds between evaluations; `tick()` calls in
                     between just return the current mask.
      base_mask      resting mask (7 = 1-in-8, the PR-6 rate).
      min_mask       floor while escalated (0 = sample everything).
      clock          injectable monotonic clock for tests.
    """

    def __init__(self, telemetry: Optional[MetricsRegistry] = None,
                 stages: Sequence[str] = DEFAULT_STAGES,
                 gate_p99_s: float = 0.005,
                 recover_p99_s: Optional[float] = None,
                 interval_s: float = 2.0,
                 base_mask: int = 7, min_mask: int = 0,
                 clock=time.monotonic):
        for m in (base_mask, min_mask):
            if m < 0 or (m & (m + 1)) != 0:
                raise ValueError(f"mask must be 2**k - 1, got {m}")
        if min_mask > base_mask:
            raise ValueError("min_mask must not exceed base_mask")
        self.telemetry = telemetry if telemetry is not None \
            else default_registry()
        self.stages = tuple(stages)
        self.gate_p99_s = gate_p99_s
        self.recover_p99_s = (recover_p99_s if recover_p99_s is not None
                              else gate_p99_s / 2.0)
        self.interval_s = interval_s
        self.base_mask = base_mask
        self.min_mask = min_mask
        self.mask = base_mask
        self._clock = clock
        self._last_tick = -float("inf")
        # per-stage cumulative bucket counts at the previous evaluation,
        # so each tick sees only the WINDOW of new observations
        self._prev: Dict[str, list] = {}
        tel = self.telemetry
        self._escalations = tel.counter("sampling.escalations")
        self._decays = tel.counter("sampling.decays")
        self._gauge = tel.gauge("sampling.mask")
        self._gauge.set(self.mask)

    # -- evaluation ---------------------------------------------------------
    def _windowed_p99(self, stage: str) -> Optional[float]:
        """p99 over observations since the previous tick, or None when
        the window is empty (no traffic -> no opinion)."""
        hist = self.telemetry.histogram(f"pipeline.stage.{stage}.seconds")
        counts, _total, n, lo, hi, _ex = hist._fold()
        prev = self._prev.get(stage)
        if prev is None or len(prev) != len(counts):
            delta = list(counts)
        else:
            delta = [c - p for c, p in zip(counts, prev)]
        self._prev[stage] = list(counts)
        window_n = sum(delta)
        if window_n <= 0:
            return None
        return quantile_from_buckets(hist.bounds, delta, 0.99,
                                     lo=lo, hi=hi)

    def tick(self, force: bool = False) -> int:
        """Re-evaluate at most once per `interval_s`; returns the mask
        the caller should sample with from now on."""
        if not self.telemetry.enabled:     # no histograms to watch
            return self.mask
        now = self._clock()
        if not force and now - self._last_tick < self.interval_s:
            return self.mask
        self._last_tick = now
        worst: Optional[float] = None
        for stage in self.stages:
            p99 = self._windowed_p99(stage)
            if p99 is not None and (worst is None or p99 > worst):
                worst = p99
        if worst is None:                  # idle window: hold the rate
            return self.mask
        if worst > self.gate_p99_s and self.mask > self.min_mask:
            self.mask >>= 1                # double the sampling rate
            self._escalations.inc()
            self._gauge.set(self.mask)
        elif worst <= self.recover_p99_s and self.mask < self.base_mask:
            self.mask = (self.mask << 1) | 1
            self._decays.inc()
            self._gauge.set(self.mask)
        return self.mask


def resolve_sampler(spec, telemetry: Optional[MetricsRegistry] = None):
    """Map a `sampler=` constructor argument to a sampler instance:

      None / "fixed"   FixedSampler(7) — the PR-6 constant 1-in-8
      "adaptive"       AdaptiveSampler(telemetry)
      int              FixedSampler(mask=spec)
      instance         passed through (anything with .mask and .tick())
    """
    if spec is None or spec == "fixed":
        return FixedSampler()
    if spec == "adaptive":
        return AdaptiveSampler(telemetry)
    if isinstance(spec, int):
        return FixedSampler(spec)
    if hasattr(spec, "tick") and hasattr(spec, "mask"):
        return spec
    raise ValueError(f"unknown sampler spec: {spec!r}")
