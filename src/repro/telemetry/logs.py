"""Structured logging: one JSON object per line on stderr.

The daemon (and anything else with a server lifecycle) logs through
this instead of ad-hoc `print(..., file=sys.stderr)`: every line is
machine-parseable `{"ts", "level", "component", "event", ...fields}`,
so multi-process test harnesses and log shippers stop grepping prose.
stdout is never touched — CLI contracts like the daemon's `--ping` ->
"pong" stay byte-identical.

    log = StructuredLogger("crispy-daemon")
    log.info("serving", unix=sock_path, tcp=tcp_addr)
    log.error("bind failed", error=str(e))

Levels: debug < info < warn < error; records below `level` are dropped.
Non-JSON-serializable field values are stringified rather than raised —
a log line must never take the server down.

Lines emitted inside an active trace span are stamped with `trace_id`
and `span_id` automatically, so stderr joins the distributed traces for
free (explicit `trace_id=`/`span_id=` fields win over the stamp).
"""
from __future__ import annotations

import json
import sys
import time
from typing import Optional, TextIO

from repro.telemetry.spans import current_span

_LEVELS = {"debug": 10, "info": 20, "warn": 30, "error": 40}


class StructuredLogger:
    """Minimal leveled JSON-lines logger (stderr by default)."""

    def __init__(self, component: str, stream: Optional[TextIO] = None,
                 level: str = "info"):
        self.component = component
        self.stream = stream
        self.threshold = _LEVELS.get(level, 20)

    def log(self, level: str, event: str, **fields) -> None:
        if _LEVELS.get(level, 20) < self.threshold:
            return
        rec = {"ts": round(time.time(), 3), "level": level,
               "component": self.component, "event": event}
        sp = current_span()
        if sp is not None and sp.trace_id is not None:
            rec["trace_id"] = sp.trace_id
            rec["span_id"] = sp.span_id
        rec.update(fields)
        try:
            line = json.dumps(rec, default=str)
        except (TypeError, ValueError):     # pathological keys
            line = json.dumps({"ts": rec["ts"], "level": level,
                               "component": self.component,
                               "event": str(event)})
        stream = self.stream if self.stream is not None else sys.stderr
        try:
            print(line, file=stream, flush=True)
        except (OSError, ValueError):
            pass                            # closed stream on shutdown

    def debug(self, event: str, **fields) -> None:
        self.log("debug", event, **fields)

    def info(self, event: str, **fields) -> None:
        self.log("info", event, **fields)

    def warn(self, event: str, **fields) -> None:
        self.log("warn", event, **fields)

    def error(self, event: str, **fields) -> None:
        self.log("error", event, **fields)
