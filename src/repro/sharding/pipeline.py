"""GPipe-style pipeline parallelism via shard_map + collective_permute.

For the 256-chip assigned meshes TP×DP saturates every arch without
pipeline bubbles (DESIGN.md §5), so PP is not in the dry-run presets; this
module provides the mechanism the >4k-chip deployment note refers to, with
correctness tests on a real multi-device mesh (tests/test_distribution.py).

Layout: mesh axis 'pipe' with P stages; the layer stack (L, ...) is split
into P contiguous blocks of L/P layers, stage s holding block s (leading
stacked axis sharded over 'pipe'). Microbatches stream through the classic
GPipe schedule: T = n_micro + P - 1 ticks, stage s working on microbatch
t - s at tick t; activations hop stages with collective_permute. The whole
schedule lives inside one lax.scan, so it jits, differentiates (jax AD
transposes collective_permute to the reverse permutation — backward flows
automatically) and composes with the data/model axes of the same mesh.

Bubble fraction = (P-1)/(T) as usual; choose n_micro >> P.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import compat_axis_size, compat_shard_map


def _pipeline_body(stage_params, x_micro, *, fn: Callable, n_micro: int,
                   axis: str):
    """shard_map body. stage_params: this stage's (L/P, ...) layer slice;
    x_micro: (n_micro, B, S, d) — full input stream, replicated over
    'pipe' (stage 0 reads it; others ignore). Returns (n_micro, B, S, d)
    outputs (valid on every stage after the final broadcast)."""
    n_stages = compat_axis_size(axis)
    stage = lax.axis_index(axis)
    ticks = n_micro + n_stages - 1
    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def tick(carry, t):
        incoming, outputs = carry
        # stage 0 ingests microbatch t (clamped; bubble ticks are masked)
        mb_in = lax.dynamic_index_in_dim(
            x_micro, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
        x_in = jnp.where(stage == 0, mb_in, incoming)
        y = fn(stage_params, x_in)
        # the last stage emits microbatch t - (P-1)
        out_idx = t - (n_stages - 1)
        emit = (stage == n_stages - 1) & (out_idx >= 0)
        idx = jnp.clip(out_idx, 0, n_micro - 1)
        current = lax.dynamic_index_in_dim(outputs, idx, 0, keepdims=False)
        outputs = lax.dynamic_update_index_in_dim(
            outputs, jnp.where(emit, y, current), idx, 0)
        # hop activations forward
        nxt = lax.ppermute(y, axis, fwd_perm)
        return (nxt, outputs), None

    init = (jnp.zeros_like(x_micro[0]),
            jnp.zeros_like(x_micro))
    (_, outputs), _ = lax.scan(tick, init, jnp.arange(ticks))
    # only the last stage holds real outputs; broadcast via masked psum
    # (ppermute can't fan out one source to all destinations)
    outputs = lax.psum(
        jnp.where(stage == n_stages - 1, outputs, 0), axis)
    return outputs


def pipeline_apply(fn: Callable, stacked_params, x, mesh, *,
                   n_micro: int, axis: str = "pipe"):
    """Run `x` through the full stacked layer group with the stack split
    over the mesh's `axis` dimension.

    fn(stage_params, x) must apply a (L/P, ...) stacked slice (e.g. a
    lax.scan over its layers). x: (B, S, d); B must divide into n_micro.
    """
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    x_micro = x.reshape(n_micro, B // n_micro, *x.shape[1:])
    body = functools.partial(_pipeline_body, fn=fn, n_micro=n_micro,
                             axis=axis)
    # stacked params: leading layer axis sharded over the pipe axis
    pspec = jax.tree.map(lambda _: P(axis), stacked_params)
    out = compat_shard_map(
        body, mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        check_vma=False)(stacked_params, x_micro)
    return out.reshape(B, *x.shape[1:])
