"""Logical-axis sharding rules: param-tree leaf name -> PartitionSpec.

Megatron-style tensor parallelism over the 'model' mesh axis:
  attention heads, MLP ff dim, expert dim, vocab dim -> 'model'
plus optional FSDP of expert ff over 'data' (RunConfig.fsdp_experts) and
ZeRO-1 sharding of optimizer state over ('pod','data').

Specs are *trailing-dim* patterns: stacked scan params (leading layer /
group dims) get Nones prepended automatically. A dim whose size is not
divisible by its mesh axis falls back to replicated (e.g. whisper's 12
heads on a 16-way model axis, chatglm's 2 KV heads) — correctness first,
GSPMD still shards everything divisible.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig

# (leaf name, trailing spec). First rank-compatible match wins.
_RULES = [
    ("embed", ("model", None)),
    ("head", ("model", None)),
    # attention projections (d, H, Dh) / (H, Dh, d)
    ("wq", (None, "model", None)),
    ("wk", (None, "model", None)),
    ("wv", (None, "model", None)),
    ("wo", ("model", None, None)),
    ("wo", ("model", None)),            # rwkv output (d, d)
    # MLA
    ("wuq", (None, "model", None)),
    ("wuk", (None, "model", None)),
    ("wuv", (None, "model", None)),
    # dense MLP (d, ff) / (ff, d)
    ("gate", (None, "model")),
    ("up", (None, "model")),
    ("down", ("model", None)),
    # MoE experts (E, d, f) / (E, f, d); f optionally FSDP over data
    ("w_gate", ("model", None, "__ff__")),
    ("w_up", ("model", None, "__ff__")),
    ("w_down", ("model", "__ff__", None)),
    # rwkv
    ("wg", (None, "model")),
    ("w_lora_b", (None, "model", None)),
    ("u", ("model", None)),
    ("w0", ("model", None)),
    ("cm_k", (None, "model")),
    ("cm_v", ("model", None)),
    # mamba2
    ("in_proj", (None, "model")),
    ("out_proj", ("model", None)),
    ("conv_w", (None, "model")),
    ("conv_b", ("model",)),
    ("A_log", ("model",)),
    ("D", ("model",)),
    ("dt_bias", ("model",)),
    ("ssm_norm", ("model",)),
]


def _axis_size(mesh: Mesh, name: str) -> int:
    try:
        return mesh.shape[name]
    except (KeyError, TypeError):
        return 1


def _leaf_name(path) -> str:
    for p in reversed(path):
        k = getattr(p, "key", None)
        if isinstance(k, str):
            return k
        n = getattr(p, "name", None)
        if isinstance(n, str):
            return n
    return ""


def _spec_for(name: str, shape: Tuple[int, ...], mesh: Mesh,
              run: RunConfig) -> P:
    ff_axis = "data" if run.fsdp_experts else None
    for rule_name, trailing in _RULES:
        if rule_name != name or len(trailing) > len(shape):
            continue
        lead = len(shape) - len(trailing)
        spec = [None] * lead
        ok = True
        for dim, ax in zip(shape[lead:], trailing):
            if ax == "__ff__":
                ax = ff_axis
            if ax is None:
                spec.append(None)
            elif dim % max(_axis_size(mesh, ax), 1) == 0 and \
                    _axis_size(mesh, ax) > 1:
                spec.append(ax)
            elif _axis_size(mesh, ax) <= 1:
                spec.append(None)
            else:
                spec.append(None)       # non-divisible -> replicate this dim
        if ok:
            if run.fsdp_params:
                # FSDP: 2D-shard — put 'data' on the first replicated,
                # divisible dim (weights gathered transiently per layer)
                return _zero1_extend(P(*spec), shape, mesh, ("data",))
            return P(*spec)
    return P()                           # replicated (norms, scalars, biases)


def param_specs(params, mesh: Optional[Mesh], run: RunConfig):
    """PartitionSpec pytree matching `params` (which may be a pytree of
    arrays or ShapeDtypeStructs)."""
    if mesh is None:
        return jax.tree.map(lambda _: P(), params)

    def one(path, leaf):
        return _spec_for(_leaf_name(path), leaf.shape, mesh, run)

    return jax.tree_util.tree_map_with_path(one, params)


def _zero1_extend(spec: P, shape: Tuple[int, ...], mesh: Mesh,
                  axes=("data",)) -> P:
    """ZeRO-1: additionally shard optimizer state over the data axis on the
    first dim that is still replicated and divisible."""
    spec_t = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    for ax in axes:
        n = _axis_size(mesh, ax)
        if n <= 1:
            continue
        used = set(a for a in spec_t if a is not None)
        if ax in used:
            continue
        for i, (dim, cur) in enumerate(zip(shape, spec_t)):
            if cur is None and dim % n == 0 and dim >= n:
                spec_t = spec_t[:i] + (ax,) + spec_t[i + 1:]
                break
    return P(*spec_t)


def cache_specs(caches, mesh: Optional[Mesh], run: RunConfig,
                global_batch: int):
    """PartitionSpecs for serve caches. Batch dim over (pod, data) when
    divisible; KV heads / state heads over 'model' when divisible."""
    if mesh is None:
        return jax.tree.map(lambda _: P(), caches)
    baxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = 1
    for a in baxes:
        dp *= _axis_size(mesh, a)
    bax = baxes if (dp > 1 and global_batch % dp == 0) else None

    def model_if(dim):
        n = _axis_size(mesh, "model")
        return "model" if (n > 1 and dim % n == 0) else None

    def one(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        if name == "pos" or nd <= 1:
            return P()
        if name in ("k", "v", "k_scale", "v_scale"):  # (..., B, S, K, D|1)
            lead = nd - 4
            k_ax = model_if(leaf.shape[lead + 2])
            # too few KV heads to shard (GQA kv<16, MQA): shard the cache's
            # SEQUENCE dim over 'model' instead — decode attention becomes a
            # sharded reduction over context chunks (flash-decoding layout)
            s_ax = None if k_ax else model_if(leaf.shape[lead + 1])
            return P(*([None] * lead), bax, s_ax, k_ax, None)
        if name in ("ckv", "kr"):          # (..., B, S, r) — MLA latents
            lead = nd - 3
            # no head dim at all: always context-shard over 'model'
            return P(*([None] * lead), bax, model_if(leaf.shape[lead + 1]),
                     None)
        if name == "h":                    # (..., B, H, N, P)
            lead = nd - 4
            return P(*([None] * lead), bax,
                     model_if(leaf.shape[lead + 1]), None, None)
        if name == "conv":                 # (..., B, W, C)
            lead = nd - 3
            return P(*([None] * lead), bax, None,
                     model_if(leaf.shape[lead + 2]))
        if name == "wkv":                  # (..., B, H, K, K)
            lead = nd - 4
            return P(*([None] * lead), bax,
                     model_if(leaf.shape[lead + 1]), None, None)
        if name in ("tm_last", "cm_last"):  # (..., B, d)
            lead = nd - 2
            return P(*([None] * lead), bax, None)
        return P()

    return jax.tree_util.tree_map_with_path(one, caches)


def opt_state_specs(opt_state, p_specs, params, mesh: Optional[Mesh],
                    run: RunConfig):
    """Specs for OptState(step, m, v, master): moments & master follow the
    param spec, ZeRO-1-extended over 'data' (+ 'pod' if present)."""
    if mesh is None:
        return jax.tree.map(lambda _: P(), opt_state)
    axes = tuple(a for a in ("data", "pod") if _axis_size(mesh, a) > 1) \
        if run.zero1 else ()

    def z(spec, leaf):
        return _zero1_extend(spec, leaf.shape, mesh, axes) if axes else spec

    m = jax.tree.map(z, p_specs, params)
    v = jax.tree.map(z, p_specs, params)
    master = None
    if opt_state.master is not None:
        master = jax.tree.map(z, p_specs, params)
    from repro.optim.adamw import OptState
    return OptState(P(), m, v, master)


def batch_spec(mesh: Optional[Mesh], ndim: int = 2) -> P:
    if mesh is None:
        return P()
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return P(axes, *([None] * (ndim - 1)))


def named(mesh: Optional[Mesh], spec: P):
    return None if mesh is None else NamedSharding(mesh, spec)
