from repro.sharding.rules import (param_specs, opt_state_specs, cache_specs,
                                  named, batch_spec)
