"""Sharded, replicated state plane: scale past the single-writer daemon.

One crispy-daemon is a single writer and a single point of failure —
its throughput ceiling caps the entire fleet, and its crash takes the
shared registry with it. This module scales the state plane OUT while
keeping the `StateBackend` protocol unchanged, so every existing view
(`ProfileStore`, `BackendModelRegistry`, `ProfilingBudget`,
`TelemetryPublisher`, `__traces__` publishing) works over the sharded
plane with zero call-site changes:

  ShardedBackend      the full StateBackend protocol over N children via
                      consistent hashing of NAMESPACES. Each namespace is
                      owned by exactly one shard (a stable md5 hash ring
                      with virtual nodes), so everything the protocol
                      guarantees per namespace — append ordering, CAS
                      arbitration, reserve never-over-grants, compaction
                      cursor monotonicity — holds unchanged: it all
                      happens on the one daemon that owns the namespace.
                      A shared `ProfilingBudget` envelope is one
                      namespace, hence one arbiter. `batch()` splits a
                      multi-op frame by owning shard, fans the per-shard
                      sub-frames out CONCURRENTLY, and reassembles the
                      per-op results in original order — the service's
                      one-frame-per-batch round-trip win survives, and
                      aggregate ops/s now scales with shard count
                      (benchmarks/state_backends.py --shards).

  HashRing            the routing core. Ring positions hash
                      "<shard-name>#<vnode>"; shard names default to
                      index-based "shard-<i>" so routing depends only on
                      the shard COUNT and never on addresses — a failover
                      that swaps a shard's primary address must not
                      remap namespaces.

  ReplicationShipper  warm-standby replication for one shard. Runs
                      inside the primary daemon process with direct
                      access to its storage backend, and periodically
                      ships log tails (from per-namespace cursors) plus
                      changed versioned documents to the standby daemon
                      as ONE batched frame of `replicate` wire ops.
                      Shipping is idempotent by cursor/version (the
                      standby skips anything already applied), and a
                      post-compaction gap triggers a full re-ship from
                      the snapshot head. See repro.state.transport for
                      the frame shapes.

  topology doc        {"version": n, "shards": {name: {"primary": addr,
                      "standby": addr}}} stored as a CAS document at
                      (TOPOLOGY_NS, TOPOLOGY_KEY) on EVERY node
                      (`publish_topology`), so any reachable daemon can
                      answer "who serves shard X now". `DaemonBackend`
                      uses it client-side: on `StateBackendUnavailable`
                      it retries the shard's standby once and re-resolves
                      primaries from the doc (see
                      DaemonBackend._adopt_topology).

Consistency model, stated plainly: replication is asynchronous (warm
standby, not synchronous quorum). On primary failure, rows shipped
since the last replication round may be absent on the standby until
the primary returns; acknowledged-write durability across a kill is
guaranteed for everything the shipper delivered (tests pin this via an
explicit `ship_once()` barrier). Client failover retries an
un-acknowledged op on the standby, so a mutating op interrupted
mid-flight may execute at most twice — log rows are idempotent under
the store's "later wins" fold and CAS/reserve re-arbitrate, which is
the same at-most-twice contract `DaemonBackend` already documents for
its single-daemon retry path.
"""
from __future__ import annotations

import bisect
import hashlib
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from repro.state.backend import StateBackend, StateBackendError
from repro.state.transport import REPLICATE_OP, TOPOLOGY_KEY, TOPOLOGY_NS

# virtual nodes per shard: the ring-arc granularity. 256 keeps the
# heaviest shard within a few percent of the mean for realistic
# namespace counts (at 64 the skew reaches ~10%); building the ring is
# still just shards*vnodes md5 calls at construction time
DEFAULT_VNODES = 256


def stable_hash(text: str) -> int:
    """64-bit stable hash for ring placement. Python's builtin hash() is
    salted per process (PYTHONHASHSEED), which would route the same
    namespace to different shards in different processes — md5 is stable
    across processes, platforms and Python versions."""
    return int.from_bytes(hashlib.md5(text.encode()).digest()[:8], "big")


class HashRing:
    """Consistent-hash ring mapping namespaces onto shard indices.

    Virtual nodes smooth the per-shard load: each shard owns `vnodes`
    ring positions, so with realistic namespace counts the heaviest
    shard stays close to the mean. Lookup is O(log(n*vnodes)) bisect.
    """

    def __init__(self, names: Sequence[str], vnodes: int = DEFAULT_VNODES):
        if not names:
            raise ValueError("hash ring needs at least one shard name")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate shard names: {list(names)}")
        self.names = list(names)
        self.vnodes = max(1, int(vnodes))
        points: List[Tuple[int, int]] = []
        for idx, name in enumerate(self.names):
            for v in range(self.vnodes):
                points.append((stable_hash(f"{name}#{v}"), idx))
        points.sort()
        self._hashes = [h for h, _ in points]
        self._owners = [i for _, i in points]

    def owner_index(self, ns: str) -> int:
        """Index of the shard owning `ns` (first ring point clockwise of
        the namespace's hash, wrapping at the top)."""
        pos = bisect.bisect(self._hashes, stable_hash(ns))
        if pos == len(self._hashes):
            pos = 0
        return self._owners[pos]

    def owner(self, ns: str) -> str:
        return self.names[self.owner_index(ns)]


class ShardedBackend(StateBackend):
    """StateBackend over N children, routing each namespace to the one
    shard that owns it on the hash ring (see module docstring).

    Children are usually `DaemonBackend`s (one per shard primary, each
    optionally carrying a standby address for client-side failover) but
    any StateBackend works — the conformance suite runs this class over
    both in-memory and daemon children.
    """

    kind = "sharded"

    def __init__(self, children: Sequence[StateBackend],
                 names: Optional[Sequence[str]] = None,
                 vnodes: int = DEFAULT_VNODES):
        if not children:
            raise ValueError("ShardedBackend needs at least one child")
        self.children = list(children)
        # index-based default names: routing must depend only on shard
        # COUNT, never on child addresses (addresses change on failover)
        self.names = (list(names) if names is not None
                      else [f"shard-{i}" for i in range(len(self.children))])
        if len(self.names) != len(self.children):
            raise ValueError(
                f"{len(self.names)} names for {len(self.children)} children")
        self.ring = HashRing(self.names, vnodes=vnodes)

    @classmethod
    def from_addresses(cls, addresses: Sequence[str],
                       standbys: Optional[Sequence[Optional[str]]] = None,
                       auth_token: Optional[str] = None,
                       timeout_s: float = 10.0,
                       vnodes: int = DEFAULT_VNODES) -> "ShardedBackend":
        """Build the usual fleet client: one DaemonBackend per primary
        address, each tagged with its shard name and (optional) standby
        so client-side failover re-routes per shard."""
        from repro.state.daemon import DaemonBackend
        standbys = list(standbys or [])
        standbys += [None] * (len(addresses) - len(standbys))
        children = [
            DaemonBackend(addr, timeout_s=timeout_s, auth_token=auth_token,
                          standby=standby, shard_name=f"shard-{i}")
            for i, (addr, standby) in enumerate(zip(addresses, standbys))]
        return cls(children, vnodes=vnodes)

    # -- routing ------------------------------------------------------------
    def shard_index(self, ns: str) -> int:
        return self.ring.owner_index(ns)

    def shard_for(self, ns: str) -> StateBackend:
        return self.children[self.ring.owner_index(ns)]

    # -- protocol: every single-namespace op routes to its owner ------------
    def append(self, ns, record):
        self.shard_for(ns).append(ns, record)

    def read(self, ns, cursor=0):
        return self.shard_for(ns).read(ns, cursor)

    def compact(self, ns, key_fields=None, max_age_s=None):
        return self.shard_for(ns).compact(ns, key_fields=key_fields,
                                          max_age_s=max_age_s)

    def load(self, ns, key):
        return self.shard_for(ns).load(ns, key)

    def cas(self, ns, key, version, value):
        return self.shard_for(ns).cas(ns, key, version, value)

    def reserve(self, ns, key, deltas, limits=None):
        # one namespace -> one owning shard -> one arbiter: the shared
        # budget envelope keeps its never-over-grant guarantee
        return self.shard_for(ns).reserve(ns, key, deltas, limits)

    # -- batched ops ---------------------------------------------------------
    def batch(self, ops: Sequence[Dict]) -> List[Dict]:
        """Split the frame by owning shard, fan the sub-frames out
        concurrently, reassemble ordered per-op results.

        Ops without a routable namespace (non-dict ops, missing "ns")
        deterministically go to shard 0, which answers with the same
        per-op error shape a single daemon would. A shard whose whole
        sub-frame fails at the transport (its primary AND standby are
        down) degrades to per-op {"ok": false} slots rather than
        poisoning the other shards' results — `sync_views` re-queues
        exactly the rows whose slots failed.

        Within one shard, sub-ops keep their relative order, so a batch
        still reads its own earlier writes per namespace (cross-shard
        sub-frames run concurrently, but ops on the SAME namespace are
        always on the same shard)."""
        ops = list(ops)
        if not ops:
            return []
        by_shard: Dict[int, List[Tuple[int, Dict]]] = {}
        for pos, op in enumerate(ops):
            ns = op.get("ns") if isinstance(op, dict) else None
            idx = self.shard_index(ns) if isinstance(ns, str) else 0
            by_shard.setdefault(idx, []).append((pos, op))

        results: List[Optional[Dict]] = [None] * len(ops)

        def run(idx: int, members: List[Tuple[int, Dict]]) -> None:
            sub = [op for _pos, op in members]
            try:
                got = self.children[idx].batch(sub)
                if len(got) != len(sub):
                    raise StateBackendError(
                        f"shard {self.names[idx]} answered {len(got)} "
                        f"results for {len(sub)} ops")
            except StateBackendError as e:
                got = [{"ok": False,
                        "error": f"shard {self.names[idx]}: {e}"}] * len(sub)
            for (pos, _op), result in zip(members, got):
                results[pos] = result

        groups = sorted(by_shard.items())
        if len(groups) == 1:
            run(*groups[0])
        else:
            threads = [threading.Thread(target=run, args=group, daemon=True)
                       for group in groups[1:]]
            for t in threads:
                t.start()
            run(*groups[0])      # run one sub-frame on the calling thread
            for t in threads:
                t.join()
        return results            # every slot filled by run()

    # -- lifecycle / introspection ------------------------------------------
    def ping(self) -> bool:
        return all(child.ping() for child in self.children)

    def close(self) -> None:
        for child in self.children:
            child.close()

    def describe(self) -> str:
        parts = []
        for name, child in zip(self.names, self.children):
            addr = getattr(child, "address", None)
            parts.append(f"{name}={addr or getattr(child, 'kind', '?')}")
        return f"sharded[{', '.join(parts)}]"

    def topology(self) -> Dict:
        """Topology descriptor for stats surfaces AND the on-ring doc:
        per-shard name/kind/address/standby plus the ring's vnode count."""
        shards = []
        for name, child in zip(self.names, self.children):
            shards.append({
                "name": name,
                "kind": getattr(child, "kind", "unknown"),
                "address": getattr(child, "address", None),
                "standby": getattr(child, "standby_address", None),
            })
        return {"vnodes": self.ring.vnodes, "shards": shards}


# -- topology doc -------------------------------------------------------------

def publish_topology(backend: ShardedBackend) -> Dict:
    """CAS-write the topology doc onto EVERY shard (so any reachable node
    can answer during failover). Returns the doc value written. Nodes
    that are down are skipped — they adopt the doc via replication or
    the next publish."""
    entries = {s["name"]: {"primary": s["address"], "standby": s["standby"]}
               for s in backend.topology()["shards"]}
    written = None
    for child in backend.children:
        try:
            while True:
                value, version = child.load(TOPOLOGY_NS, TOPOLOGY_KEY)
                doc = {"version": int((value or {}).get("version", 0)) + 1,
                       "shards": entries}
                won, _cur, _ver = child.cas(TOPOLOGY_NS, TOPOLOGY_KEY,
                                            version, doc)
                if won:
                    written = doc
                    break
        except StateBackendError:
            continue
    return written or {"version": 1, "shards": entries}


def load_topology(backend: StateBackend) -> Optional[Dict]:
    """The topology doc as seen by one node, or None."""
    value, _version = backend.load(TOPOLOGY_NS, TOPOLOGY_KEY)
    return value


# -- warm-standby replication -------------------------------------------------

class ReplicationShipper:
    """Ships one shard's state to its warm standby (see module docstring).

    Runs inside the primary daemon process against the daemon's own
    storage backend (memory or file root) — enumeration uses
    `log_namespaces()` / `doc_snapshot()` directly, no self-RPC. Each
    round reads every namespace's tail past the last shipped cursor plus
    every document whose version moved, and sends the lot as ONE batch
    frame of `replicate` ops to the standby. The standby's cursor
    tracking makes re-shipping idempotent; a "replication gap" answer
    (the standby's applied cursor predates our post-compaction base)
    resets that namespace's cursor to 0 so the next round re-ships the
    folded snapshot from the head.
    """

    def __init__(self, backend: StateBackend, standby: str,
                 auth_token: Optional[str] = None,
                 period_s: float = 0.5, timeout_s: float = 5.0):
        self.backend = backend
        self.standby = standby
        self.auth_token = auth_token
        self.period_s = max(0.01, float(period_s))
        self.timeout_s = timeout_s
        self.stats = {"rounds": 0, "shipped_rows": 0, "shipped_docs": 0,
                      "errors": 0, "resyncs": 0}
        self._cursors: Dict[str, int] = {}
        self._doc_versions: Dict[Tuple[str, str], int] = {}
        self._client: Optional[StateBackend] = None
        self._lock = threading.Lock()      # ship_once vs the period thread
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _standby_client(self) -> StateBackend:
        if self._client is None:
            from repro.state.daemon import DaemonBackend
            self._client = DaemonBackend(self.standby,
                                         timeout_s=self.timeout_s,
                                         auth_token=self.auth_token)
        return self._client

    def ship_once(self) -> Dict:
        """One replication round. Returns round stats; raises
        StateBackendError when the standby is unreachable (the period
        thread swallows that — warm standby is best-effort until the
        standby returns)."""
        with self._lock:
            return self._ship_locked()

    def _ship_locked(self) -> Dict:
        ops: List[Dict] = []
        meta: List[Tuple[str, object, int]] = []
        for ns in self.backend.log_namespaces():
            prev = self._cursors.get(ns, 0)
            rows, end = self.backend.read(ns, prev)
            if not rows:
                # nothing new (a fold that dropped every row still moves
                # the cursor — track it locally, nothing to ship)
                self._cursors[ns] = max(prev, end)
                continue
            ops.append({"op": REPLICATE_OP,
                        "log": {"ns": ns, "rows": rows,
                                "base": prev, "cursor": end}})
            meta.append(("log", ns, end))
        for ns, key, value, version in self.backend.doc_snapshot():
            if version > self._doc_versions.get((ns, key), 0):
                ops.append({"op": REPLICATE_OP,
                            "doc": {"ns": ns, "key": key, "value": value,
                                    "version": version}})
                meta.append(("doc", (ns, key), version))
        round_stats = {"ops": len(ops), "rows": 0, "docs": 0, "errors": 0}
        if not ops:
            self.stats["rounds"] += 1
            return round_stats
        results = self._standby_client().batch(ops)
        for (kind, ident, val), resp in zip(meta, results):
            if resp.get("ok"):
                if kind == "log":
                    self._cursors[ident] = int(resp.get("cursor", val))
                    round_stats["rows"] += int(resp.get("applied", 0))
                else:
                    self._doc_versions[ident] = val
                    round_stats["docs"] += 1
            else:
                round_stats["errors"] += 1
                if (kind == "log"
                        and "replication gap" in str(resp.get("error", ""))):
                    # the standby is behind our compacted base: re-ship
                    # the whole folded log next round
                    self._cursors[ident] = 0
                    self.stats["resyncs"] += 1
        self.stats["rounds"] += 1
        self.stats["shipped_rows"] += round_stats["rows"]
        self.stats["shipped_docs"] += round_stats["docs"]
        self.stats["errors"] += round_stats["errors"]
        return round_stats

    # -- period thread ------------------------------------------------------
    def start(self) -> "ReplicationShipper":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="crispy-replication")
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.period_s):
            try:
                self.ship_once()
            except StateBackendError:
                self.stats["errors"] += 1     # standby down: keep trying

    def stop(self, final_ship: bool = True) -> None:
        self._stop.set()
        thread, self._thread = self._thread, None
        if thread is not None:
            thread.join(timeout=5.0)
        if final_ship:
            try:
                self.ship_once()     # drain the tail on graceful shutdown
            except StateBackendError:
                pass
        client, self._client = self._client, None
        if client is not None:
            client.close()


# -- standby-side application -------------------------------------------------

class ReplicationApplier:
    """The standby daemon's side of the protocol: applies `replicate`
    frames idempotently onto a local backend. Owned by CrispyDaemon
    (one per daemon; dispatch calls `apply` under the daemon's write
    lock)."""

    def __init__(self, backend: StateBackend):
        self.backend = backend
        self._log_cursors: Dict[str, int] = {}      # highest primary cursor
        self._doc_versions: Dict[Tuple[str, str], int] = {}

    def apply(self, req: Dict) -> Dict:
        log = req.get("log")
        if isinstance(log, dict):
            return self._apply_log(log)
        doc = req.get("doc")
        if isinstance(doc, dict):
            return self._apply_doc(doc)
        return {"ok": False,
                "error": "replicate frame needs a 'log' or 'doc' body"}

    def _apply_log(self, body: Dict) -> Dict:
        ns = body.get("ns")
        rows = body.get("rows")
        if not isinstance(ns, str) or not isinstance(rows, list):
            return {"ok": False, "error": "replicate log needs ns + rows"}
        base = int(body.get("base", 0))
        cursor = int(body.get("cursor", base + len(rows)))
        applied_to = self._log_cursors.get(ns, 0)
        if cursor <= applied_to:               # already have it: idempotent
            return {"ok": True, "applied": 0, "cursor": applied_to}
        if base > applied_to:
            # the primary compacted past what we hold — we cannot splice
            # this tail without a hole; demand a full re-ship
            return {"ok": False,
                    "error": (f"replication gap in {ns!r}: frame base "
                              f"{base} > applied cursor {applied_to}")}
        # overlap (base <= applied_to < cursor): skip the prefix we already
        # applied. Best-effort dedup — under the store's later-wins fold a
        # duplicated row would be harmless anyway.
        skip = min(len(rows), max(0, applied_to - base))
        applied = 0
        for row in rows[skip:]:
            self.backend.append(ns, row)
            applied += 1
        self._log_cursors[ns] = cursor
        return {"ok": True, "applied": applied, "cursor": cursor}

    def _apply_doc(self, body: Dict) -> Dict:
        ns, key = body.get("ns"), body.get("key")
        if not isinstance(ns, str) or not isinstance(key, str):
            return {"ok": False, "error": "replicate doc needs ns + key"}
        version = int(body.get("version", 0))
        value = body.get("value")
        seen = self._doc_versions.get((ns, key), 0)
        if version <= seen:                    # already have it: idempotent
            return {"ok": True, "applied": False, "version": seen}
        # force-write via CAS loop from whatever local version we hold —
        # replication is the one writer allowed to overwrite unconditionally
        # (the primary's version ordering is the source of truth)
        while True:
            _cur, local_version = self.backend.load(ns, key)
            won, _v, _ver = self.backend.cas(ns, key, local_version,
                                             value if isinstance(value, dict)
                                             else {})
            if won:
                break
        self._doc_versions[(ns, key)] = version
        return {"ok": True, "applied": True, "version": version}


__all__ = [
    "DEFAULT_VNODES", "HashRing", "ReplicationApplier", "ReplicationShipper",
    "ShardedBackend", "TOPOLOGY_KEY", "TOPOLOGY_NS", "load_topology",
    "publish_topology", "stable_hash",
]
