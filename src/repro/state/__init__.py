"""Unified shared-state backend for Crispy's cross-process resources.

One host serving many concurrent allocation clients needs exactly three
shared things: the profile/anchor log (`ProfileStore`), the confident-model
registry (`LockedModelRegistry` / `BackendModelRegistry`), and the
profiling envelope (`ProfilingBudget` in shared mode). Before this package
each of them hand-rolled its own sharing (two copies of fcntl JSONL
locking, and no budget sharing at all); now all three are thin views over
one `StateBackend` protocol:

  backend.py       `StateBackend` — append-only logs (`append`/`read`),
                   versioned documents (`load`/`cas`), and lease-style
                   `reserve` for budget arbitration — plus
                   `InMemoryBackend` (tests/embedded).
  file_backend.py  `FileBackend` — the fcntl implementation. The ONLY
                   module in the repo that may import fcntl; `FileLock`
                   lives here.
  daemon.py        `CrispyDaemon` server + `DaemonBackend` client —
                   single-writer state over a unix-domain socket and/or
                   TCP, so contended reservations are one RPC instead of
                   a CAS retry loop through the filesystem, and services
                   on OTHER hosts share the same substrate.
  transport.py     address parsing ("/path" vs "host:port" vs
                   "tcp://host:port"), newline-JSON framing, and the
                   shared-token auth frame both daemon and client speak.
  compaction.py    `fold_log` (snapshot-plus-tail log folding, tombstone
                   and age handling) + `prune_registry_doc` (size/age
                   registry eviction with doc tombstones). Every backend
                   exposes them via `compact(ns, ...)`.
  sharding.py      `ShardedBackend` — the same protocol over N daemons
                   via consistent hashing of namespaces — plus
                   `ReplicationShipper`/`ReplicationApplier` (warm-
                   standby replication) and the topology-doc helpers.

Daemon lifecycle (full wire protocol in daemon.py):

  start     python -m repro.state.daemon --socket /tmp/crispy.sock \
                [--listen 0.0.0.0:7421] [--auth-token SECRET] \
                [--root state-dir | --memory] \
                [--compact-after N] [--registry-max-records N]
            With --root the daemon persists through a FileBackend and a
            restart resumes from disk; --memory serves volatile state.
            The socket path defaults to $CRISPY_DAEMON_SOCKET, else
            <tmpdir>/crispy-daemon.sock; --listen alone makes the daemon
            tcp-only. TCP should carry an auth token ($CRISPY_DAEMON_TOKEN
            or --auth-token).
  connect   backend = DaemonBackend("/tmp/crispy.sock")        # unix
            backend = DaemonBackend("crispy-host:7421")        # tcp
            then AllocationService(..., backend=backend) or
            ProfileStore(backend=backend) / ProfilingBudget(...,
            backend=backend). Clients reconnect once on transport errors
            (daemon restarts are transparent); a daemon that stays down
            raises StateBackendUnavailable naming the unix path or
            host:port it could not reach.
  health    python -m repro.state.daemon --socket ... --ping
            (or --listen host:port --ping for a tcp daemon)
  shutdown  python -m repro.state.daemon --socket ... --shutdown
            (or SIGTERM/SIGINT) — the server drains, unlinks the socket,
            and exits 0.

Wire batching + pipelining: every backend exposes `batch(ops)` — N
wire-shaped ops executed in order with per-op error isolation (a failing
op yields its own {"ok": false} slot). On `DaemonBackend` that is ONE
{"op": "batch", "ops": [...]} round trip (auto-chunked under the 8 MiB
frame cap), and `DaemonBackend.pipeline()` additionally pipelines plain
single-op frames — N request lines, one flush, N ordered responses —
against daemons of any version. Frames without the batch op stay
byte-identical to the legacy protocol (pinned by
tests/test_state_conformance.py), and on an authed TCP daemon the auth
handshake still gates batch frames like any other. The shared views
coalesce their hot patterns automatically:
`repro.profiling.store.refresh_views(store, registry)` fetches the
profile-log tail and the registry document in one frame, and
`ProfileStore(write_behind=True)` flushes buffered point/anchor rows as
one batched append frame. The daemon records batch widths in
`daemon.batch.size` and still times each sub-op into its
`daemon.op.<op>.seconds` histogram.

Sharded fleet topology, replication and failover (sharding.py): when one
daemon's write throughput caps the fleet, shard the state plane —

  topology   N primary daemons, each optionally paired with a warm
             standby:

               python -m repro.state.daemon --socket /tmp/s0.sock \
                   --shard-name shard-0 --standby /tmp/s0-standby.sock
               python -m repro.state.daemon --socket /tmp/s0-standby.sock
               python -m repro.state.daemon --socket /tmp/s1.sock \
                   --shard-name shard-1

             backend = ShardedBackend.from_addresses(
                 ["/tmp/s0.sock", "/tmp/s1.sock"],
                 standbys=["/tmp/s0-standby.sock", None])
             publish_topology(backend)   # the doc lives on the ring

             Namespaces route by a stable md5 hash ring with virtual
             nodes, so each namespace (hence each budget envelope,
             each log, each document key's arbitration) is owned by
             exactly ONE shard and every per-namespace protocol
             guarantee holds unchanged; `batch()` splits frames by
             owning shard and fans out concurrently, so aggregate
             ops/s scales with shard count
             (`benchmarks/state_backends.py --shards N`).

  replicate  each primary's `ReplicationShipper` periodically ships log
             tails + changed documents to its standby as batched
             `replicate` frames — idempotent by cursor/version, full
             resync after a compaction gap, auth-gated like every op.

  failover   a `DaemonBackend(primary, standby=.., shard_name=..)` that
             gets `StateBackendUnavailable` from its primary retries
             the standby ONCE and re-resolves the shard's current
             primary/standby from the topology doc stored at
             (`__topology__`, "shards") on whatever node answered.
             Mutating frames interrupted mid-flight may execute at most
             twice (availability over exactly-once); log rows are
             idempotent under later-wins folding and CAS/reserve
             re-arbitrate, so views stay correct.

Choosing a backend: `InMemoryBackend` for tests and single-process
embedding; `FileBackend` for a handful of processes on one host with no
extra moving parts; `DaemonBackend` when reservation traffic is contended,
you want one process to own all writes, or clients live on other hosts
(tcp); `ShardedBackend` when one daemon's throughput or blast radius is
the bottleneck. `benchmarks/state_backends.py --transport {unix,tcp}`
measures file vs daemon under multi-process load on either transport, its
`--batch N` flag measures batched vs single-op round trips, and its
`--shards N` flag measures aggregate ops/s over 1/2/4-shard topologies.
"""
from repro.state.backend import (CASConflict, InMemoryBackend, StateBackend,
                                 StateBackendError, StateBackendUnavailable)
from repro.state.compaction import (DEFAULT_KEY_FIELDS, fold_log,
                                    prune_registry_doc)
from repro.state.file_backend import FileBackend, FileLock, HAS_FCNTL
from repro.state.transport import (AUTH_TOKEN_ENV, default_auth_token,
                                   describe_address, parse_address)

# daemon exports resolve lazily (PEP 562): `python -m repro.state.daemon`
# would otherwise import the module twice (package import + runpy __main__)
# and warn about unpredictable behaviour
_DAEMON_EXPORTS = ("CrispyDaemon", "DaemonBackend", "HAS_UNIX_SOCKETS",
                   "default_socket_path")

# sharding exports resolve lazily too: sharding imports DaemonBackend for
# from_addresses/shipping, so eager import would drag daemon.py in
_SHARDING_EXPORTS = ("HashRing", "ReplicationApplier", "ReplicationShipper",
                     "ShardedBackend", "TOPOLOGY_KEY", "TOPOLOGY_NS",
                     "load_topology", "publish_topology")

__all__ = [
    "AUTH_TOKEN_ENV", "CASConflict", "CrispyDaemon", "DaemonBackend",
    "DEFAULT_KEY_FIELDS", "FileBackend", "FileLock", "HAS_FCNTL",
    "HAS_UNIX_SOCKETS", "HashRing", "InMemoryBackend",
    "ReplicationApplier", "ReplicationShipper", "ShardedBackend",
    "StateBackend", "StateBackendError", "StateBackendUnavailable",
    "TOPOLOGY_KEY", "TOPOLOGY_NS", "default_auth_token",
    "default_socket_path", "describe_address", "fold_log", "load_topology",
    "parse_address", "prune_registry_doc", "publish_topology",
]


def __getattr__(name):
    if name in _DAEMON_EXPORTS:
        from repro.state import daemon
        return getattr(daemon, name)
    if name in _SHARDING_EXPORTS:
        from repro.state import sharding
        return getattr(sharding, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
