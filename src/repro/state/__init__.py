"""Unified shared-state backend for Crispy's cross-process resources.

One host serving many concurrent allocation clients needs exactly three
shared things: the profile/anchor log (`ProfileStore`), the confident-model
registry (`LockedModelRegistry` / `BackendModelRegistry`), and the
profiling envelope (`ProfilingBudget` in shared mode). Before this package
each of them hand-rolled its own sharing (two copies of fcntl JSONL
locking, and no budget sharing at all); now all three are thin views over
one `StateBackend` protocol:

  backend.py       `StateBackend` — append-only logs (`append`/`read`),
                   versioned documents (`load`/`cas`), and lease-style
                   `reserve` for budget arbitration — plus
                   `InMemoryBackend` (tests/embedded).
  file_backend.py  `FileBackend` — the fcntl implementation. The ONLY
                   module in the repo that may import fcntl; `FileLock`
                   lives here.
  daemon.py        `CrispyDaemon` server + `DaemonBackend` client —
                   single-writer state over a unix-domain socket and/or
                   TCP, so contended reservations are one RPC instead of
                   a CAS retry loop through the filesystem, and services
                   on OTHER hosts share the same substrate.
  transport.py     address parsing ("/path" vs "host:port" vs
                   "tcp://host:port"), newline-JSON framing, and the
                   shared-token auth frame both daemon and client speak.
  compaction.py    `fold_log` (snapshot-plus-tail log folding, tombstone
                   and age handling) + `prune_registry_doc` (size/age
                   registry eviction with doc tombstones). Every backend
                   exposes them via `compact(ns, ...)`.

Daemon lifecycle (full wire protocol in daemon.py):

  start     python -m repro.state.daemon --socket /tmp/crispy.sock \
                [--listen 0.0.0.0:7421] [--auth-token SECRET] \
                [--root state-dir | --memory] \
                [--compact-after N] [--registry-max-records N]
            With --root the daemon persists through a FileBackend and a
            restart resumes from disk; --memory serves volatile state.
            The socket path defaults to $CRISPY_DAEMON_SOCKET, else
            <tmpdir>/crispy-daemon.sock; --listen alone makes the daemon
            tcp-only. TCP should carry an auth token ($CRISPY_DAEMON_TOKEN
            or --auth-token).
  connect   backend = DaemonBackend("/tmp/crispy.sock")        # unix
            backend = DaemonBackend("crispy-host:7421")        # tcp
            then AllocationService(..., backend=backend) or
            ProfileStore(backend=backend) / ProfilingBudget(...,
            backend=backend). Clients reconnect once on transport errors
            (daemon restarts are transparent); a daemon that stays down
            raises StateBackendUnavailable naming the unix path or
            host:port it could not reach.
  health    python -m repro.state.daemon --socket ... --ping
            (or --listen host:port --ping for a tcp daemon)
  shutdown  python -m repro.state.daemon --socket ... --shutdown
            (or SIGTERM/SIGINT) — the server drains, unlinks the socket,
            and exits 0.

Wire batching + pipelining: every backend exposes `batch(ops)` — N
wire-shaped ops executed in order with per-op error isolation (a failing
op yields its own {"ok": false} slot). On `DaemonBackend` that is ONE
{"op": "batch", "ops": [...]} round trip (auto-chunked under the 8 MiB
frame cap), and `DaemonBackend.pipeline()` additionally pipelines plain
single-op frames — N request lines, one flush, N ordered responses —
against daemons of any version. Frames without the batch op stay
byte-identical to the legacy protocol (pinned by
tests/test_state_conformance.py), and on an authed TCP daemon the auth
handshake still gates batch frames like any other. The shared views
coalesce their hot patterns automatically:
`repro.profiling.store.refresh_views(store, registry)` fetches the
profile-log tail and the registry document in one frame, and
`ProfileStore(write_behind=True)` flushes buffered point/anchor rows as
one batched append frame. The daemon records batch widths in
`daemon.batch.size` and still times each sub-op into its
`daemon.op.<op>.seconds` histogram.

Choosing a backend: `InMemoryBackend` for tests and single-process
embedding; `FileBackend` for a handful of processes on one host with no
extra moving parts; `DaemonBackend` when reservation traffic is contended,
you want one process to own all writes, or clients live on other hosts
(tcp). `benchmarks/state_backends.py --transport {unix,tcp}` measures
file vs daemon under multi-process load on either transport, and its
`--batch N` flag measures batched vs single-op round trips.
"""
from repro.state.backend import (CASConflict, InMemoryBackend, StateBackend,
                                 StateBackendError, StateBackendUnavailable)
from repro.state.compaction import (DEFAULT_KEY_FIELDS, fold_log,
                                    prune_registry_doc)
from repro.state.file_backend import FileBackend, FileLock, HAS_FCNTL
from repro.state.transport import (AUTH_TOKEN_ENV, default_auth_token,
                                   describe_address, parse_address)

# daemon exports resolve lazily (PEP 562): `python -m repro.state.daemon`
# would otherwise import the module twice (package import + runpy __main__)
# and warn about unpredictable behaviour
_DAEMON_EXPORTS = ("CrispyDaemon", "DaemonBackend", "HAS_UNIX_SOCKETS",
                   "default_socket_path")

__all__ = [
    "AUTH_TOKEN_ENV", "CASConflict", "CrispyDaemon", "DaemonBackend",
    "DEFAULT_KEY_FIELDS", "FileBackend", "FileLock", "HAS_FCNTL",
    "HAS_UNIX_SOCKETS", "InMemoryBackend", "StateBackend",
    "StateBackendError", "StateBackendUnavailable", "default_auth_token",
    "default_socket_path", "describe_address", "fold_log", "parse_address",
    "prune_registry_doc",
]


def __getattr__(name):
    if name in _DAEMON_EXPORTS:
        from repro.state import daemon
        return getattr(daemon, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
