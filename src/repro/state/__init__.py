"""Unified shared-state backend for Crispy's cross-process resources.

One host serving many concurrent allocation clients needs exactly three
shared things: the profile/anchor log (`ProfileStore`), the confident-model
registry (`LockedModelRegistry` / `BackendModelRegistry`), and the
profiling envelope (`ProfilingBudget` in shared mode). Before this package
each of them hand-rolled its own sharing (two copies of fcntl JSONL
locking, and no budget sharing at all); now all three are thin views over
one `StateBackend` protocol:

  backend.py       `StateBackend` — append-only logs (`append`/`read`),
                   versioned documents (`load`/`cas`), and lease-style
                   `reserve` for budget arbitration — plus
                   `InMemoryBackend` (tests/embedded).
  file_backend.py  `FileBackend` — the fcntl implementation. The ONLY
                   module in the repo that may import fcntl; `FileLock`
                   lives here.
  daemon.py        `CrispyDaemon` server + `DaemonBackend` client —
                   single-writer state over a unix-domain socket, so
                   contended reservations are one RPC instead of a CAS
                   retry loop through the filesystem.

Daemon lifecycle (full wire protocol in daemon.py):

  start     python -m repro.state.daemon --socket /tmp/crispy.sock \
                [--root state-dir | --memory]
            With --root the daemon persists through a FileBackend and a
            restart resumes from disk; --memory serves volatile state.
            The socket path defaults to $CRISPY_DAEMON_SOCKET, else
            <tmpdir>/crispy-daemon.sock.
  connect   backend = DaemonBackend("/tmp/crispy.sock")
            then AllocationService(..., backend=backend) or
            ProfileStore(backend=backend) / ProfilingBudget(...,
            backend=backend). Clients reconnect once on transport errors
            (daemon restarts are transparent); a daemon that stays down
            raises StateBackendUnavailable.
  health    python -m repro.state.daemon --socket ... --ping
  shutdown  python -m repro.state.daemon --socket ... --shutdown
            (or SIGTERM/SIGINT) — the server drains, unlinks the socket,
            and exits 0.

Choosing a backend: `InMemoryBackend` for tests and single-process
embedding; `FileBackend` for a handful of processes on one host with no
extra moving parts; `DaemonBackend` when reservation traffic is contended
or you want one process to own all writes.
`benchmarks/state_backends.py` measures file vs daemon under
multi-process load.
"""
from repro.state.backend import (CASConflict, InMemoryBackend, StateBackend,
                                 StateBackendError, StateBackendUnavailable)
from repro.state.file_backend import FileBackend, FileLock, HAS_FCNTL

# daemon exports resolve lazily (PEP 562): `python -m repro.state.daemon`
# would otherwise import the module twice (package import + runpy __main__)
# and warn about unpredictable behaviour
_DAEMON_EXPORTS = ("CrispyDaemon", "DaemonBackend", "HAS_UNIX_SOCKETS",
                   "default_socket_path")

__all__ = [
    "CASConflict", "CrispyDaemon", "DaemonBackend", "FileBackend",
    "FileLock", "HAS_FCNTL", "HAS_UNIX_SOCKETS", "InMemoryBackend",
    "StateBackend", "StateBackendError", "StateBackendUnavailable",
    "default_socket_path",
]


def __getattr__(name):
    if name in _DAEMON_EXPORTS:
        from repro.state import daemon
        return getattr(daemon, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
