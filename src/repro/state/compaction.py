"""Log folding + registry-document eviction: the pure logic behind the
backends' `compact` and the daemon's size/age thresholds.

Append-only logs grow forever under "later rows win" semantics — every
re-profiled point and recalibrated anchor adds a row that permanently
shadows an earlier one. Folding rewrites a log into snapshot-plus-tail
form: one surviving row per identity key (the LAST appended), dropped
tombstones, optionally dropped over-age rows. Backends then republish the
folded rows under a bumped cursor base, so the logical cursor space stays
monotone across compactions (see `StateBackend.read`).

Identity of a row = the (field, value) pairs of the `key_fields` it
actually carries, e.g. the default ("kind", "sig", "size", "key") gives
profile rows the identity (kind=profile, sig=..., size=...) and anchor
rows (kind=anchor, sig=...). A row carrying NONE of the key fields has no
foldable identity and is always kept — generic logs (benchmark counters,
audit trails) pass through a fold verbatim instead of collapsing into
their last row.

Tombstones: a row with a truthy "tombstone" field deletes its identity —
the fold drops every earlier row it shadows but KEEPS the tombstone
itself as the identity's surviving row. That is load-bearing for
incremental readers: a sibling process whose pre-compaction cursor
re-reads the folded snapshot must still see the deletion to drop the
point from its in-memory index (ProfileStore.refresh applies rows, it
never diffs against absence). Anything appended for the identity *after*
the tombstone wins over it as usual. Surviving tombstones are reaped by
the age filter: `max_age_s` drops over-age SURVIVORS (rows without a
"ts" are exempt) — the filter runs after shadowing, so an over-age
tombstone takes everything it shadows with it instead of resurrecting
older rows. Folding is idempotent and order-preserving (rows survive in
last-occurrence order), so replaying a folded log rebuilds exactly the
state of replaying the original.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

# identity fields of the rows Crispy's own stores append; rows without
# any of them (generic logs) never fold
DEFAULT_KEY_FIELDS: Tuple[str, ...] = ("kind", "sig", "size", "key")

# field names writers stamp on rows ("tombstone" marks a deletion row —
# fold_log needs no special case for it, it wins its identity like any
# later row; "ts" is what max_age_s filters on)
TOMBSTONE_FIELD = "tombstone"
TIMESTAMP_FIELD = "ts"


def fold_log(rows: Sequence[Dict],
             key_fields: Optional[Sequence[str]] = None,
             max_age_s: Optional[float] = None,
             now: Optional[float] = None) -> List[Dict]:
    """Fold `rows` (oldest first) into their surviving subset."""
    key_fields = tuple(key_fields if key_fields is not None
                       else DEFAULT_KEY_FIELDS)
    now = time.time() if now is None else now
    # "later rows win" needs no tombstone special-case here: a tombstone
    # is simply the identity's last row, shadowing the rows before it
    # (and being shadowed by a later re-put)
    survivors: Dict[object, Dict] = {}      # identity -> last row
    order: Dict[object, int] = {}           # identity -> last position
    for i, row in enumerate(rows):
        ident = tuple((f, _hashable(row[f]))
                      for f in key_fields if f in row)
        key: object = ident if ident else ("__row__", i)
        survivors[key] = row
        order[key] = i

    def over_age(row: Dict) -> bool:
        # applied to SURVIVORS only — everything an over-age tombstone
        # shadowed is already gone, so dropping it resurrects nothing
        if max_age_s is None:
            return False
        ts = row.get(TIMESTAMP_FIELD)
        return ts is not None and float(ts) < now - max_age_s

    return [survivors[k] for k in sorted(order, key=order.__getitem__)
            if not over_age(survivors[k])]


def _hashable(value):
    if isinstance(value, (list, tuple)):
        return tuple(_hashable(v) for v in value)
    if isinstance(value, dict):
        return tuple(sorted((k, _hashable(v)) for k, v in value.items()))
    return value


# -- registry-document eviction -----------------------------------------------

# how long an eviction tombstone outlives its record: long enough for
# every live sibling registry to merge the deletion into memory, short
# enough that a churning registry's tombstone map stays bounded. (A
# sibling dormant longer than this may resurrect the record on its next
# flush — the record is then simply re-evictable.)
DEFAULT_TOMBSTONE_TTL_S = 24 * 3600.0


def prune_registry_doc(value: Optional[Dict],
                       max_records: Optional[int] = None,
                       max_age_s: Optional[float] = None,
                       now: Optional[float] = None,
                       tombstone_ttl_s: float = DEFAULT_TOMBSTONE_TTL_S
                       ) -> Tuple[Dict, List[str]]:
    """Evict records from a BackendModelRegistry document by age/count.

    Works on the raw document shape ({"records": {sig: {"created_at": ..}},
    "tombstones": {sig: ts}}) so the state package needs no import of the
    allocator. Evicted signatures gain a tombstone stamped `now`, which the
    registry's merge honors — a sibling process flushing its in-memory copy
    cannot resurrect a daemon-side eviction. Tombstones older than
    `tombstone_ttl_s` have done their job and are reaped, so the doc the
    eviction knobs exist to bound never grows with eviction history.
    Returns (new_value, evicted).
    """
    now = time.time() if now is None else now
    value = dict(value or {})
    records = dict(value.get("records") or {})
    tombstones = {k: float(v)
                  for k, v in (value.get("tombstones") or {}).items()
                  if float(v) >= now - tombstone_ttl_s}
    by_age = sorted(records,
                    key=lambda sig: float(records[sig].get("created_at", 0.0)))
    evicted: List[str] = []
    if max_age_s is not None:
        for sig in by_age:
            if float(records[sig].get("created_at", 0.0)) < now - max_age_s:
                evicted.append(sig)
    if max_records is not None and len(records) - len(evicted) > max_records:
        extra = len(records) - len(evicted) - max_records
        remaining = [sig for sig in by_age if sig not in evicted]
        evicted.extend(remaining[:extra])   # oldest beyond the cap go first
    for sig in evicted:
        del records[sig]
        tombstones[sig] = now
    value["records"] = records
    value["tombstones"] = tombstones
    return value, evicted
