"""Shared transport machinery for crispy-daemon and DaemonBackend.

The daemon originally spoke newline-JSON over a unix-domain socket only,
with the framing inlined in daemon.py. Multi-host support needs the same
framing over TCP, so this module owns everything both transports share:

  addresses   `parse_address` maps one string form onto either transport:

                /tmp/crispy.sock          unix (anything with a path
                unix:///tmp/crispy.sock    separator, or no ':')
                127.0.0.1:7421            tcp  (host:port, numeric port)
                tcp://crispy-host:7421    tcp
                [::1]:7421                tcp  (bracketed IPv6)

              `describe_address` renders the parsed form back into the
              human string every connect error must carry — "unix socket
              '/tmp/crispy.sock'" vs "tcp address 127.0.0.1:7421" — so a
              misconfigured multi-host client names exactly what it
              failed to reach.

  framing     one JSON object per line, request -> response
              (`send_frame` / `recv_frame` over a socket makefile).

  auth        TCP exposes the daemon beyond the unix-permission boundary,
              so connections may be gated by a shared token: the FIRST
              frame on a connection must then be
              {"op": "auth", "token": ...}. `default_auth_token` reads
              $CRISPY_DAEMON_TOKEN so daemon and clients agree without
              plumbing the secret through every constructor.

  tracing     any request frame MAY carry a `trace` field (TRACE_FIELD)
              holding the caller's {"trace_id", "span_id"} propagation
              token (repro.telemetry.current_trace_context). The daemon
              then opens its per-op span as a child of that remote
              span, so cross-process traces stitch into one tree. The
              field is strictly optional on BOTH transports: a frame
              without it — i.e. every frame an old client sends — takes
              the exact pre-tracing code path and gets byte-identical
              responses.

  batching    {"op": "batch", "ops": [<frame>, ...]} (BATCH_OP) carries
              N sub-op frames in ONE round trip. The response is
              {"ok": true, "results": [<resp>, ...]} with exactly one
              result per sub-op, in order. Sub-op failures are isolated:
              a failing sub-op yields its own {"ok": false, "error": ..}
              slot and the remaining sub-ops still execute. Connection-
              scoped ops (`auth`) and frame-scoped ones (`batch` itself,
              `shutdown`) may not nest inside a batch. A frame-level
              `trace` field covers the whole batch (one adopted
              `daemon.op.batch` span; sub-ops are timed into their own
              `daemon.op.<op>.seconds` histograms). Like the trace
              field, the batch op is strictly additive: frames without
              it take the exact legacy single-op path, byte-identical
              (pinned by test_state_conformance).

              Clients may also PIPELINE legacy single-op frames: write
              N request lines before reading the N responses. The
              daemon answers strictly in order on each connection, so
              pipelining needs no protocol change and works against any
              daemon version (`DaemonBackend.pipeline()`).

  replication {"op": "replicate", "log": {"ns": .., "rows": [..],
              "base": c0, "cursor": c1}} ships a log tail (primary
              cursors c0..c1) to a warm-standby daemon, and
              {"op": "replicate", "doc": {"ns": .., "key": ..,
              "value": {..}, "version": n}} ships a versioned document.
              Both are idempotent by cursor/version: the standby tracks
              the highest primary cursor applied per namespace (and the
              highest primary doc version per key) and skips anything
              at or below it, so a restarted shipper can replay from
              zero without duplicating state. A frame whose `base` is
              past the standby's applied cursor is a replication GAP
              and is rejected ({"ok": false, "error": "replication
              gap..."}); the shipper then resets to cursor 0 and
              re-ships the (compacted) log from the head. Like every
              op, `replicate` rides behind the connection-level auth
              handshake, so a token-gated standby only accepts
              replication from holders of the shared secret. The op may
              ride inside a batch frame — the shipper coalesces one
              round of tails + docs into one round trip. See
              repro.state.sharding.ReplicationShipper.
"""
from __future__ import annotations

import json
import os
import socket
from typing import Dict, Optional, Tuple, Union

AUTH_TOKEN_ENV = "CRISPY_DAEMON_TOKEN"

# optional per-frame trace-propagation field (see module docstring)
TRACE_FIELD = "trace"

# multi-op frame: {"op": BATCH_OP, "ops": [...]} -> {"ok": true,
# "results": [...]} (see module docstring)
BATCH_OP = "batch"

# ops that must not appear INSIDE a batch frame: auth is connection
# state, shutdown tears the connection down mid-frame, and nesting
# batches would unbound the per-frame work a single line can demand
BATCH_EXCLUDED_OPS = frozenset({"auth", BATCH_OP, "shutdown"})

# warm-standby replication frame (see module docstring). May ride
# inside a batch — the shipper coalesces one round into one frame.
REPLICATE_OP = "replicate"

# the shard topology document lives ON the ring itself (a plain CAS doc
# replicated to every node), so any reachable daemon can answer "who is
# primary for shard X now" during client-side failover. Double-underscore
# namespace: reserved, same convention as __telemetry__ / __traces__.
TOPOLOGY_NS = "__topology__"
TOPOLOGY_KEY = "shards"

# parsed address forms: ("unix", path) | ("tcp", (host, port))
Address = Tuple[str, Union[str, Tuple[str, int]]]


def default_auth_token() -> Optional[str]:
    return os.environ.get(AUTH_TOKEN_ENV) or None


def parse_address(address: str) -> Address:
    """Classify an address string as unix or tcp (see module docstring)."""
    addr = address.strip()
    if addr.startswith("unix://"):
        return "unix", addr[len("unix://"):]
    if addr.startswith("tcp://"):
        addr = addr[len("tcp://"):]
        return "tcp", _host_port(addr)
    if addr.startswith("[") or (":" in addr and os.sep not in addr
                                and addr.rsplit(":", 1)[1].isdigit()):
        return "tcp", _host_port(addr)
    return "unix", addr


def _host_port(addr: str) -> Tuple[str, int]:
    if addr.startswith("["):                 # bracketed IPv6: [::1]:7421
        host, _, rest = addr[1:].partition("]")
        port = rest.lstrip(":")
    else:
        host, _, port = addr.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(
            f"not a host:port tcp address: {addr!r} (use host:port, "
            f"tcp://host:port or a unix socket path)")
    return host, int(port)


def describe_address(parsed: Address) -> str:
    """Human form for error messages: names the transport AND the target
    so unix-path vs host:port misconfiguration is obvious at a glance."""
    scheme, target = parsed
    if scheme == "unix":
        return f"unix socket '{target}'"
    host, port = target
    return f"tcp address {host}:{port}"


def connect(parsed: Address, timeout_s: float) -> socket.socket:
    """Open a connected stream socket for either transport."""
    scheme, target = parsed
    if scheme == "unix":
        if not hasattr(socket, "AF_UNIX"):   # pragma: no cover - non-POSIX
            raise OSError("unix-domain sockets unavailable on this platform")
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        sock.settimeout(timeout_s)
        try:
            sock.connect(target)
        except BaseException:
            sock.close()
            raise
        return sock
    host, port = target
    sock = socket.create_connection((host, port), timeout=timeout_s)
    sock.settimeout(timeout_s)
    return sock


# -- framing ------------------------------------------------------------------

def send_frame(wfile, payload: Dict) -> None:
    wfile.write((json.dumps(payload) + "\n").encode())
    wfile.flush()


# hard cap on one frame's pre-parse buffering. readline() with no bound
# buffers an arbitrarily long newline-free stream in RAM — on the TCP
# transport that lets any peer that can reach the port (even pre-auth)
# OOM the single daemon holding everyone's shared state. Generous for
# real traffic: the largest legitimate frame is a registry document.
MAX_FRAME_BYTES = 8 * 1024 * 1024


def recv_frame(rfile) -> Optional[Dict]:
    """Next frame, or None on a clean EOF. Raises ValueError on garbage
    or an over-long frame (the caller drops the connection — framing
    never resynchronizes)."""
    line = rfile.readline(MAX_FRAME_BYTES + 1)
    if not line:
        return None
    if len(line) > MAX_FRAME_BYTES:
        raise ValueError(f"frame exceeds {MAX_FRAME_BYTES} bytes")
    obj = json.loads(line)
    if not isinstance(obj, dict):
        raise ValueError(f"frame is not a JSON object: {obj!r}")
    return obj


def auth_frame(token: str) -> Dict:
    return {"op": "auth", "token": token}
