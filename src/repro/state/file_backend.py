"""FileBackend: the StateBackend over fcntl-locked files.

This module is the ONLY place in the repo that touches fcntl — it absorbs
the locking/JSONL machinery that PR 2 duplicated across
`repro.profiling.store.ProfileStore` and `LockedModelRegistry`.

Layout under the backend root (one directory shared by all processes):

  <ns>.jsonl        append-only log. Appends happen under an exclusive
                    lock as a single O_APPEND write, so concurrent writers
                    never interleave partial lines; `read` consumes bytes
                    from an offset cursor and only complete lines.
  <ns>.jsonl.meta   cursor base of a compacted log: {"base": n}. Logical
                    cursor = base + byte offset in the current file;
                    `compact` folds the log (tmp + rename) and bumps the
                    base past every pre-compaction cursor, so stale
                    cursors re-read the folded snapshot instead of
                    landing mid-line in the rewritten file. The meta file
                    persists with the log, which is what makes a
                    compacted daemon --root survive restarts.
  <ns>.json         versioned documents of the namespace:
                    {"docs": {key: {"version": n, "value": {...}}}}.
                    `cas` rewrites the file atomically (tmp + rename)
                    under an exclusive lock.
  <file>.lock       fcntl advisory lock files (created on demand).

Namespaces are sanitized into filenames, so `FileBackend(dir)` with
namespace "prof" shares state with any process that opens the same
directory — the cross-process story is the filesystem.
"""
from __future__ import annotations

import json
import os
import re
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.state.backend import StateBackend
from repro.state.compaction import fold_log

try:
    import fcntl
    HAS_FCNTL = True
except ImportError:                      # non-POSIX: degrade gracefully
    fcntl = None
    HAS_FCNTL = False

_NS_RE = re.compile(r"[^A-Za-z0-9._-]+")


class FileLock:
    """fcntl advisory lock on `path` (created on demand). Not reentrant
    within a process — hold it briefly. Degrades to a no-op lock where
    fcntl is unavailable (the O_APPEND write and atomic rename below are
    then the only cross-process guarantees)."""

    def __init__(self, path: str, shared: bool = False,
                 timeout_s: float = 10.0, poll_s: float = 0.005):
        self.path = path
        self.shared = shared
        self.timeout_s = timeout_s
        self.poll_s = poll_s
        self._fd: Optional[int] = None

    def acquire(self) -> "FileLock":
        self._fd = os.open(self.path, os.O_RDWR | os.O_CREAT, 0o644)
        if not HAS_FCNTL:
            return self
        flag = fcntl.LOCK_SH if self.shared else fcntl.LOCK_EX
        deadline = time.monotonic() + self.timeout_s
        while True:
            try:
                fcntl.flock(self._fd, flag | fcntl.LOCK_NB)
                return self
            except (BlockingIOError, OSError):
                if time.monotonic() >= deadline:
                    os.close(self._fd)
                    self._fd = None
                    raise TimeoutError(
                        f"could not lock {self.path} within "
                        f"{self.timeout_s}s")
                time.sleep(self.poll_s)

    def release(self) -> None:
        if self._fd is None:
            return
        try:
            if HAS_FCNTL:
                fcntl.flock(self._fd, fcntl.LOCK_UN)
        finally:
            os.close(self._fd)
            self._fd = None

    def __enter__(self) -> "FileLock":
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()


class FileBackend(StateBackend):
    kind = "file"

    def __init__(self, root: str, lock_timeout_s: float = 10.0):
        self.root = root
        self.lock_timeout_s = lock_timeout_s
        os.makedirs(root, exist_ok=True)

    # -- paths --------------------------------------------------------------
    def _ns(self, ns: str) -> str:
        clean = _NS_RE.sub("_", ns).strip("._") or "default"
        return os.path.join(self.root, clean)

    def log_path(self, ns: str) -> str:
        return self._ns(ns) + ".jsonl"

    def doc_path(self, ns: str) -> str:
        return self._ns(ns) + ".json"

    def _lock(self, path: str, shared: bool = False) -> FileLock:
        return FileLock(path + ".lock", shared=shared,
                        timeout_s=self.lock_timeout_s)

    # -- append-only logs ---------------------------------------------------
    def append(self, ns: str, record: Dict) -> None:
        line = (json.dumps(record) + "\n").encode()
        path = self.log_path(ns)
        with self._lock(path):
            fd = os.open(path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
            try:
                os.write(fd, line)
            finally:
                os.close(fd)

    def read(self, ns: str, cursor: int = 0) -> Tuple[List[Dict], int]:
        path = self.log_path(ns)
        if not os.path.exists(path):
            return [], cursor
        with self._lock(path, shared=True):
            base = self._read_base(path)
            # a cursor below the compaction base predates the last fold:
            # restart at the snapshot head (rows are idempotent)
            offset = max(0, cursor - base)
            with open(path, "rb") as f:
                f.seek(offset)
                data = f.read()
        if not data:
            return [], max(cursor, base + offset)
        # only consume complete lines; a torn tail (should not happen under
        # the lock, but be paranoid) is re-read by the next call
        end = data.rfind(b"\n") + 1
        rows: List[Dict] = []
        for line in data[:end].splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                rows.append(json.loads(line))
            except ValueError:
                continue            # skip a corrupt row, keep the rest
        return rows, base + offset + end

    def compact(self, ns: str,
                key_fields: Optional[Sequence[str]] = None,
                max_age_s: Optional[float] = None) -> Dict:
        path = self.log_path(ns)
        if not os.path.exists(path):
            return {"before": 0, "after": 0, "dropped": 0}
        with self._lock(path):
            with open(path, "rb") as f:
                data = f.read()
            old_base = self._read_base(path)
            rows = []
            for line in data.splitlines():
                line = line.strip()
                if not line:
                    continue
                try:
                    rows.append(json.loads(line))
                except ValueError:
                    continue
            folded = fold_log(rows, key_fields=key_fields,
                              max_age_s=max_age_s)
            # bump the base FIRST: a crash between the two writes leaves
            # base past every handed-out cursor with the old log intact —
            # readers re-read from the head, nothing tears. (No reader
            # runs in between anyway: both writes happen under the
            # exclusive lock `read` takes shared.)
            self._write_base(path, old_base + len(data))
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                for row in folded:
                    f.write((json.dumps(row) + "\n").encode())
            os.replace(tmp, path)
            return {"before": len(rows), "after": len(folded),
                    "dropped": len(rows) - len(folded)}

    def _meta_path(self, log_path: str) -> str:
        return log_path + ".meta"

    def _read_base(self, log_path: str) -> int:
        try:
            with open(self._meta_path(log_path)) as f:
                return int(json.load(f).get("base", 0))
        except (OSError, ValueError):
            return 0

    def _write_base(self, log_path: str, base: int) -> None:
        meta = self._meta_path(log_path)
        tmp = meta + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"base": base}, f)
        os.replace(tmp, meta)

    # -- versioned documents ------------------------------------------------
    def _read_docs(self, path: str) -> Dict[str, Dict]:
        if not os.path.exists(path):
            return {}
        try:
            with open(path) as f:
                payload = json.load(f)
        except ValueError:              # half-written legacy file
            return {}
        docs = payload.get("docs")
        return docs if isinstance(docs, dict) else {}

    def _write_docs(self, path: str, docs: Dict[str, Dict]) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"docs": docs}, f)
        os.replace(tmp, path)       # atomic on POSIX: no torn reads

    # -- replication enumeration --------------------------------------------
    # The shipper sees the SANITIZED namespace (the filename stem). That is
    # fine: sanitization is a fixpoint, so re-applying ops under the stem on
    # the standby lands in the same files, and every daemon-facing caller
    # already uses filename-safe namespaces.
    def log_namespaces(self) -> List[str]:
        try:
            names = os.listdir(self.root)
        except OSError:
            return []
        return sorted(f[:-len(".jsonl")] for f in names
                      if f.endswith(".jsonl"))

    def doc_snapshot(self) -> List[Tuple[str, str, Optional[Dict], int]]:
        try:
            names = os.listdir(self.root)
        except OSError:
            return []
        out: List[Tuple[str, str, Optional[Dict], int]] = []
        for f in sorted(names):
            if not f.endswith(".json"):
                continue
            ns = f[:-len(".json")]
            path = os.path.join(self.root, f)
            with self._lock(path, shared=True):
                docs = self._read_docs(path)
            for key in sorted(docs):
                entry = docs[key]
                out.append((ns, key, entry.get("value"),
                            int(entry.get("version", 0))))
        return out

    def load(self, ns: str, key: str) -> Tuple[Optional[Dict], int]:
        path = self.doc_path(ns)
        with self._lock(path, shared=True):
            entry = self._read_docs(path).get(key)
        if entry is None:
            return None, 0
        return entry.get("value"), int(entry.get("version", 0))

    def cas(self, ns: str, key: str, version: int,
            value: Dict) -> Tuple[bool, Optional[Dict], int]:
        path = self.doc_path(ns)
        with self._lock(path):
            docs = self._read_docs(path)
            entry = docs.get(key)
            cur_ver = int(entry.get("version", 0)) if entry else 0
            if cur_ver != version:
                return False, (entry.get("value") if entry else None), cur_ver
            docs[key] = {"version": cur_ver + 1, "value": value}
            self._write_docs(path, docs)
            return True, value, cur_ver + 1
