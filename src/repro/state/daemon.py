"""crispy-daemon: a single-writer shared-state server, unix socket + TCP.

The FileBackend shares state through fcntl locks — correct, but every CAS
is a lock/read/rewrite of a JSON file and contended reservations retry
through the filesystem. The daemon centralizes writes the way Ruya
centralizes its iteratively-updated memory model: ONE process owns the
state and applies every mutation atomically under one lock, and clients
talk to it over a newline-delimited JSON protocol (framing/address
parsing in transport.py). `reserve` becomes a single round trip instead
of a CAS retry loop, so N allocation-service processes arbitrate one
profiling envelope with no lock convoys.

Two transports, same protocol, served simultaneously:

  unix socket   --socket /tmp/crispy.sock — co-located services on one
                host, gated by filesystem permissions.
  tcp           --listen host:port — services on OTHER hosts share the
                same envelope/registry/store. Port 0 binds an ephemeral
                port; the resolved address is announced in the "serving"
                log line and written to --port-file when given. TCP crosses the
                unix-permission boundary, so pair it with --auth-token
                (or $CRISPY_DAEMON_TOKEN): the first frame on every
                connection must then be {"op": "auth", "token": ...}.

Wire protocol (one JSON object per line, request -> response):

  {"op": "auth", "token": ..}                      -> {"ok": true}
  {"op": "ping"}                                   -> {"ok": true}
  {"op": "append", "ns": .., "record": {..}}       -> {"ok": true}
  {"op": "read", "ns": .., "cursor": 0}            -> {"ok": true,
                                                       "rows": [..],
                                                       "cursor": n}
  {"op": "load", "ns": .., "key": ..}              -> {"ok": true,
                                                       "value": ..,
                                                       "version": n}
  {"op": "cas", "ns": .., "key": .., "version": n,
   "value": {..}}                                  -> {"ok": true,
                                                       "won": bool, ..}
  {"op": "reserve", "ns": .., "key": ..,
   "deltas": {..}, "limits": {..}}                 -> {"ok": true,
                                                       "granted": bool,
                                                       "doc": {..}}
  {"op": "compact", "ns": .., "key_fields": [..],
   "max_age_s": ..}                                -> {"ok": true,
                                                       "before": n,
                                                       "after": m,
                                                       "dropped": n-m}
  {"op": "evict_registry", "ns": .., "key": ..,
   "max_records": .., "max_age_s": ..}             -> {"ok": true,
                                                       "evicted": [..]}
  {"op": "metrics"}                                -> {"ok": true,
                                                       "metrics": {..}}
  {"op": "traces", "clear": false}                 -> {"ok": true,
                                                       "source": ..,
                                                       "traces": [..]}
  {"op": "replicate", "log": {..} | "doc": {..}}   -> {"ok": true,
                                                       "applied": ..,
                                                       "cursor"/"version"}
  {"op": "batch", "ops": [<frame>, ..]}            -> {"ok": true,
                                                       "results": [..]}
  {"op": "shutdown"}                               -> {"ok": true}

`batch` carries N sub-op frames in one round trip: exactly one result
per sub-op, in order, with per-op error isolation (a failing sub-op
contributes its own {"ok": false, "error": ..} slot and the rest still
run). `auth`, `shutdown` and `batch` itself may not nest inside it. The
daemon dispatches the sub-ops in a tight loop — one frame decode, one
batch span — while still timing each sub-op into its
`daemon.op.<op>.seconds` histogram, and records the distribution of
batch widths in `daemon.batch.size`. Clients may also PIPELINE legacy
single-op frames (write N lines, then read N responses — the daemon
answers strictly in order per connection); `DaemonBackend.pipeline()`
wraps that, and `DaemonBackend.batch()` wraps the batch frame with
automatic chunking under the frame cap. The shared views coalesce
automatically: `repro.profiling.store.refresh_views` fetches the
profile-store tail and the registry doc in one frame, and
`ProfileStore(write_behind=True)` flushes buffered point/anchor writes
as one batched append frame.

Additionally, ANY request frame may carry a `trace` field — the
caller's {"trace_id", "span_id"} propagation token (see
repro.state.transport and repro.telemetry). The daemon then times the
op inside a `daemon.op.<op>` span ADOPTED into the caller's trace
(recorded as a local root in the daemon's TraceRing, parent_id = the
caller's span), so `stitch_fleet_traces` can graft daemon work under
the requesting service's tree. Frames without the field — everything an
old client sends — take the pre-tracing code path and get
byte-identical responses, on both transports.

`metrics` returns the daemon's own telemetry snapshot (repro.telemetry):
per-op latency histograms `daemon.op.<op>.seconds` (with exemplars
referencing traced callers) plus frames/bytes_in/auth_failures/
compactions counters — identical over both transports. `traces` returns
(and with `"clear": true` drains) the daemon's finished trace roots as
span dicts. Server-side lifecycle events (serving announcement, errors,
clean shutdown) are structured one-line JSON on stderr
(`StructuredLogger`); the CLI's stdout answers ("pong", "no daemon",
"shutdown requested") are a scripting contract and never change shape.

Log compaction + registry eviction: append-only namespaces grow forever
under "later rows win", so `compact` folds a log into snapshot-plus-tail
form (repro.state.compaction) — cursors stay monotone, tombstoned
identities stay dead, and with a FileBackend --root the shrunken log
survives restarts. `--compact-after N` auto-compacts any log namespace
every N appends (optionally dropping rows older than
`--compact-max-age`); `--registry-max-records` / `--registry-max-age`
prune the model-registry document after each registry flush, recording
doc tombstones so sibling services cannot resurrect the eviction.

Lifecycle (also documented in the repro.state package docstring):

  start     python -m repro.state.daemon --socket /tmp/crispy.sock \
                [--listen 0.0.0.0:7421] [--root DIR | --memory]
            --root persists state through a FileBackend so a restarted
            daemon resumes where it stopped; --memory (the default when no
            root is given) serves an InMemoryBackend. With --listen and
            no --socket the daemon is TCP-only.
  health    python -m repro.state.daemon --socket ... --ping   (or
            --listen host:port --ping) exits 0 iff the daemon answers.
  shutdown  python -m repro.state.daemon --socket ... --shutdown
            asks the daemon to stop; the server drains, unlinks its
            socket and the foreground process exits 0. SIGTERM/SIGINT do
            the same.

Clients (`DaemonBackend`) accept either address form ("/tmp/crispy.sock"
or "host:port" / "tcp://host:port"), keep one connection per thread and
reconnect once on a transport error — a daemon restarted on the same
address is picked up transparently; a daemon that stays down surfaces
`StateBackendUnavailable` naming the exact unix path or host:port it
could not reach.

Sharding + warm-standby replication (repro.state.sharding): a daemon
started with `--shard-name shard-0 --standby ADDR` runs a
`ReplicationShipper` that periodically ships log tails and changed
documents to the standby daemon via batched `replicate` frames
(idempotent by cursor/version; `--replicate-interval` sets the period).
The applied `replicate` op is purely additive to the wire protocol —
legacy frames stay byte-identical (pinned by the conformance suite).
`--shard-name` also tags the daemon's telemetry source as
"crispy-daemon@<shard>", so fleet snapshots and `trace_tool --fleet`
attribute per-op heat to the right shard. Clients carrying `standby=`
fail over: on `StateBackendUnavailable` they retry the standby address
once and re-resolve the shard's primary from the topology doc stored
on the ring itself (sharding.publish_topology).
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import socket
import socketserver
import sys
import tempfile
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from repro.state.backend import (InMemoryBackend, StateBackend,
                                 StateBackendError, StateBackendUnavailable)
from repro.state.compaction import prune_registry_doc
from repro.state.file_backend import FileBackend
from repro.state.transport import (BATCH_EXCLUDED_OPS, BATCH_OP,
                                   MAX_FRAME_BYTES, TOPOLOGY_KEY,
                                   TOPOLOGY_NS, TRACE_FIELD,
                                   auth_frame, connect,
                                   default_auth_token, describe_address,
                                   parse_address, recv_frame, send_frame)
from repro.telemetry import (MetricsRegistry, StructuredLogger,
                             TelemetryPublisher, TraceRing,
                             current_trace_context, span)
from time import perf_counter, sleep

HAS_UNIX_SOCKETS = hasattr(socket, "AF_UNIX")

DEFAULT_SOCKET = os.path.join(tempfile.gettempdir(), "crispy-daemon.sock")
DEFAULT_TIMEOUT_S = 10.0

REGISTRY_NS = "registry"
REGISTRY_KEY = "records"


def default_socket_path() -> str:
    return os.environ.get("CRISPY_DAEMON_SOCKET", DEFAULT_SOCKET)


class CrispyDaemon:
    """Single-writer state server. Owns a local backend (InMemoryBackend
    by default, FileBackend when constructed with `root=` for durability
    across restarts), serializes every mutation under one lock, and
    serves it over a unix socket (`socket_path`), TCP (`listen`,
    "host:port" — port 0 for ephemeral), or both at once."""

    def __init__(self, socket_path: Optional[str] = None,
                 backend: Optional[StateBackend] = None,
                 root: Optional[str] = None,
                 listen: Optional[str] = None,
                 auth_token: Optional[str] = None,
                 compact_after: Optional[int] = None,
                 compact_max_age_s: Optional[float] = None,
                 registry_max_records: Optional[int] = None,
                 registry_max_age_s: Optional[float] = None,
                 telemetry=None,            # repro.telemetry MetricsRegistry
                 standby: Optional[str] = None,
                 replicate_interval_s: float = 0.5,
                 shard_name: Optional[str] = None,
                 op_delay_s: float = 0.0):
        if socket_path is None and listen is None:
            raise StateBackendError(
                "CrispyDaemon needs a unix socket_path, a tcp listen "
                "address, or both")
        if socket_path is not None and not HAS_UNIX_SOCKETS:
            raise StateBackendError(        # pragma: no cover - non-POSIX
                "unix-domain sockets are unavailable on this platform; "
                "use listen='host:port'")
        if backend is None:
            backend = FileBackend(root) if root else InMemoryBackend()
        self.backend = backend
        self.socket_path = socket_path
        self.listen = listen
        self.auth_token = auth_token
        self.compact_after = compact_after
        self.compact_max_age_s = compact_max_age_s
        self.registry_max_records = registry_max_records
        self.registry_max_age_s = registry_max_age_s
        # warm-standby replication (repro.state.sharding): when `standby`
        # names another daemon, start() launches a ReplicationShipper that
        # periodically ships this daemon's state there; `shard_name` tags
        # the telemetry source so fleet views attribute per-shard heat
        self.standby = standby
        self.replicate_interval_s = replicate_interval_s
        self.shard_name = shard_name
        self.shipper = None                      # set by start() if standby
        self._applier = None                     # lazy ReplicationApplier
        # opt-in per-mutation service-time model (--op-delay): slept
        # INSIDE the writer lock, where a durable backend would pay its
        # fsync — makes shard-topology scaling measurable on hosts with
        # fewer cores than shards, and widens failover race windows for
        # tests. Zero (the default) is a no-op on the hot path.
        self.op_delay_s = float(op_delay_s)
        self.tcp_address: Optional[str] = None   # resolved after start()
        self._write_lock = threading.Lock()
        self._appends_since_compact: Dict[str, int] = {}
        self._servers: List[socketserver.BaseServer] = []
        # servers whose serve_forever loop was started: shutdown() on a
        # never-served socketserver blocks forever on its is-shut-down
        # event, so stop() must only shut these down and merely close
        # the rest (a bound-but-unserved server from a failed start())
        self._serving: set = set()
        self._threads: List[threading.Thread] = []
        # open client connections, severed on stop() so handler threads
        # (daemon_threads) don't keep serving a "stopped" daemon
        self._conns: set = set()
        self._conns_lock = threading.Lock()
        # per-daemon registry by default: two daemons in one test process
        # must not sum each other's counters. Served as the `metrics` op.
        self.telemetry = telemetry if telemetry is not None \
            else MetricsRegistry()
        self._c_frames = self.telemetry.counter("daemon.frames")
        self._c_bytes = self.telemetry.counter("daemon.bytes_in")
        self._c_auth_failures = self.telemetry.counter(
            "daemon.auth_failures")
        self._c_compactions = self.telemetry.counter("daemon.compactions")
        # sub-ops per {"op": "batch"} frame — the wire-coalescing ledger:
        # mean batch size is how many round-trips each frame saved
        self._h_batch_size = self.telemetry.histogram(
            "daemon.batch.size",
            buckets=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128,
                     192, 256))
        # daemon.op.<op>.seconds histograms, created lazily on first use;
        # the plain-dict read is the lock-free fast path (a lost race just
        # calls the locking registry factory twice for the same name)
        self._op_hist: Dict[str, object] = {}
        # finished daemon-side spans (roots adopted into callers' traces);
        # served by the `traces` op and published by --telemetry-interval
        self.trace_ring = TraceRing()

    def _op_hist_for(self, op) -> "object":
        if not isinstance(op, str):
            op = "invalid"              # unknown junk shares one series
        h = self._op_hist.get(op)
        if h is None:
            h = self.telemetry.histogram(f"daemon.op.{op}.seconds")
            self._op_hist[op] = h
        return h

    @property
    def source(self) -> str:
        """Telemetry source label: shard-qualified when this daemon is
        one shard of a fleet, the historical label otherwise."""
        return (f"crispy-daemon@{self.shard_name}" if self.shard_name
                else "crispy-daemon")

    # -- request dispatch ---------------------------------------------------
    def handle_request(self, req: Dict) -> Dict:
        op = req.get("op")
        trace = req.pop(TRACE_FIELD, None)
        if isinstance(trace, dict):
            # traced caller: time the op INSIDE a span adopted into the
            # caller's trace, so the histogram observe lands its exemplar
            # with the caller's trace_id and the span (a local root with
            # parent_id = the caller's span) is stitchable fleet-wide
            op_name = op if isinstance(op, str) else "invalid"
            with span(f"daemon.op.{op_name}", ring=self.trace_ring,
                      parent=trace):
                t0 = perf_counter()
                try:
                    return self._dispatch(op, req)
                finally:
                    self._op_hist_for(op).observe(perf_counter() - t0)
        # untraced (legacy) frame: the exact pre-tracing path
        t0 = perf_counter()
        try:
            return self._dispatch(op, req)
        finally:
            self._op_hist_for(op).observe(perf_counter() - t0)

    def _dispatch(self, op, req: Dict) -> Dict:
        b = self.backend
        if op == BATCH_OP:
            return self._dispatch_batch(req)
        if op in ("ping", "auth"):      # auth is a no-op once admitted
            return {"ok": True, "kind": b.kind}
        if op == "metrics":
            # per-op latency histograms + frame/byte/compaction counters,
            # identical over both transports; `source` is shard-qualified
            # so fleet aggregation can attribute per-shard heat
            return {"ok": True, "kind": b.kind, "source": self.source,
                    "metrics": self.telemetry.snapshot()}
        if op == "traces":
            # finished daemon-side span roots, ready for stitching; the
            # in-flight request's own span closes after this snapshot
            roots = [s.to_dict() for s in self.trace_ring.traces()]
            if req.get("clear"):
                self.trace_ring.clear()
            return {"ok": True, "source": self.source,
                    "traces": roots}
        if op == "replicate":
            # warm-standby application, idempotent by primary cursor /
            # doc version (repro.state.sharding.ReplicationApplier)
            if self._applier is None:
                from repro.state.sharding import ReplicationApplier
                self._applier = ReplicationApplier(b)
            with self._write_lock:
                return self._applier.apply(req)
        if op == "append":
            with self._write_lock:
                b.append(req["ns"], req["record"])
                if self.op_delay_s:
                    sleep(self.op_delay_s)
                self._maybe_autocompact_locked(req["ns"])
            return {"ok": True}
        if op == "read":
            rows, cursor = b.read(req["ns"], int(req.get("cursor", 0)))
            return {"ok": True, "rows": rows, "cursor": cursor}
        if op == "load":
            value, version = b.load(req["ns"], req["key"])
            return {"ok": True, "value": value, "version": version}
        if op == "cas":
            with self._write_lock:
                won, value, version = b.cas(req["ns"], req["key"],
                                            int(req["version"]),
                                            req["value"])
                if self.op_delay_s:
                    sleep(self.op_delay_s)
                if won and self._maybe_prune_registry_locked(req["ns"],
                                                             req["key"]):
                    value, version = b.load(req["ns"], req["key"])
            return {"ok": True, "won": won, "value": value,
                    "version": version}
        if op == "reserve":
            # the whole check-and-bump happens under the writer lock: this
            # is the single-RPC arbitration FileBackend needs a CAS retry
            # loop for
            with self._write_lock:
                granted, doc = b.reserve(req["ns"], req["key"],
                                         req.get("deltas", {}),
                                         req.get("limits") or {})
                if self.op_delay_s:
                    sleep(self.op_delay_s)
            return {"ok": True, "granted": granted, "doc": doc}
        if op == "compact":
            with self._write_lock:
                stats = b.compact(req["ns"],
                                  key_fields=req.get("key_fields"),
                                  max_age_s=req.get("max_age_s"))
                self._appends_since_compact[req["ns"]] = 0
            self._c_compactions.inc()
            resp = {"ok": True}
            resp.update(stats)
            return resp
        if op == "evict_registry":
            with self._write_lock:
                evicted = self._prune_registry_locked(
                    req.get("ns", REGISTRY_NS),
                    req.get("key", REGISTRY_KEY),
                    req.get("max_records"), req.get("max_age_s"))
            return {"ok": True, "evicted": evicted}
        if op == "shutdown":
            threading.Thread(target=self.stop, daemon=True).start()
            return {"ok": True}
        return {"ok": False, "error": f"unknown op {op!r}"}

    def _dispatch_batch(self, req: Dict) -> Dict:
        """One {"op": "batch"} frame: execute the sub-ops in order with
        per-op error isolation. Sub-ops skip the per-frame plumbing
        (framing, trace adoption, frame counters are paid ONCE) but each
        still lands in its own `daemon.op.<op>.seconds` histogram, so
        per-op latency telemetry stays comparable across batched and
        single-op clients."""
        ops = req.get("ops")
        if not isinstance(ops, list):
            return {"ok": False,
                    "error": "batch needs \"ops\": [frame, ...]"}
        self._h_batch_size.observe(len(ops))
        results: List[Dict] = []
        for sub in ops:
            if not isinstance(sub, dict):
                results.append({"ok": False,
                                "error": f"batch op is not a frame: "
                                         f"{sub!r}"})
                continue
            sub_op = sub.get("op")
            if sub_op in BATCH_EXCLUDED_OPS:
                results.append({"ok": False,
                                "error": f"op {sub_op!r} is not allowed "
                                         f"inside a batch"})
                continue
            t0 = perf_counter()
            try:
                results.append(self._dispatch(sub_op, sub))
            except Exception as e:      # isolation: one bad sub-op must
                results.append({"ok": False,    # not fail its siblings
                                "error": f"{type(e).__name__}: {e}"})
            finally:
                self._op_hist_for(sub_op).observe(perf_counter() - t0)
        return {"ok": True, "results": results}

    # -- compaction / eviction thresholds -----------------------------------
    def _maybe_autocompact_locked(self, ns: str) -> None:
        if not self.compact_after:
            return
        n = self._appends_since_compact.get(ns, 0) + 1
        if n >= self.compact_after:
            self.backend.compact(ns, max_age_s=self.compact_max_age_s)
            self._c_compactions.inc()
            n = 0
        self._appends_since_compact[ns] = n

    def _maybe_prune_registry_locked(self, ns: str, key: str) -> bool:
        if (self.registry_max_records is None
                and self.registry_max_age_s is None):
            return False
        if ns != REGISTRY_NS or key != REGISTRY_KEY:
            return False
        return bool(self._prune_registry_locked(
            ns, key, self.registry_max_records, self.registry_max_age_s))

    def _prune_registry_locked(self, ns: str, key: str,
                               max_records: Optional[int],
                               max_age_s: Optional[float]) -> List[str]:
        b = self.backend
        while True:
            value, version = b.load(ns, key)
            new_value, evicted = prune_registry_doc(
                value, max_records=max_records, max_age_s=max_age_s)
            if not evicted:
                return []
            won, _cur, _ver = b.cas(ns, key, version, new_value)
            if won:
                return evicted
            # only possible when another PROCESS shares our FileBackend
            # root directly; re-read and retry

    # -- lifecycle ----------------------------------------------------------
    def _make_handler(self):
        daemon = self

        class Handler(socketserver.StreamRequestHandler):
            def setup(self):
                super().setup()
                with daemon._conns_lock:
                    daemon._conns.add(self.connection)

            def finish(self):
                with daemon._conns_lock:
                    daemon._conns.discard(self.connection)
                super().finish()

            def handle(self):
                authed = daemon.auth_token is None
                while True:
                    # bounded readline: an (even unauthenticated) peer
                    # streaming newline-free bytes must cost one frame's
                    # budget, not daemon RAM (see transport.MAX_FRAME_BYTES)
                    line = self.rfile.readline(MAX_FRAME_BYTES + 1)
                    if not line:
                        break
                    daemon._c_frames.inc()
                    daemon._c_bytes.inc(len(line))
                    if len(line) > MAX_FRAME_BYTES:
                        try:
                            self.wfile.write((json.dumps(
                                {"ok": False,
                                 "error": "frame too large"}) +
                                "\n").encode())
                            self.wfile.flush()
                        except OSError:
                            pass
                        return                  # drop: cannot resync
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        req = json.loads(line)
                        if not authed:
                            # the first frame MUST authenticate; anything
                            # else (including a wrong token) is answered
                            # once and the connection is dropped
                            if (req.get("op") == "auth" and
                                    req.get("token") == daemon.auth_token):
                                authed = True
                                resp = {"ok": True,
                                        "kind": daemon.backend.kind}
                            else:
                                daemon._c_auth_failures.inc()
                                resp = {"ok": False, "error":
                                        "auth required: send "
                                        '{"op": "auth", "token": ...} '
                                        "as the first frame"}
                        else:
                            resp = daemon.handle_request(req)
                    except Exception as e:      # a bad request must never
                        resp = {"ok": False,    # kill the server
                                "error": f"{type(e).__name__}: {e}"}
                    try:
                        self.wfile.write((json.dumps(resp) + "\n").encode())
                        self.wfile.flush()
                    except OSError:
                        return                  # client went away
                    if not resp.get("ok") and not authed:
                        return                  # failed auth: hang up

        return Handler

    def start(self, background: bool = True) -> "CrispyDaemon":
        handler = self._make_handler()
        try:
            if self.socket_path is not None:
                self._servers.append(self._start_unix(handler))
            if self.listen is not None:
                self._servers.append(self._start_tcp(handler))
        except BaseException:
            # e.g. the unix socket bound but the tcp port was taken: tear
            # down whatever DID bind, or the half-started daemon leaks a
            # listening-but-unserved socket that fools the liveness probe
            self.stop()
            raise
        if background:
            for server in self._servers:
                self._serve_on_thread(server)
        if self.standby is not None and self.shipper is None:
            from repro.state.sharding import ReplicationShipper
            self.shipper = ReplicationShipper(
                self.backend, self.standby, auth_token=self.auth_token,
                period_s=self.replicate_interval_s).start()
        return self

    def _serve_on_thread(self, server) -> None:
        self._serving.add(server)
        t = threading.Thread(
            target=lambda: server.serve_forever(poll_interval=0.05),
            daemon=True)
        t.start()
        self._threads.append(t)

    def _start_unix(self, handler) -> socketserver.BaseServer:
        if os.path.exists(self.socket_path):
            # a crash leaves a stale socket behind (safe to reclaim), but
            # a LIVE daemon must not be silently usurped — two daemons on
            # one path would split "the one shared envelope" in two
            probe = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            probe.settimeout(1.0)
            alive = False
            try:
                probe.connect(self.socket_path)
                alive = True
            except OSError:
                pass                         # stale: nobody listening
            finally:
                probe.close()
            if alive:
                raise StateBackendError(
                    f"a daemon is already serving {self.socket_path}; "
                    f"connect a DaemonBackend to it or pick another "
                    f"--socket")
            os.unlink(self.socket_path)

        class UnixServer(socketserver.ThreadingUnixStreamServer):
            daemon_threads = True
            allow_reuse_address = True

        return UnixServer(self.socket_path, handler)

    def _start_tcp(self, handler) -> socketserver.BaseServer:
        scheme, target = parse_address(self.listen)
        if scheme != "tcp":
            raise StateBackendError(
                f"listen= wants a tcp host:port address, got "
                f"{self.listen!r}")

        class TCPServer(socketserver.ThreadingTCPServer):
            daemon_threads = True
            allow_reuse_address = True
            if ":" in target[0]:        # a literal IPv6 host ([::1]:port)
                address_family = socket.AF_INET6

        server = TCPServer(target, handler)
        host, port = server.server_address[:2]
        self.tcp_address = (f"[{host}]:{port}" if ":" in str(host)
                            else f"{host}:{port}")   # resolves host:0
        return server

    def serve_forever(self) -> None:
        if not self._servers:
            self.start(background=False)
        servers = list(self._servers)
        if not servers:                 # stop() may have raced us
            return
        # extra servers run on background threads; the last one occupies
        # the foreground so `python -m repro.state.daemon` blocks
        for server in servers[:-1]:
            self._serve_on_thread(server)
        self._serving.add(servers[-1])
        servers[-1].serve_forever(poll_interval=0.05)

    def stop(self) -> None:
        shipper, self.shipper = self.shipper, None
        if shipper is not None:
            shipper.stop()      # final ship drains the tail when reachable
        servers, self._servers = self._servers, []
        for server in servers:
            if server in self._serving:
                server.shutdown()
            server.server_close()
        self._serving.clear()
        with self._conns_lock:
            conns = list(self._conns)
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        if self.socket_path and os.path.exists(self.socket_path):
            try:
                os.unlink(self.socket_path)
            except OSError:
                pass
        threads, self._threads = self._threads, []
        for t in threads:
            t.join(timeout=5.0)

    def __enter__(self) -> "CrispyDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


class DaemonBackend(StateBackend):
    """StateBackend speaking the crispy-daemon wire protocol over either
    transport: `DaemonBackend("/tmp/crispy.sock")` (unix) or
    `DaemonBackend("crispy-host:7421")` / `"tcp://host:port"` (tcp).

    One connection per thread (the AllocationService worker, profiling
    executor workers and direct callers each get their own); connections
    whose owning thread has exited are swept and closed on the next call
    from any thread, so long-lived services with thread churn never
    exhaust the daemon's connection slots. A transport error drops the
    connection and retries once, so clients fail over to a daemon
    restarted on the same address. A daemon that stays down raises
    `StateBackendUnavailable` naming the unix path or host:port —
    callers see a clean, debuggable error, never a hang: connects are
    bounded by `timeout_s` and response reads by `read_timeout_s`
    (default: `timeout_s`), so a daemon that accepts but never answers
    surfaces a timeout error instead of wedging the service worker.
    When the daemon requires a shared token, pass `auth_token=` or
    export $CRISPY_DAEMON_TOKEN; the client then authenticates every
    fresh connection before its first request.

    Wire coalescing: `batch(ops)` executes N ops in ONE round trip via
    the {"op": "batch"} frame (ordered results, per-op error isolation);
    `pipeline()` returns a context manager that queues ordinary backend
    calls and flushes them as pipelined legacy frames — N writes, one
    socket flush, N reads — which works against daemons that predate the
    batch op. The shared views coalesce automatically: see
    `repro.profiling.store.refresh_views` (store tail-read + registry
    doc get in one frame) and `ProfileStore(write_behind=True)` (profile
    point/anchor write-through flushed as one batched append frame)."""

    kind = "daemon"

    def __init__(self, address: Optional[str] = None,
                 timeout_s: float = DEFAULT_TIMEOUT_S,
                 auth_token: Optional[str] = None,
                 read_timeout_s: Optional[float] = None,
                 standby: Optional[str] = None,
                 shard_name: Optional[str] = None):
        self.address = address or default_socket_path()
        self._parsed = parse_address(self.address)
        self.transport = self._parsed[0]          # "unix" | "tcp"
        if self.transport == "unix" and not HAS_UNIX_SOCKETS:
            raise StateBackendError(   # pragma: no cover - non-POSIX
                "unix-domain sockets are unavailable on this platform; "
                "connect to a tcp daemon (host:port) instead")
        # back-compat: unix clients historically exposed .socket_path
        self.socket_path = (self._parsed[1]
                            if self.transport == "unix" else None)
        self.timeout_s = timeout_s
        self.read_timeout_s = (read_timeout_s if read_timeout_s is not None
                               else timeout_s)
        self.auth_token = (auth_token if auth_token is not None
                           else default_auth_token())
        # client-side failover (repro.state.sharding): when this client's
        # primary is unreachable and `standby` names the shard's warm
        # standby, _call retries there ONCE, then re-resolves the shard's
        # current primary/standby from the topology doc on the ring
        self.standby_address = standby
        self.shard_name = shard_name
        self.failovers = 0              # observable: how often we switched
        self._local = threading.local()
        # every open (thread, sock, file) triple, for the dead-thread
        # sweep + close(): per-thread caching alone leaks sockets when
        # threads exit without closing (executor pools churn workers)
        self._conn_registry: Dict[int, tuple] = {}
        self._conn_lock = threading.Lock()

    def describe(self) -> str:
        return describe_address(self._parsed)

    # -- transport ----------------------------------------------------------
    def _files(self):
        files = getattr(self._local, "files", None)
        if files is None:
            self._sweep_dead_threads()
            sock = connect(self._parsed, self.timeout_s)
            if self.read_timeout_s != self.timeout_s:
                sock.settimeout(self.read_timeout_s)
            files = (sock, sock.makefile("rwb"))
            self._local.files = files
            with self._conn_lock:
                # thread idents are REUSED: a new thread can inherit a
                # dead thread's ident before any sweep ran, and plainly
                # overwriting the slot would leak the dead thread's
                # socket until process exit — close the usurped entry
                stale = self._conn_registry.pop(threading.get_ident(), None)
                self._conn_registry[threading.get_ident()] = \
                    (threading.current_thread(), files)
            if stale is not None and stale[1] is not files:
                self._close_files(stale[1])
            if self.auth_token is not None:
                self._auth(files[1])
        return files

    def _sweep_dead_threads(self) -> None:
        """Close cached connections whose owning thread has exited —
        their threading.local slots are unreachable, so without this
        sweep every dead worker thread leaks one daemon connection for
        the life of the process."""
        with self._conn_lock:
            dead = [ident for ident, (thread, _f) in
                    self._conn_registry.items() if not thread.is_alive()]
            victims = [self._conn_registry.pop(ident) for ident in dead]
        for _thread, files in victims:
            self._close_files(files)

    @staticmethod
    def _close_files(files) -> None:
        sock, f = files
        for closer in (f.close, sock.close):
            try:
                closer()
            except OSError:
                pass

    def _auth(self, f) -> None:
        send_frame(f, auth_frame(self.auth_token))
        resp = recv_frame(f)
        if resp is None:
            raise ConnectionError("daemon closed the connection during auth")
        if not resp.get("ok"):
            self._drop()
            raise StateBackendError(
                f"crispy-daemon at {self.describe()} rejected our auth "
                f"token: {resp.get('error')}")

    def _drop(self) -> None:
        files = getattr(self._local, "files", None)
        self._local.files = None
        with self._conn_lock:
            self._conn_registry.pop(threading.get_ident(), None)
        if files is not None:
            self._close_files(files)

    # ops safe to blindly resend: they mutate nothing server-side that a
    # duplicate could corrupt (`traces` with clear= drains telemetry, so
    # a resend loses at worst best-effort trace rows, never state)
    _IDEMPOTENT_OPS = frozenset({"ping", "read", "load", "metrics",
                                 "traces"})

    def _retry_safe(self, payload: Dict) -> bool:
        """May this fully-sent frame be resent on a fresh connection? A
        batch frame is exactly as resendable as its least-resendable
        sub-op."""
        op = payload.get("op")
        if op == BATCH_OP:
            return all(isinstance(sub, dict)
                       and sub.get("op") in self._IDEMPOTENT_OPS
                       for sub in payload.get("ops") or ())
        return op in self._IDEMPOTENT_OPS

    def _call(self, payload: Dict) -> Dict:
        """`_call_once` plus client-side failover: when the primary is
        unreachable and a standby address is known, switch every future
        connection to the standby, retry the frame ONCE there, and
        re-resolve the shard's topology from the doc on the ring. A
        mutating frame that died mid-flight may thus execute at most
        twice (once invisibly on the dying primary, once on the
        standby); log rows are idempotent under the store's later-wins
        fold and CAS/reserve re-arbitrate, the same contract as the
        single-daemon reconnect retry. `shutdown` never fails over — a
        dead primary must not take its healthy standby down with it."""
        try:
            return self._call_once(payload)
        except StateBackendUnavailable as primary_err:
            target = self.standby_address
            if (target is None or payload.get("op") == "shutdown"
                    or parse_address(target) == self._parsed):
                raise
            self._activate(target)
            try:
                resp = self._call_once(payload)
            except StateBackendUnavailable:
                raise primary_err       # both down: name the primary error
            self.failovers += 1
            self._adopt_topology()
            return resp

    def _activate(self, address: str) -> None:
        """Point every future connection at `address` (the old address
        becomes the failover candidate, so a recovered ex-primary can be
        retried if the new one dies too)."""
        self.close()                    # sever EVERY thread's cached conn
        old = self.address
        self.address = address
        self._parsed = parse_address(address)
        self.transport = self._parsed[0]
        self.socket_path = (self._parsed[1]
                            if self.transport == "unix" else None)
        self.standby_address = old

    def _adopt_topology(self) -> None:
        """Refresh this shard's primary/standby from the topology doc on
        whatever node we just reached (best-effort: a fleet without a
        published doc keeps the swapped pair from `_activate`)."""
        if self.shard_name is None or getattr(self._local, "adopting",
                                              False):
            return
        self._local.adopting = True     # the load() below re-enters _call
        try:
            value, _version = self.load(TOPOLOGY_NS, TOPOLOGY_KEY)
            entry = ((value or {}).get("shards") or {}).get(self.shard_name)
            if not isinstance(entry, dict):
                return
            primary, standby = entry.get("primary"), entry.get("standby")
            for candidate in (primary, standby):
                if (candidate and
                        parse_address(candidate) != self._parsed):
                    self.standby_address = candidate
                    return
        except (StateBackendError, ValueError):
            pass
        finally:
            self._local.adopting = False

    def _call_once(self, payload: Dict) -> Dict:
        op = payload.get("op")
        ctx = current_trace_context()
        if ctx is not None:
            # inside an active span: stamp the propagation token so the
            # daemon's work joins this trace (old daemons ignore unknown
            # request fields, so this is safe against version skew)
            payload = dict(payload)
            payload[TRACE_FIELD] = ctx
        last: Optional[Exception] = None
        for attempt in range(2):        # second attempt = fresh connection
            sent = False
            try:
                _sock, f = self._files()
                send_frame(f, payload)
                sent = True
                resp = recv_frame(f)
                if resp is None:
                    raise ConnectionError("daemon closed the connection")
                if not resp.get("ok"):
                    raise StateBackendError(
                        f"daemon at {self.describe()} rejected {op}: "
                        f"{resp.get('error')}")
                return resp
            except StateBackendError:
                raise                   # auth rejection / op rejection
            except socket.timeout as e:
                # the daemon accepted the frame but never answered (a
                # wedged writer lock, a stuck disk): drop the connection
                # and name the wedge — the caller must never hang
                self._drop()
                raise StateBackendUnavailable(
                    f"crispy-daemon at {self.describe()} did not answer "
                    f"{op} within {self.read_timeout_s}s (the operation "
                    f"may or may not have been applied): "
                    f"{e or 'timed out'}")
            except (OSError, ValueError, ConnectionError) as e:
                self._drop()
                last = e
                # a mutating op (append/cas/reserve/compact) whose request
                # was fully sent may already have been applied server-side
                # — resending could apply it twice (double-spend a budget
                # point, duplicate a log row), so surface the ambiguity
                # instead of retrying. Failures before the request went
                # out (dead cached connection, connect refused) are
                # always safe to retry on a fresh connection.
                if sent and not self._retry_safe(payload):
                    raise StateBackendUnavailable(
                        f"crispy-daemon connection lost mid-{op} at "
                        f"{self.describe()} (the operation may or may "
                        f"not have been applied): {e}")
        raise StateBackendUnavailable(
            f"crispy-daemon unreachable at {self.describe()}: {last}")

    # -- protocol ------------------------------------------------------------
    def append(self, ns: str, record: Dict) -> None:
        self._call({"op": "append", "ns": ns, "record": record})

    def read(self, ns: str, cursor: int = 0) -> Tuple[List[Dict], int]:
        resp = self._call({"op": "read", "ns": ns, "cursor": cursor})
        return resp["rows"], resp["cursor"]

    def load(self, ns: str, key: str) -> Tuple[Optional[Dict], int]:
        resp = self._call({"op": "load", "ns": ns, "key": key})
        return resp["value"], resp["version"]

    def cas(self, ns: str, key: str, version: int,
            value: Dict) -> Tuple[bool, Optional[Dict], int]:
        resp = self._call({"op": "cas", "ns": ns, "key": key,
                           "version": version, "value": value})
        return resp["won"], resp["value"], resp["version"]

    def reserve(self, ns: str, key: str, deltas: Dict[str, float],
                limits: Optional[Dict[str, float]] = None
                ) -> Tuple[bool, Dict]:
        resp = self._call({"op": "reserve", "ns": ns, "key": key,
                           "deltas": deltas, "limits": limits or {}})
        return resp["granted"], resp["doc"]

    def compact(self, ns: str,
                key_fields: Optional[Sequence[str]] = None,
                max_age_s: Optional[float] = None) -> Dict:
        resp = self._call({"op": "compact", "ns": ns,
                           "key_fields": (list(key_fields)
                                          if key_fields is not None
                                          else None),
                           "max_age_s": max_age_s})
        return {"before": resp["before"], "after": resp["after"],
                "dropped": resp["dropped"]}

    # -- wire coalescing -----------------------------------------------------

    # leave the daemon headroom under MAX_FRAME_BYTES: the batch frame
    # wraps the sub-ops in envelope JSON and may gain a trace field
    _BATCH_BYTE_BUDGET = MAX_FRAME_BYTES // 2

    def batch(self, ops: Sequence[Dict]) -> List[Dict]:
        """Execute N ops in ONE {"op": "batch"} round trip: ordered
        wire-shaped results, per-op error isolation (a failing sub-op
        yields its {"ok": false} slot without aborting the rest — this
        method raises only on transport/frame failures). Oversized
        batches are split into successive frames, each well under the
        daemon's 8 MiB line cap, preserving op order across chunks.
        Reconnect-retry follows `_call`'s single-op rule: a batch frame
        is resent only when EVERY sub-op is idempotent."""
        results: List[Dict] = []
        for chunk in self._chunk_ops(list(ops)):
            resp = self._call({"op": BATCH_OP, "ops": chunk})
            got = resp.get("results")
            if not isinstance(got, list) or len(got) != len(chunk):
                raise StateBackendError(
                    f"daemon at {self.describe()} returned "
                    f"{len(got) if isinstance(got, list) else 'no'} "
                    f"batch results for {len(chunk)} ops")
            results.extend(got)
        return results

    def _chunk_ops(self, ops: List[Dict]) -> List[List[Dict]]:
        """Split a batch so each frame stays under _BATCH_BYTE_BUDGET
        serialized (single over-budget ops still go out alone — the
        daemon's frame cap is the real enforcement boundary)."""
        chunks: List[List[Dict]] = []
        current: List[Dict] = []
        used = 0
        for op in ops:
            size = len(json.dumps(op)) + 2       # +2 for ", " separators
            if current and used + size > self._BATCH_BYTE_BUDGET:
                chunks.append(current)
                current, used = [], 0
            current.append(op)
            used += size
        if current:
            chunks.append(current)
        return chunks

    def pipeline(self) -> "_DaemonPipeline":
        """Context manager that queues ordinary single-op frames and
        flushes them as a pipelined burst on exit — N request lines
        written with ONE socket flush, then N responses read in order.
        Works against daemons that predate the batch op (the server
        answers strictly in order per connection, so no protocol change
        is needed). Queued calls return handles whose `.result()` is
        valid after the `with` block:

            with backend.pipeline() as p:
                h1 = p.read("profiles", cursor)
                h2 = p.load("registry", "records")
            rows, cur = h1.result()["rows"], h1.result()["cursor"]

        A transport failure mid-flush raises `StateBackendUnavailable`
        when any non-idempotent op may have reached the daemon (same
        ambiguity rule as `_call`); a failure before any byte went out
        retries once on a fresh connection."""
        return _DaemonPipeline(self)

    def evict_registry(self, ns: str = REGISTRY_NS, key: str = REGISTRY_KEY,
                       max_records: Optional[int] = None,
                       max_age_s: Optional[float] = None) -> List[str]:
        """Daemon-side registry eviction by count/age; returns the evicted
        signatures (tombstoned in the doc, so siblings honor it)."""
        resp = self._call({"op": "evict_registry", "ns": ns, "key": key,
                           "max_records": max_records,
                           "max_age_s": max_age_s})
        return list(resp.get("evicted", []))

    def metrics(self, with_source: bool = False):
        """The daemon's telemetry snapshot (`daemon.op.<op>.seconds`
        histograms + frame/byte/auth-failure/compaction counters) —
        same answer over unix and tcp transports. `with_source=True`
        returns (source, snapshot) where source is the daemon's
        shard-qualified telemetry label ("crispy-daemon@shard-0" on a
        fleet member, "crispy-daemon" on a lone daemon or one that
        predates sharding)."""
        resp = self._call({"op": "metrics"})
        if with_source:
            return resp.get("source") or "crispy-daemon", resp["metrics"]
        return resp["metrics"]

    def traces(self, clear: bool = False, with_source: bool = False):
        """The daemon's finished trace roots (span dicts, ready for
        `stitch_fleet_traces`); `clear=True` drains the ring.
        `with_source=True` returns (source, roots), same labeling rule
        as `metrics`."""
        resp = self._call({"op": "traces", "clear": bool(clear)})
        roots = list(resp.get("traces", []))
        if with_source:
            return resp.get("source") or "crispy-daemon", roots
        return roots

    def ping(self) -> bool:
        try:
            return bool(self._call({"op": "ping"}).get("ok"))
        except StateBackendError:
            return False

    def shutdown_daemon(self) -> None:
        """Ask the daemon to stop (it drains and unlinks its socket)."""
        self._call({"op": "shutdown"})
        self._drop()

    def close(self) -> None:
        """Close EVERY cached connection, not just the calling thread's:
        a service shutting down must release all its daemon slots even
        for worker threads that are still parked in a pool — including
        connections whose owning thread died mid-call (the registry
        holds them regardless of thread liveness). Idempotent: a second
        close() finds an empty registry and does nothing. Surviving
        threads that call again after close() reconnect transparently
        (their first attempt fails on the closed socket and `_call`
        retries on a fresh connection)."""
        self._local.files = None
        with self._conn_lock:
            victims = list(self._conn_registry.values())
            self._conn_registry.clear()
        for _thread, files in victims:
            self._close_files(files)


class _PipelineHandle:
    """Future-like result slot for one pipelined op (see
    `DaemonBackend.pipeline`). `.result()` returns the wire-shaped
    response dict ({"ok": true, "rows": ...} etc.) once the pipeline
    has flushed; a rejected op raises StateBackendError there, so one
    bad op never poisons its neighbors' results."""

    __slots__ = ("op", "_resp", "_error", "_done")

    def __init__(self, op: str):
        self.op = op
        self._resp: Optional[Dict] = None
        self._error: Optional[Exception] = None
        self._done = False

    def _resolve(self, resp: Optional[Dict], error: Optional[Exception]):
        self._resp, self._error, self._done = resp, error, True

    def result(self) -> Dict:
        if not self._done:
            raise StateBackendError(
                f"pipelined {self.op} has not been flushed yet — read "
                f"results after the `with backend.pipeline()` block")
        if self._error is not None:
            raise self._error
        return self._resp


class _DaemonPipeline:
    """Queues single-op frames and flushes them as one write burst (see
    `DaemonBackend.pipeline`). Not thread-safe — a pipeline belongs to
    the thread that opened it, like the connection it rides on."""

    def __init__(self, backend: DaemonBackend):
        self._backend = backend
        self._queue: List[Tuple[Dict, _PipelineHandle]] = []
        self._flushed = False

    # -- queuing (mirrors the backend's protocol surface) -------------------
    def call(self, payload: Dict) -> _PipelineHandle:
        if self._flushed:
            raise StateBackendError("pipeline already flushed")
        handle = _PipelineHandle(str(payload.get("op")))
        self._queue.append((dict(payload), handle))
        return handle

    def ping(self) -> _PipelineHandle:
        return self.call({"op": "ping"})

    def append(self, ns: str, record: Dict) -> _PipelineHandle:
        return self.call({"op": "append", "ns": ns, "record": record})

    def read(self, ns: str, cursor: int = 0) -> _PipelineHandle:
        return self.call({"op": "read", "ns": ns, "cursor": cursor})

    def load(self, ns: str, key: str) -> _PipelineHandle:
        return self.call({"op": "load", "ns": ns, "key": key})

    def cas(self, ns: str, key: str, version: int,
            value: Dict) -> _PipelineHandle:
        return self.call({"op": "cas", "ns": ns, "key": key,
                          "version": version, "value": value})

    def reserve(self, ns: str, key: str, deltas: Dict[str, float],
                limits: Optional[Dict[str, float]] = None
                ) -> _PipelineHandle:
        return self.call({"op": "reserve", "ns": ns, "key": key,
                          "deltas": deltas, "limits": limits or {}})

    # -- flush ---------------------------------------------------------------
    def __enter__(self) -> "_DaemonPipeline":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.flush()

    def flush(self) -> None:
        """Write every queued frame, one socket flush, then read the
        responses in order and resolve the handles."""
        if self._flushed:
            return
        self._flushed = True
        if not self._queue:
            return
        backend = self._backend
        ctx = current_trace_context()
        payloads = []
        for payload, _h in self._queue:
            if ctx is not None:
                payload = dict(payload, **{TRACE_FIELD: ctx})
            payloads.append(payload)
        all_idempotent = all(backend._retry_safe(p) for p in payloads)
        last: Optional[Exception] = None
        for attempt in range(2):
            sent = False
            try:
                _sock, f = backend._files()
                blob = b"".join((json.dumps(p) + "\n").encode()
                                for p in payloads)
                f.write(blob)
                f.flush()
                sent = True
                for payload, handle in self._queue:
                    resp = recv_frame(f)
                    if resp is None:
                        raise ConnectionError(
                            "daemon closed the connection mid-pipeline")
                    if not resp.get("ok"):
                        handle._resolve(None, StateBackendError(
                            f"daemon at {backend.describe()} rejected "
                            f"{handle.op}: {resp.get('error')}"))
                    else:
                        handle._resolve(resp, None)
                return
            except socket.timeout as e:
                backend._drop()
                err = StateBackendUnavailable(
                    f"crispy-daemon at {backend.describe()} did not "
                    f"answer a pipelined burst of {len(self._queue)} ops "
                    f"within {backend.read_timeout_s}s (the operations "
                    f"may or may not have been applied): "
                    f"{e or 'timed out'}")
                self._fail_unresolved(err)
                raise err
            except (OSError, ValueError, ConnectionError) as e:
                backend._drop()
                last = e
                if sent and not all_idempotent:
                    err = StateBackendUnavailable(
                        f"crispy-daemon connection lost mid-pipeline at "
                        f"{backend.describe()} (some of the "
                        f"{len(self._queue)} queued operations may have "
                        f"been applied): {e}")
                    self._fail_unresolved(err)
                    raise err
        err = StateBackendUnavailable(
            f"crispy-daemon unreachable at {self._backend.describe()}: "
            f"{last}")
        self._fail_unresolved(err)
        raise err

    def _fail_unresolved(self, error: Exception) -> None:
        for _payload, handle in self._queue:
            if not handle._done:
                handle._resolve(None, error)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.state.daemon",
        description="crispy-daemon: shared-state server for Crispy "
                    "allocation services (see module docstring for the "
                    "lifecycle).")
    ap.add_argument("--socket", default=None,
                    help="unix socket path (default: $CRISPY_DAEMON_SOCKET "
                         f"or {DEFAULT_SOCKET}, unless --listen makes the "
                         "daemon tcp-only)")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="also serve TCP on this address (port 0 = "
                         "ephemeral; resolved address is announced and "
                         "written to --port-file)")
    ap.add_argument("--port-file", default=None,
                    help="write the resolved tcp host:port here after "
                         "binding (for scripts that use --listen host:0)")
    ap.add_argument("--auth-token", default=None,
                    help="require this shared token as the first frame of "
                         "every connection (default: $CRISPY_DAEMON_TOKEN "
                         "when set)")
    ap.add_argument("--root", default=None,
                    help="persist state in this directory (FileBackend); "
                         "a restarted daemon resumes from it")
    ap.add_argument("--memory", action="store_true",
                    help="serve an in-memory backend (the default when "
                         "--root is not given)")
    ap.add_argument("--compact-after", type=int, default=None, metavar="N",
                    help="auto-compact a log namespace every N appends")
    ap.add_argument("--compact-max-age", type=float, default=None,
                    metavar="S", help="during compaction, drop rows whose "
                    "'ts' is older than S seconds")
    ap.add_argument("--registry-max-records", type=int, default=None,
                    metavar="N", help="evict oldest registry records "
                    "beyond N after each registry flush")
    ap.add_argument("--registry-max-age", type=float, default=None,
                    metavar="S", help="evict registry records older than "
                    "S seconds after each registry flush")
    ap.add_argument("--telemetry-interval", type=float, default=None,
                    metavar="S", help="publish the daemon's own metrics "
                    "snapshot (__telemetry__ namespace) and trace roots "
                    "(__traces__) into its backend every S seconds "
                    "(source 'crispy-daemon', shard-qualified under "
                    "--shard-name)")
    ap.add_argument("--standby", default=None, metavar="ADDR",
                    help="warm-standby daemon address (unix path or "
                         "host:port); this daemon ships its log tails "
                         "and changed documents there via batched "
                         "'replicate' frames")
    ap.add_argument("--replicate-interval", type=float, default=0.5,
                    metavar="S", help="seconds between replication "
                    "rounds to --standby (default 0.5)")
    ap.add_argument("--shard-name", default=None, metavar="NAME",
                    help="this daemon's shard name in a sharded fleet "
                    "(e.g. shard-0); tags telemetry as "
                    "'crispy-daemon@NAME' for per-shard heat")
    ap.add_argument("--op-delay", type=float, default=0.0, metavar="S",
                    help="inject S seconds of per-mutation service time "
                    "under the writer lock (models a durable backend's "
                    "fsync; benchmark/failover testing only, default 0)")
    ap.add_argument("--ping", action="store_true",
                    help="health-check a running daemon and exit")
    ap.add_argument("--shutdown", action="store_true",
                    help="ask a running daemon to stop and exit")
    args = ap.parse_args(argv)

    auth_token = args.auth_token or default_auth_token()
    # server-side events are structured one-line JSON on stderr; the CLI
    # answers on stdout ("pong" / "no daemon" / "shutdown requested" and
    # the exit codes) are a scripting contract and stay byte-identical
    log = StructuredLogger("crispy-daemon")

    if args.ping or args.shutdown:
        # --listen names the tcp daemon to target; else the unix socket
        target = args.listen or args.socket or default_socket_path()
        try:
            client = DaemonBackend(target, timeout_s=5.0,
                                   auth_token=auth_token)
            if args.ping:
                ok = client.ping()
                print("pong" if ok else "no daemon", flush=True)
                return 0 if ok else 1
            client.shutdown_daemon()
            print("shutdown requested", flush=True)
            return 0
        except StateBackendError as e:
            log.error("client command failed", target=target, error=str(e))
            return 1

    socket_path = args.socket
    if socket_path is None and args.listen is None:
        socket_path = default_socket_path()
    if socket_path is not None and not HAS_UNIX_SOCKETS:
        log.error("unix sockets unavailable on this platform; "
                  "use --listen host:port")
        return 2

    daemon = CrispyDaemon(socket_path, root=args.root, listen=args.listen,
                          auth_token=auth_token,
                          compact_after=args.compact_after,
                          compact_max_age_s=args.compact_max_age,
                          registry_max_records=args.registry_max_records,
                          registry_max_age_s=args.registry_max_age,
                          standby=args.standby,
                          replicate_interval_s=args.replicate_interval,
                          shard_name=args.shard_name,
                          op_delay_s=args.op_delay)
    # stop() blocks until serve_forever returns, so it must not run on the
    # thread serve_forever occupies (the signal handler interrupts it)
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_: threading.Thread(
            target=daemon.stop, daemon=True).start())
    try:
        daemon.start(background=True)   # bind before announcing
    except (StateBackendError, OSError) as e:   # e.g. live daemon / EADDRINUSE
        log.error("start failed", error=str(e))
        return 1
    log.info("serving", backend=daemon.backend.kind,
             unix=socket_path, tcp=daemon.tcp_address,
             auth=bool(auth_token), shard=args.shard_name,
             standby=args.standby)
    if args.port_file and daemon.tcp_address:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(daemon.tcp_address)
        os.replace(tmp, args.port_file)
    publisher = None
    if args.telemetry_interval:
        publisher = TelemetryPublisher(
            daemon.backend, daemon.source, daemon.telemetry,
            period_s=args.telemetry_interval,
            ring=daemon.trace_ring).start()
    try:
        # the servers run on background threads (started above so the
        # announce/port-file happens after EVERY bind); park until stop()
        for t in list(daemon._threads):
            t.join()
    except OSError:                     # server socket closed by stop()
        pass
    # a remote "shutdown" op triggers stop() on a daemon thread; finish
    # the cleanup (socket unlink) here so process exit never races it
    daemon.stop()
    if publisher is not None:
        publisher.stop()                # final snapshot lands the totals
    log.info("clean shutdown")
    return 0


if __name__ == "__main__":
    sys.exit(main())
