"""crispy-daemon: a single-writer shared-state server over a unix socket.

The FileBackend shares state through fcntl locks — correct, but every CAS
is a lock/read/rewrite of a JSON file and contended reservations retry
through the filesystem. The daemon centralizes writes the way Ruya
centralizes its iteratively-updated memory model: ONE process owns the
state and applies every mutation atomically under one lock, and clients
talk to it over a newline-delimited JSON protocol on a unix-domain
socket. `reserve` becomes a single round trip instead of a CAS retry
loop, so N allocation-service processes arbitrate one profiling envelope
with no lock convoys.

Wire protocol (one JSON object per line, request -> response):

  {"op": "ping"}                                   -> {"ok": true}
  {"op": "append", "ns": .., "record": {..}}       -> {"ok": true}
  {"op": "read", "ns": .., "cursor": 0}            -> {"ok": true,
                                                       "rows": [..],
                                                       "cursor": n}
  {"op": "load", "ns": .., "key": ..}              -> {"ok": true,
                                                       "value": ..,
                                                       "version": n}
  {"op": "cas", "ns": .., "key": .., "version": n,
   "value": {..}}                                  -> {"ok": true,
                                                       "won": bool, ..}
  {"op": "reserve", "ns": .., "key": ..,
   "deltas": {..}, "limits": {..}}                 -> {"ok": true,
                                                       "granted": bool,
                                                       "doc": {..}}
  {"op": "shutdown"}                               -> {"ok": true}

Lifecycle (also documented in the repro.state package docstring):

  start     python -m repro.state.daemon --socket /tmp/crispy.sock \
                [--root DIR | --memory]
            --root persists state through a FileBackend so a restarted
            daemon resumes where it stopped; --memory (the default when no
            root is given) serves an InMemoryBackend.
  health    python -m repro.state.daemon --socket /tmp/crispy.sock --ping
            exits 0 iff the daemon answers.
  shutdown  python -m repro.state.daemon --socket /tmp/crispy.sock \
                --shutdown
            asks the daemon to stop; the server drains, unlinks its
            socket and the foreground process exits 0. SIGTERM/SIGINT do
            the same.

Clients (`DaemonBackend`) keep one connection per thread and reconnect
once on a transport error — a daemon restarted on the same socket path is
picked up transparently; a daemon that stays down surfaces
`StateBackendUnavailable` with the socket path in the message.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import socket
import socketserver
import sys
import tempfile
import threading
from typing import Dict, List, Optional, Tuple

from repro.state.backend import (InMemoryBackend, StateBackend,
                                 StateBackendError, StateBackendUnavailable)
from repro.state.file_backend import FileBackend

HAS_UNIX_SOCKETS = hasattr(socket, "AF_UNIX")

DEFAULT_SOCKET = os.path.join(tempfile.gettempdir(), "crispy-daemon.sock")
DEFAULT_TIMEOUT_S = 10.0


def default_socket_path() -> str:
    return os.environ.get("CRISPY_DAEMON_SOCKET", DEFAULT_SOCKET)


class CrispyDaemon:
    """Single-writer state server. Owns a local backend (InMemoryBackend
    by default, FileBackend when constructed with `root=` for durability
    across restarts) and serializes every mutation under one lock."""

    def __init__(self, socket_path: str,
                 backend: Optional[StateBackend] = None,
                 root: Optional[str] = None):
        if not HAS_UNIX_SOCKETS:       # pragma: no cover - non-POSIX
            raise StateBackendError(
                "unix-domain sockets are unavailable on this platform")
        if backend is None:
            backend = FileBackend(root) if root else InMemoryBackend()
        self.backend = backend
        self.socket_path = socket_path
        self._write_lock = threading.Lock()
        self._server: Optional[socketserver.ThreadingUnixStreamServer] = None
        self._thread: Optional[threading.Thread] = None
        # open client connections, severed on stop() so handler threads
        # (daemon_threads) don't keep serving a "stopped" daemon
        self._conns: set = set()
        self._conns_lock = threading.Lock()

    # -- request dispatch ---------------------------------------------------
    def handle_request(self, req: Dict) -> Dict:
        op = req.get("op")
        b = self.backend
        if op == "ping":
            return {"ok": True, "kind": b.kind}
        if op == "append":
            with self._write_lock:
                b.append(req["ns"], req["record"])
            return {"ok": True}
        if op == "read":
            rows, cursor = b.read(req["ns"], int(req.get("cursor", 0)))
            return {"ok": True, "rows": rows, "cursor": cursor}
        if op == "load":
            value, version = b.load(req["ns"], req["key"])
            return {"ok": True, "value": value, "version": version}
        if op == "cas":
            with self._write_lock:
                won, value, version = b.cas(req["ns"], req["key"],
                                            int(req["version"]),
                                            req["value"])
            return {"ok": True, "won": won, "value": value,
                    "version": version}
        if op == "reserve":
            # the whole check-and-bump happens under the writer lock: this
            # is the single-RPC arbitration FileBackend needs a CAS retry
            # loop for
            with self._write_lock:
                granted, doc = b.reserve(req["ns"], req["key"],
                                         req.get("deltas", {}),
                                         req.get("limits") or {})
            return {"ok": True, "granted": granted, "doc": doc}
        if op == "shutdown":
            threading.Thread(target=self.stop, daemon=True).start()
            return {"ok": True}
        return {"ok": False, "error": f"unknown op {op!r}"}

    # -- lifecycle ----------------------------------------------------------
    def start(self, background: bool = True) -> "CrispyDaemon":
        if os.path.exists(self.socket_path):
            # a crash leaves a stale socket behind (safe to reclaim), but
            # a LIVE daemon must not be silently usurped — two daemons on
            # one path would split "the one shared envelope" in two
            probe = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            probe.settimeout(1.0)
            alive = False
            try:
                probe.connect(self.socket_path)
                alive = True
            except OSError:
                pass                         # stale: nobody listening
            finally:
                probe.close()
            if alive:
                raise StateBackendError(
                    f"a daemon is already serving {self.socket_path}; "
                    f"connect a DaemonBackend to it or pick another "
                    f"--socket")
            os.unlink(self.socket_path)
        daemon = self

        class Handler(socketserver.StreamRequestHandler):
            def setup(self):
                super().setup()
                with daemon._conns_lock:
                    daemon._conns.add(self.connection)

            def finish(self):
                with daemon._conns_lock:
                    daemon._conns.discard(self.connection)
                super().finish()

            def handle(self):
                for line in self.rfile:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        req = json.loads(line)
                        resp = daemon.handle_request(req)
                    except Exception as e:      # a bad request must never
                        resp = {"ok": False,    # kill the server
                                "error": f"{type(e).__name__}: {e}"}
                    try:
                        self.wfile.write((json.dumps(resp) + "\n").encode())
                        self.wfile.flush()
                    except OSError:
                        return                  # client went away

        class Server(socketserver.ThreadingUnixStreamServer):
            daemon_threads = True
            allow_reuse_address = True

        self._server = Server(self.socket_path, Handler)
        if background:
            self._thread = threading.Thread(
                target=lambda: self._server.serve_forever(poll_interval=0.05),
                daemon=True)
            self._thread.start()
        return self

    def serve_forever(self) -> None:
        if self._server is None:
            self.start(background=False)
        server = self._server
        if server is not None:          # stop() may have raced us
            server.serve_forever(poll_interval=0.05)

    def stop(self) -> None:
        server, self._server = self._server, None
        if server is not None:
            server.shutdown()
            server.server_close()
        with self._conns_lock:
            conns = list(self._conns)
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        if os.path.exists(self.socket_path):
            try:
                os.unlink(self.socket_path)
            except OSError:
                pass
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "CrispyDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


class DaemonBackend(StateBackend):
    """StateBackend speaking the crispy-daemon wire protocol.

    One connection per thread (the AllocationService worker, profiling
    executor workers and direct callers each get their own); a transport
    error drops the connection and retries once, so clients fail over to
    a daemon restarted on the same socket path. A daemon that stays down
    raises `StateBackendUnavailable` — callers see a clean error, never a
    hang (socket ops are bounded by `timeout_s`)."""

    kind = "daemon"

    def __init__(self, socket_path: Optional[str] = None,
                 timeout_s: float = DEFAULT_TIMEOUT_S):
        if not HAS_UNIX_SOCKETS:       # pragma: no cover - non-POSIX
            raise StateBackendError(
                "unix-domain sockets are unavailable on this platform")
        self.socket_path = socket_path or default_socket_path()
        self.timeout_s = timeout_s
        self._local = threading.local()

    # -- transport ----------------------------------------------------------
    def _files(self):
        files = getattr(self._local, "files", None)
        if files is None:
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.settimeout(self.timeout_s)
            sock.connect(self.socket_path)
            files = (sock, sock.makefile("rwb"))
            self._local.files = files
        return files

    def _drop(self) -> None:
        files = getattr(self._local, "files", None)
        self._local.files = None
        if files is not None:
            sock, f = files
            for closer in (f.close, sock.close):
                try:
                    closer()
                except OSError:
                    pass

    # ops safe to blindly resend: they mutate nothing server-side
    _IDEMPOTENT_OPS = frozenset({"ping", "read", "load"})

    def _call(self, payload: Dict) -> Dict:
        op = payload.get("op")
        last: Optional[Exception] = None
        for attempt in range(2):        # second attempt = fresh connection
            sent = False
            try:
                _sock, f = self._files()
                f.write((json.dumps(payload) + "\n").encode())
                f.flush()
                sent = True
                line = f.readline()
                if not line:
                    raise ConnectionError("daemon closed the connection")
                resp = json.loads(line)
                if not resp.get("ok"):
                    raise StateBackendError(
                        f"daemon rejected {op}: {resp.get('error')}")
                return resp
            except (OSError, ValueError, ConnectionError) as e:
                self._drop()
                last = e
                # a mutating op (append/cas/reserve) whose request was
                # fully sent may already have been applied server-side —
                # resending could apply it twice (double-spend a budget
                # point, duplicate a log row), so surface the ambiguity
                # instead of retrying. Failures before the request went
                # out (dead cached connection, connect refused) are
                # always safe to retry on a fresh connection.
                if sent and op not in self._IDEMPOTENT_OPS:
                    raise StateBackendUnavailable(
                        f"crispy-daemon connection lost mid-{op} at "
                        f"{self.socket_path} (the operation may or may "
                        f"not have been applied): {e}")
        raise StateBackendUnavailable(
            f"crispy-daemon unreachable at {self.socket_path}: {last}")

    # -- protocol ------------------------------------------------------------
    def append(self, ns: str, record: Dict) -> None:
        self._call({"op": "append", "ns": ns, "record": record})

    def read(self, ns: str, cursor: int = 0) -> Tuple[List[Dict], int]:
        resp = self._call({"op": "read", "ns": ns, "cursor": cursor})
        return resp["rows"], resp["cursor"]

    def load(self, ns: str, key: str) -> Tuple[Optional[Dict], int]:
        resp = self._call({"op": "load", "ns": ns, "key": key})
        return resp["value"], resp["version"]

    def cas(self, ns: str, key: str, version: int,
            value: Dict) -> Tuple[bool, Optional[Dict], int]:
        resp = self._call({"op": "cas", "ns": ns, "key": key,
                           "version": version, "value": value})
        return resp["won"], resp["value"], resp["version"]

    def reserve(self, ns: str, key: str, deltas: Dict[str, float],
                limits: Optional[Dict[str, float]] = None
                ) -> Tuple[bool, Dict]:
        resp = self._call({"op": "reserve", "ns": ns, "key": key,
                           "deltas": deltas, "limits": limits or {}})
        return resp["granted"], resp["doc"]

    def ping(self) -> bool:
        try:
            return bool(self._call({"op": "ping"}).get("ok"))
        except StateBackendError:
            return False

    def shutdown_daemon(self) -> None:
        """Ask the daemon to stop (it drains and unlinks its socket)."""
        self._call({"op": "shutdown"})
        self._drop()

    def close(self) -> None:
        self._drop()


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.state.daemon",
        description="crispy-daemon: shared-state server for Crispy "
                    "allocation services (see module docstring for the "
                    "lifecycle).")
    ap.add_argument("--socket", default=default_socket_path(),
                    help="unix socket path (default: $CRISPY_DAEMON_SOCKET "
                         f"or {DEFAULT_SOCKET})")
    ap.add_argument("--root", default=None,
                    help="persist state in this directory (FileBackend); "
                         "a restarted daemon resumes from it")
    ap.add_argument("--memory", action="store_true",
                    help="serve an in-memory backend (the default when "
                         "--root is not given)")
    ap.add_argument("--ping", action="store_true",
                    help="health-check a running daemon and exit")
    ap.add_argument("--shutdown", action="store_true",
                    help="ask a running daemon to stop and exit")
    args = ap.parse_args(argv)

    if not HAS_UNIX_SOCKETS:           # pragma: no cover - non-POSIX
        print("crispy-daemon: unix sockets unavailable on this platform",
              file=sys.stderr)
        return 2

    if args.ping or args.shutdown:
        client = DaemonBackend(args.socket, timeout_s=5.0)
        try:
            if args.ping:
                ok = client.ping()
                print("pong" if ok else "no daemon", flush=True)
                return 0 if ok else 1
            client.shutdown_daemon()
            print("shutdown requested", flush=True)
            return 0
        except StateBackendError as e:
            print(f"crispy-daemon: {e}", file=sys.stderr)
            return 1

    daemon = CrispyDaemon(args.socket, root=args.root)
    # stop() blocks until serve_forever returns, so it must not run on the
    # thread serve_forever occupies (the signal handler interrupts it)
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_: threading.Thread(
            target=daemon.stop, daemon=True).start())
    try:
        daemon.start(background=False)  # bind before announcing
    except StateBackendError as e:      # e.g. live daemon on this socket
        print(f"crispy-daemon: {e}", file=sys.stderr)
        return 1
    print(f"crispy-daemon: serving {daemon.backend.kind} state on "
          f"{args.socket}", flush=True)
    try:
        daemon.serve_forever()
    except OSError:                     # server socket closed by stop()
        pass
    # a remote "shutdown" op triggers stop() on a daemon thread; finish
    # the cleanup (socket unlink) here so process exit never races it
    daemon.stop()
    print("crispy-daemon: clean shutdown", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
