"""StateBackend: the one shared-state protocol behind Crispy's stores.

Before this package, every shared-state owner hand-rolled its own
multi-process machinery: `ProfileStore` and `LockedModelRegistry` each
carried their own fcntl JSONL/merge code, and `ProfilingBudget` was
process-local (two service processes could each spend the full ten-minute
envelope). `StateBackend` factors the sharing into one transport-agnostic
protocol with exactly two storage shapes plus one arbitration primitive:

  append-only logs    `append(ns, record)` / `read(ns, cursor)` — ordered
                      JSON-safe records per namespace, read incrementally
                      from an opaque integer cursor. This is the shape of
                      the profile/anchor store: later rows win, so readers
                      need no compaction.

  versioned documents `load(ns, key)` / `cas(ns, key, version, value)` —
                      a JSON document with a monotonically increasing
                      version; `cas` succeeds only when the caller's
                      version matches the current one. This is the shape
                      of the model registry (read-merge-CAS flush) and the
                      shared budget doc.

  lease reservations  `reserve(ns, key, deltas, limits)` — atomically bump
                      numeric counters in a document iff the limits hold.
                      This is the shape of cross-process budget
                      arbitration: N processes reserve points from one
                      envelope and the backend guarantees the sum never
                      exceeds it. The base implementation is a CAS retry
                      loop so any backend gets it for free; the daemon
                      backend forwards it as a single RPC the single-writer
                      server applies atomically.

Implementations:

  InMemoryBackend     dict + threading.Lock. Tests, embedded single-process
                      use, and the storage engine inside the daemon.
  FileBackend         fcntl-locked JSONL logs + atomically rewritten JSON
                      doc files (file_backend.py). The only module in the
                      repo allowed to touch fcntl.
  DaemonBackend       newline-JSON RPC over a unix-domain socket to a
                      single-writer `crispy-daemon` (daemon.py).
"""
from __future__ import annotations

import threading
from abc import ABC, abstractmethod
from typing import Dict, List, Optional, Sequence, Tuple

from repro.state.compaction import fold_log


class StateBackendError(RuntimeError):
    """Base error for backend failures."""


class StateBackendUnavailable(StateBackendError):
    """The backend's transport is down (daemon crashed / socket gone).
    Callers may retry after the daemon restarts; state survives when the
    daemon is backed by a FileBackend root."""


class CASConflict(StateBackendError):  # pragma: no cover - debugging aid
    """Optional strict-mode error for callers that treat a lost CAS race
    as exceptional rather than retryable."""


class StateBackend(ABC):
    """Transport-agnostic shared-state protocol (see module docstring).

    All values must be JSON-serializable dicts; namespaces are short
    identifier-like strings (implementations may sanitize them into
    filenames). Every operation is atomic with respect to every other
    operation on the same backend, across threads and — for FileBackend
    and DaemonBackend — across processes.
    """

    kind: str = "abstract"

    # -- append-only logs ---------------------------------------------------
    @abstractmethod
    def append(self, ns: str, record: Dict) -> None:
        """Append one record to the `ns` log. Concurrent appends never
        interleave or drop records."""

    @abstractmethod
    def read(self, ns: str, cursor: int = 0) -> Tuple[List[Dict], int]:
        """Records appended since `cursor` (0 = start), plus the new
        cursor. Cursors are opaque ints valid only for this backend;
        they stay monotone across `compact` — a cursor taken before a
        compaction re-reads the folded snapshot (rows are idempotent
        under "later wins", so re-application is harmless), never a torn
        or partial view."""

    def compact(self, ns: str,
                key_fields: Optional[Sequence[str]] = None,
                max_age_s: Optional[float] = None) -> Dict:
        """Fold the `ns` log into snapshot-plus-tail form (see
        repro.state.compaction.fold_log): keep the LAST row per identity
        key — a tombstone row survives as its identity's last word, so
        stale readers still observe the deletion — and drop over-age
        survivors. Returns {"before": n, "after": m, "dropped": n - m}.
        Pre-compaction cursors remain valid (they re-read the snapshot).
        Backends that cannot rewrite their log raise StateBackendError."""
        raise StateBackendError(
            f"{self.kind} backend does not support compaction")

    # -- versioned documents ------------------------------------------------
    @abstractmethod
    def load(self, ns: str, key: str) -> Tuple[Optional[Dict], int]:
        """Current (value, version) of a document; (None, 0) if absent."""

    @abstractmethod
    def cas(self, ns: str, key: str, version: int,
            value: Dict) -> Tuple[bool, Optional[Dict], int]:
        """Replace the document iff its version still equals `version`
        (0 = create). Returns (won, current_value, current_version) —
        on a lost race the current state is returned so the caller can
        merge and retry."""

    # -- lease-style reservations ------------------------------------------
    def reserve(self, ns: str, key: str, deltas: Dict[str, float],
                limits: Optional[Dict[str, float]] = None
                ) -> Tuple[bool, Dict]:
        """Atomically apply `deltas` to numeric fields of the document iff
        every limit holds. For each (field, limit) in `limits`:

          * the field is being bumped (a nonzero delta): granted iff the
            post-apply value stays <= limit — a reservation may land
            exactly on the ceiling;
          * the field is a pure guard (no/zero delta): granted iff the
            current value is strictly < limit — matches "the envelope is
            already spent" semantics for charged-seconds checks.

        Returns (granted, document-after). A denied reservation changes
        nothing. Default implementation: CAS retry loop (correct on any
        backend); DaemonBackend overrides with a single server-side RPC.
        """
        limits = limits or {}
        while True:
            current, version = self.load(ns, key)
            doc = dict(current or {})
            granted = True
            for field, limit in limits.items():
                if limit is None:
                    continue
                cur = float(doc.get(field, 0))
                delta = float(deltas.get(field, 0))
                ok = (cur + delta <= limit) if delta else (cur < limit)
                if not ok:
                    granted = False
                    break
            if not granted:
                return False, doc
            for field, delta in deltas.items():
                doc[field] = float(doc.get(field, 0)) + float(delta)
            won, cur_val, _v = self.cas(ns, key, version, doc)
            if won:
                return True, doc
            # lost the race: re-read and re-check against fresh state

    # -- batched ops ---------------------------------------------------------
    def batch(self, ops: Sequence[Dict]) -> List[Dict]:
        """Execute several ops as one unit, returning one wire-shaped
        result dict per op, in order (the same shapes the crispy-daemon
        puts on the wire — {"ok": true, "rows": ...} etc.). Failures are
        isolated per op: a failing op yields {"ok": false, "error": ...}
        and the remaining ops still run. Ops are applied sequentially in
        order, so a batch may read its own earlier writes.

        The base implementation loops locally — correct on any backend,
        no faster than N calls. `DaemonBackend` overrides it with ONE
        {"op": "batch"} wire frame, turning N round-trips into one;
        views coalesce their hot read patterns through this method (see
        repro.profiling.store.refresh_views)."""
        return [self._apply_batch_op(op) for op in ops]

    def _apply_batch_op(self, req: Dict) -> Dict:
        try:
            if not isinstance(req, dict):
                raise StateBackendError(f"batch op is not a dict: {req!r}")
            op = req.get("op")
            if op == "ping":
                return {"ok": True, "kind": self.kind}
            if op == "append":
                self.append(req["ns"], req["record"])
                return {"ok": True}
            if op == "read":
                rows, cursor = self.read(req["ns"],
                                         int(req.get("cursor", 0)))
                return {"ok": True, "rows": rows, "cursor": cursor}
            if op == "load":
                value, version = self.load(req["ns"], req["key"])
                return {"ok": True, "value": value, "version": version}
            if op == "cas":
                won, value, version = self.cas(req["ns"], req["key"],
                                               int(req["version"]),
                                               req["value"])
                return {"ok": True, "won": won, "value": value,
                        "version": version}
            if op == "reserve":
                granted, doc = self.reserve(req["ns"], req["key"],
                                            req.get("deltas", {}),
                                            req.get("limits") or {})
                return {"ok": True, "granted": granted, "doc": doc}
            if op == "compact":
                stats = self.compact(req["ns"],
                                     key_fields=req.get("key_fields"),
                                     max_age_s=req.get("max_age_s"))
                resp = {"ok": True}
                resp.update(stats)
                return resp
            return {"ok": False, "error": f"unknown op {op!r}"}
        except Exception as e:
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}

    # -- replication enumeration --------------------------------------------
    def log_namespaces(self) -> List[str]:
        """Namespaces that currently hold log rows. Used by the warm-standby
        ReplicationShipper (which runs colocated with the primary's local
        storage backend) to discover what to ship; backends that cannot
        enumerate return [] and simply aren't shippable sources."""
        return []

    def doc_snapshot(self) -> List[Tuple[str, str, Optional[Dict], int]]:
        """Every versioned document as (ns, key, value, version). Same
        consumer and same default as `log_namespaces`."""
        return []

    # -- lifecycle ----------------------------------------------------------
    def ping(self) -> bool:
        """True when the backend is reachable."""
        return True

    def close(self) -> None:
        """Release transport resources (no-op for local backends)."""

    def __enter__(self) -> "StateBackend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class InMemoryBackend(StateBackend):
    """Process-local reference implementation: tests, embedded use, and
    the storage engine the daemon serves when started with --memory."""

    kind = "memory"

    def __init__(self):
        self._lock = threading.Lock()
        self._logs: Dict[str, List[Dict]] = {}
        # logical cursor = base + index into the current (possibly folded)
        # log; compaction bumps the base past every pre-compaction cursor
        # so stale cursors deterministically re-read the snapshot
        self._bases: Dict[str, int] = {}
        self._docs: Dict[Tuple[str, str], Tuple[Dict, int]] = {}

    def append(self, ns: str, record: Dict) -> None:
        with self._lock:
            self._logs.setdefault(ns, []).append(dict(record))

    def read(self, ns: str, cursor: int = 0) -> Tuple[List[Dict], int]:
        with self._lock:
            log = self._logs.get(ns, ())
            base = self._bases.get(ns, 0)
            start = max(0, cursor - base)
            rows = [dict(r) for r in log[start:]]
            return rows, base + len(log)

    def compact(self, ns: str,
                key_fields: Optional[Sequence[str]] = None,
                max_age_s: Optional[float] = None) -> Dict:
        with self._lock:
            log = self._logs.get(ns, [])
            before = len(log)
            folded = fold_log(log, key_fields=key_fields,
                              max_age_s=max_age_s)
            # every pre-compaction cursor is <= base + before == new base,
            # so each lands at snapshot start after the fold
            self._bases[ns] = self._bases.get(ns, 0) + before
            self._logs[ns] = folded
            return {"before": before, "after": len(folded),
                    "dropped": before - len(folded)}

    def load(self, ns: str, key: str) -> Tuple[Optional[Dict], int]:
        with self._lock:
            value, version = self._docs.get((ns, key), (None, 0))
            return (dict(value) if value is not None else None), version

    def cas(self, ns: str, key: str, version: int,
            value: Dict) -> Tuple[bool, Optional[Dict], int]:
        with self._lock:
            cur_val, cur_ver = self._docs.get((ns, key), (None, 0))
            if cur_ver != version:
                return (False,
                        dict(cur_val) if cur_val is not None else None,
                        cur_ver)
            self._docs[(ns, key)] = (dict(value), cur_ver + 1)
            return True, dict(value), cur_ver + 1

    def log_namespaces(self) -> List[str]:
        with self._lock:
            return sorted(ns for ns, log in self._logs.items()
                          if log or self._bases.get(ns, 0))

    def doc_snapshot(self) -> List[Tuple[str, str, Optional[Dict], int]]:
        with self._lock:
            return [(ns, key, dict(value), version)
                    for (ns, key), (value, version) in sorted(
                        self._docs.items())]
