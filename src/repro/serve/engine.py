"""Batched serving engine: continuous batching over a fixed-slot KV cache.

The engine keeps `slots` concurrent sequences. Each scheduler tick:
  1. admit queued requests into free slots (prompt tokens are injected
     through the decode path token-by-token — teacher-forced prefill — so
     one compiled decode_step serves both phases; architectures with a
     fused prefill use it via `prefill_into_slot`);
  2. run one batched decode_step for all active slots;
  3. retire sequences that hit max tokens or EOS.

Greedy or temperature sampling. This is the serving analogue the paper's
"job" maps onto for decode shapes, and the engine the serve_demo example
drives.

`AllocationEndpoint` exposes the allocator subsystem
(repro.allocator.service) on the same serving surface: dict-in/dict-out
allocation requests, optionally attached to a `ServeEngine` via
`attach_allocator` so one server answers both generation and
resource-allocation traffic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.allocator.service import (AllocationRequest, AllocationResponse,
                                     AllocationService)
from repro.models.model import Model
from repro.telemetry import span_if


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    submitted_at: float = field(default_factory=time.monotonic)
    finished_at: Optional[float] = None


class ServeEngine:
    def __init__(self, model: Model, params, slots: int, max_len: int,
                 eos_id: Optional[int] = None, seed: int = 0,
                 allocator: Optional[AllocationService] = None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.caches = model.init_caches(slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.pending: List[Request] = []
        self.finished: List[Request] = []
        self._feed: List[List[int]] = [[] for _ in range(slots)]
        self._last_token = np.zeros((slots,), np.int32)
        self.allocation_endpoint: Optional[AllocationEndpoint] = None
        if allocator is not None:
            self.attach_allocator(allocator)

        self._step = jax.jit(
            lambda p, b, c: model.decode_step(p, b, c, None))

    # -- public ------------------------------------------------------------
    def submit(self, req: Request):
        self.pending.append(req)

    def attach_allocator(self,
                         service: AllocationService) -> "AllocationEndpoint":
        """Expose an AllocationService next to the generation loop."""
        self.allocation_endpoint = AllocationEndpoint(service)
        return self.allocation_endpoint

    def allocate(self, **payload) -> Dict:
        """Answer one allocation request (see AllocationEndpoint.handle)."""
        if self.allocation_endpoint is None:
            raise RuntimeError("no AllocationService attached; call "
                               "attach_allocator() first")
        return self.allocation_endpoint.handle(**payload)

    def run(self, max_ticks: int = 10000) -> List[Request]:
        ticks = 0
        while (self.pending or any(self.active)) and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.finished

    # -- internals ----------------------------------------------------------
    def tick(self):
        self._admit()
        if not any(self.active):
            return
        batch = {"tokens": jnp.asarray(self._last_token)[:, None]}
        extras = self._extras()
        batch.update(extras)
        logits, self.caches = self._step(self.params, batch, self.caches)
        logits = np.asarray(logits[:, 0])           # (slots, V)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            if self._feed[i]:
                # still teacher-forcing the prompt
                self._last_token[i] = self._feed[i].pop(0)
                continue
            tok = self._sample(logits[i], req.temperature)
            req.out_tokens.append(int(tok))
            self._last_token[i] = tok
            if (len(req.out_tokens) >= req.max_new_tokens or
                    (self.eos_id is not None and tok == self.eos_id)):
                req.done = True
                req.finished_at = time.monotonic()
                self.finished.append(req)
                self.active[i] = None

    def _admit(self):
        for i in range(self.slots):
            if self.active[i] is None and self.pending:
                req = self.pending.pop(0)
                self.active[i] = req
                self.caches = _reset_slot(self.caches, i)
                self._feed[i] = list(req.prompt[1:])
                self._last_token[i] = req.prompt[0]

    def _extras(self) -> Dict:
        cfg = self.model.cfg
        extras = {}
        if cfg.family == "vlm":
            extras["media"] = jnp.zeros(
                (self.slots, cfg.cross_attn.n_media_tokens, cfg.d_model),
                jnp.float32)
        if cfg.family == "audio":
            extras["enc_out"] = jnp.zeros(
                (self.slots, cfg.encdec.enc_len, cfg.d_model), jnp.float32)
        return extras

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        if temperature <= 0.0:
            return int(np.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, jnp.asarray(logits) /
                                          temperature))


# base rank of each cache leaf kind; batch axis = ndim - base_rank
_BATCH_RANK = {"k": 4, "v": 4, "ckv": 3, "kr": 3, "pos": 1,
               "h": 4, "conv": 3, "wkv": 4, "tm_last": 2, "cm_last": 2}


class AllocationEndpoint:
    """Request endpoint over an AllocationService: wire-friendly dicts in,
    dicts out, with the service's batching/caching behind it. `submit`
    returns the service future for async callers; `handle` blocks (pass
    `include_trace=True` for per-stage walls + acquisition-tier counts);
    `stats` reports service counters plus adaptive-profiling/budget state
    for monitoring dashboards; `metrics` is the full telemetry snapshot
    (histogram percentiles included).

    Tracing: `handle` runs inside an `endpoint.request` span (when the
    service's telemetry is enabled, or always when the caller passes its
    own `trace=` propagation token to join an upstream trace), so the
    worker-side `service.*` spans, the pipeline stages, and any daemon
    round-trips all land under ONE trace id — returned on the wire as
    `trace_id` (None when untraced) for correlation with
    `stitch_fleet_traces` output and histogram exemplars."""

    def __init__(self, service: AllocationService):
        self.service = service

    def submit(self, *, job: str, profile_at, full_size: float,
               anchor: Optional[float] = None,
               sizes: Optional[List[float]] = None,
               signature: Optional[str] = None,
               leeway: Optional[float] = None,
               adaptive: Optional[bool] = None,
               placement: Optional[str] = None,
               tags: Optional[List[str]] = None,
               objective: str = "cheapest_fit"):
        return self.service.submit(AllocationRequest(
            job, profile_at, full_size, anchor=anchor, sizes=sizes,
            signature=signature, leeway=leeway, adaptive=adaptive,
            placement=placement, tags=tags, objective=objective))

    def handle(self, timeout: Optional[float] = None,
               include_trace: bool = False,
               trace: Optional[Dict] = None, **payload) -> Dict:
        # the span must wrap submit(): the service captures the caller's
        # trace context at submit time to hand it across the worker-
        # thread boundary. `trace=` is an upstream propagation token
        # ({"trace_id", "span_id"}) for callers that are themselves part
        # of a larger trace.
        tel = self.service.telemetry
        with span_if(tel.enabled or trace is not None, "endpoint.request",
                     parent=trace, job=payload.get("job")) as sp:
            resp = self.submit(**payload).result(timeout)
            wire = self.to_wire(resp)
            # which shared-state backend served this answer ("memory" /
            # "file" / "daemon", None for a process-local service), and
            # for a daemon, over which transport ("unix" | "tcp")
            wire["backend"] = self.service.backend_kind
            wire["backend_transport"] = self.service.backend_transport
            shards = self.service.backend_shards
            if shards is not None:
                # only present over a sharded backend: single-backend
                # wire answers keep their exact historical shape
                wire["backend_shards"] = [s["name"] for s in shards]
            wire["trace_id"] = sp.trace_id if sp is not None else None
            if include_trace:
                # opt-in ONLY: the rest of the wire answer stays stable
                lru_hits = max(0, resp.cache_hits - resp.store_hits)
                wire["trace"] = {
                    "stage_walls": dict(resp.stage_walls or {}),
                    "acquisition": {"fresh": resp.profiled,
                                    "lru_hits": lru_hits,
                                    "store_hits": resp.store_hits}}
        return wire

    def metrics(self) -> Dict:
        """Full telemetry snapshot (counters / gauges / histograms with
        p50/p95/p99) of the attached service — the wire form of
        `AllocationService.metrics()`, plus backend identity and the
        budget envelope when one is configured."""
        out = {"backend": self.service.backend_kind,
               "backend_transport": self.service.backend_transport,
               "backend_address": self.service.backend_address,
               "backend_shards": self.service.backend_shards,
               "metrics": self.service.metrics()}
        if self.service.budget is not None:
            out["budget"] = self.service.budget.snapshot()
        return out

    def stats(self) -> Dict:
        """Service counters + shared-state backend kind + profiling budget
        snapshot (including shared-envelope state), wire-friendly."""
        s = self.service.stats
        out = {"backend": self.service.backend_kind,
               "backend_transport": self.service.backend_transport,
               "backend_address": self.service.backend_address,
               "backend_shards": self.service.backend_shards,
               "requests": s.requests, "batches": s.batches,
               "profile_calls": s.profile_calls,
               "cache_hits": s.cache_hits, "store_hits": s.store_hits,
               "registry_hits": s.registry_hits,
               "plan_cache_hits": s.plan_cache_hits,
               "zoo_fits": s.zoo_fits, "zoo_confident": s.zoo_confident,
               "classifier_fallbacks": s.classifier_fallbacks,
               "baseline_fallbacks": s.baseline_fallbacks,
               "profile_hit_rate": s.profile_hit_rate,
               "adaptive_plans": s.adaptive_plans,
               "early_stops": s.early_stops,
               "escalations": s.escalations,
               "points_saved": s.points_saved,
               "budget_denied": s.budget_denied,
               "runtime_fits": s.runtime_fits,
               "runtime_confident": s.runtime_confident,
               "cost_objective_requests": s.cost_objective_requests,
               "objective_fallbacks": s.objective_fallbacks}
        if self.service.budget is not None:
            out["budget"] = self.service.budget.snapshot()
        return out

    @staticmethod
    def to_wire(resp: AllocationResponse) -> Dict:
        sel = resp.selection
        return {"job": resp.job, "signature": resp.signature,
                "source": resp.source, "candidate": resp.candidate,
                "neighbor": resp.neighbor,
                "requirement_gib": resp.requirement_gib,
                "config": sel.config.name,
                "usd_per_hour": sel.config.usd_per_hour,
                "method": sel.method, "fell_back": sel.fell_back,
                "profiled": resp.profiled, "cache_hits": resp.cache_hits,
                "wall_s": resp.wall_s, "early_stop": resp.early_stop,
                "escalated": resp.escalated,
                "budget_exhausted": resp.budget_exhausted,
                "placement": resp.placement,
                "objective": resp.objective,
                "objective_fell_back": sel.objective_fell_back,
                "predicted_runtime_s": sel.predicted_runtime_s,
                "predicted_cost_usd": sel.predicted_cost_usd,
                "runtime_candidate": resp.runtime_candidate}


def _reset_slot(caches, slot: int):
    """Zero one slot's state across all (stacked) cache leaves: per-row
    `pos` goes to 0 so stale KV beyond it is never attended; recurrent
    states are cleared explicitly."""
    def one(path, leaf):
        name = ""
        for p in reversed(path):
            k = getattr(p, "key", None)
            if isinstance(k, str):
                name = k
                break
        rank = _BATCH_RANK.get(name)
        if rank is None or leaf.ndim < rank:
            return leaf
        axis = leaf.ndim - rank
        idx = (slice(None),) * axis + (slot,)
        return leaf.at[idx].set(0)

    return jax.tree_util.tree_map_with_path(one, caches)
