from repro.serve.engine import AllocationEndpoint, ServeEngine, Request
