"""Adaptive ladder scheduling — now a strategy of the unified pipeline.

The PR-2 `AdaptiveLadderScheduler` (walk the ladder smallest-first, refit
per point, stop early on a confident+stable requirement, escalate into
the widest gaps only when the zoo's candidates disagree) survives as the
`placement="ladder"` strategy of `repro.pipeline`: its decision logic
lives in `repro.pipeline.placement.LadderPlacer`, the acquisition loop in
`repro.pipeline.placement.drive_placement`, and this class is the
back-compat driver for callers that hold a raw `(size) -> (result,
fresh)` profile callable (with optional `.peek`) and want budget gating
handled for them. The information-optimal default strategy is
`repro.pipeline.placement.InfoGainPlacer` (`placement="infogain"`).

Every point is gated by an optional `ProfilingBudget`; cached points
(served via `.peek`) are always free, and exhaustion mid-schedule returns
whatever was measured (`budget_exhausted=True`) with the fit over the
partial ladder — the caller's fallback chain handles an unconfident
result exactly as it handles a noisy one.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

from repro.allocator.model_zoo import fit_zoo
from repro.core.profiler import ProfileResult
from repro.core.sampling import calibrate_anchor
from repro.pipeline.placement import (DISAGREE_RTOL, InfoGainPlacer,
                                      LadderPlacer, MAX_EXTRA_POINTS,
                                      MIN_POINTS, STABILITY_RTOL,
                                      drive_placement, make_placer)
from repro.profiling.budget import ProfilingBudget

# (size) -> (result, fresh): the caller owns caching; `fresh` says whether
# the point cost a real profile run (budget is only charged for fresh
# ones). An optional `.peek(size)` attribute on the callable returns a
# cached result without profiling — consulted before the budget gate, so
# an exhausted budget never denies points that are already known.
ProfilePointFn = Callable[[float], Tuple[ProfileResult, bool]]


@dataclass
class AdaptiveProfile:
    """Outcome of one adaptive schedule over a job signature."""
    sizes: List[float]
    mems: List[float]
    results: List[ProfileResult]
    fit: object                      # ZooFit (or custom fitter output)
    points: int                      # fresh profile runs spent
    cache_hits: int                  # points served from caches/stores
    early_stop: bool                 # stopped before the base ladder ended
    escalated: bool                  # profiled beyond the base ladder
    budget_exhausted: bool           # a point was denied by the budget
    wall_s: float
    requirement_trace: List[float] = field(default_factory=list)

    @property
    def total_points(self) -> int:
        return len(self.sizes)


class AdaptiveLadderScheduler:
    """Budget-gating driver around a `PointPlacer` (default: the PR-2
    ladder strategy; pass `placement="infogain"` or a placer instance for
    information-optimal placement)."""

    def __init__(self, fitter: Optional[Callable] = None,
                 candidates: Optional[Sequence] = None,
                 min_points: int = MIN_POINTS,
                 stability_rtol: float = STABILITY_RTOL,
                 disagree_rtol: float = DISAGREE_RTOL,
                 max_extra_points: int = MAX_EXTRA_POINTS,
                 budget: Optional[ProfilingBudget] = None,
                 placement=None):
        self.fitter = fitter
        self.candidates = candidates
        self.min_points = max(2, min_points)
        self.stability_rtol = stability_rtol
        self.disagree_rtol = disagree_rtol
        self.max_extra_points = max_extra_points
        self.budget = budget
        # a placement NAME builds its placer with THIS scheduler's knobs;
        # a placer INSTANCE is used as-is (its own knobs win)
        if placement is None or placement == "ladder":
            placement = LadderPlacer(min_points=min_points,
                                     stability_rtol=stability_rtol,
                                     disagree_rtol=disagree_rtol,
                                     max_extra_points=max_extra_points)
        elif placement == "infogain":
            placement = InfoGainPlacer(min_points=min_points,
                                       stability_rtol=stability_rtol,
                                       max_extra_points=max_extra_points)
        self.placer = make_placer(placement)

    def _fit(self, sizes: Sequence[float], mems: Sequence[float]):
        if self.fitter is not None:
            return self.fitter(sizes, mems)
        return fit_zoo(sizes, mems, self.candidates)

    def run(self, ladder: Sequence[float], full_size: float,
            profile_point: ProfilePointFn) -> AdaptiveProfile:
        t0 = time.monotonic()
        peek = getattr(profile_point, "peek", None)

        def acquire(size: float):
            """Budget-gated point: cached (peeked) points are free; only
            a genuinely fresh run keeps its reservation and charge."""
            r = peek(size) if peek is not None else None
            if r is not None:
                return r, False
            if self.budget is not None and not self.budget.try_spend():
                return None
            try:
                r, was_fresh = profile_point(size)
            except BaseException:
                if self.budget is not None:
                    self.budget.refund()    # failed run: hand the point back
                raise
            if self.budget is not None:
                if was_fresh:
                    self.budget.charge(r.wall_s)
                else:
                    self.budget.refund()    # raced a cache fill: no run
            return r, was_fresh

        out = drive_placement(self.placer, ladder, full_size, acquire,
                              self._fit)
        return AdaptiveProfile(out.sizes, out.mems, out.results, out.fit,
                               out.fresh, out.cache_hits, out.early_stop,
                               out.escalated, out.budget_exhausted,
                               time.monotonic() - t0,
                               out.requirement_trace)


def calibrated_anchor(store, signature: str,
                      run_at_size: Callable[[float], float],
                      initial: float, **calibrate_kwargs) -> float:
    """`calibrate_anchor` with persistence: a signature calibrated by any
    process (or a past run) skips the measurement loop entirely."""
    if store is not None:
        known = store.get_anchor(signature)
        if known is not None:
            return known
    anchor = calibrate_anchor(run_at_size, initial, **calibrate_kwargs)
    if store is not None:
        store.put_anchor(signature, anchor)
    return anchor
