"""Adaptive ladder scheduling: stop profiling when the model is good enough.

The paper profiles a fixed five-point ladder for every job. Ruya
(arXiv:2211.04240) shows memory-aware *iterative* optimization that stops
spending once the model is good enough; this module applies that idea to
Crispy's profiling step. `AdaptiveLadderScheduler` walks the ladder
smallest-first (cheapest run first — profiling wall time grows with sample
size), refits the model zoo after every point, and stops early once

  1. the selected candidate is `confident` (train-R² gate + the zoo's
     out-of-sample LOOCV gate), and
  2. its full-size requirement prediction has *stabilized*: the relative
     change between the last two refits is under `stability_rtol`.

A perfectly linear job therefore costs 3 points instead of 5 (LOOCV needs
3 points to produce a finite score; the stability check compares it to the
2-point fit). When the base ladder ends without a confident+stable fit the
scheduler *escalates* — but only when the candidates actually disagree
about the full-size prediction (relative spread over `disagree_rtol`);
an unconfident fit whose candidates nevertheless agree (the profile is
simply not memory-elastic at this scale) falls straight through to the
classifier/baseline chain. Extra points are midpoints of the widest
ladder gaps, so escalation densifies the measured range instead of
profiling beyond the anchor's calibrated runtime band, and is capped at
`max_extra_points`.

Every point is gated by an optional `ProfilingBudget`; exhaustion
mid-ladder returns whatever was measured (`budget_exhausted=True`) and the
fit over the partial ladder — the caller's fallback chain handles an
unconfident result exactly as it handles a noisy one.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

from repro.allocator.model_zoo import ZooFit, fit_zoo
from repro.core.profiler import ProfileResult
from repro.core.sampling import calibrate_anchor
from repro.profiling.budget import ProfilingBudget

MIN_POINTS = 3              # LOOCV needs 3; stability needs a predecessor
STABILITY_RTOL = 0.05       # requirement prediction settled within 5%
DISAGREE_RTOL = 0.25        # candidate spread that justifies extra points
MAX_EXTRA_POINTS = 2        # escalation cap beyond the base ladder

# (size) -> (result, fresh): the caller owns caching; `fresh` says whether
# the point cost a real profile run (budget is only charged for fresh
# ones). An optional `.peek(size)` attribute on the callable returns a
# cached result without profiling — consulted before the budget gate, so
# an exhausted budget never denies points that are already known.
ProfilePointFn = Callable[[float], Tuple[ProfileResult, bool]]


@dataclass
class AdaptiveProfile:
    """Outcome of one adaptive schedule over a job signature."""
    sizes: List[float]
    mems: List[float]
    results: List[ProfileResult]
    fit: object                      # ZooFit (or custom fitter output)
    points: int                      # fresh profile runs spent
    cache_hits: int                  # points served from caches/stores
    early_stop: bool                 # stopped before the base ladder ended
    escalated: bool                  # profiled beyond the base ladder
    budget_exhausted: bool           # a point was denied by the budget
    wall_s: float
    requirement_trace: List[float] = field(default_factory=list)

    @property
    def total_points(self) -> int:
        return len(self.sizes)


class AdaptiveLadderScheduler:
    def __init__(self, fitter: Optional[Callable] = None,
                 candidates: Optional[Sequence] = None,
                 min_points: int = MIN_POINTS,
                 stability_rtol: float = STABILITY_RTOL,
                 disagree_rtol: float = DISAGREE_RTOL,
                 max_extra_points: int = MAX_EXTRA_POINTS,
                 budget: Optional[ProfilingBudget] = None):
        self.fitter = fitter
        self.candidates = candidates
        self.min_points = max(2, min_points)
        self.stability_rtol = stability_rtol
        self.disagree_rtol = disagree_rtol
        self.max_extra_points = max_extra_points
        self.budget = budget

    # -- fitting ------------------------------------------------------------
    def _fit(self, sizes: Sequence[float], mems: Sequence[float]):
        if self.fitter is not None:
            return self.fitter(sizes, mems)
        return fit_zoo(sizes, mems, self.candidates)

    def _disagreement(self, sizes, mems, fit, full_size: float) -> float:
        if not isinstance(fit, ZooFit):
            # custom single-model fitter: escalate only on non-confidence
            return math.inf if not getattr(fit, "confident", False) else 0.0
        # every candidate was fitted during the last refit — read their
        # full-size predictions off the ZooFit instead of refitting
        models = fit.fits or {}
        preds = []
        for m in models.values():
            try:
                p = float(m.predict(full_size))
            except (OverflowError, ValueError):
                p = math.inf
            if math.isfinite(p):
                preds.append(p)
        if len(preds) < 2:
            return 0.0
        lo, hi = min(preds), max(preds)
        scale = max(abs(hi), abs(lo), 1e-12)
        return (hi - lo) / scale

    # -- scheduling ---------------------------------------------------------
    def run(self, ladder: Sequence[float], full_size: float,
            profile_point: ProfilePointFn) -> AdaptiveProfile:
        t0 = time.monotonic()
        base = sorted(float(s) for s in ladder)
        sizes: List[float] = []
        mems: List[float] = []
        results: List[ProfileResult] = []
        trace: List[float] = []
        fresh = hits = 0
        fit = None
        prev_pred: Optional[float] = None
        early = escalated = exhausted = False

        peek = getattr(profile_point, "peek", None)

        def take(size: float) -> bool:
            """Profile one point (budget-gated; cached points are free).
            False == budget denial."""
            nonlocal fresh, hits, exhausted
            r = peek(size) if peek is not None else None
            if r is not None:
                hits += 1
            else:
                if self.budget is not None and not self.budget.try_spend():
                    exhausted = True
                    return False
                r, was_fresh = profile_point(size)
                if was_fresh:
                    fresh += 1
                    if self.budget is not None:
                        self.budget.charge(r.wall_s)
                else:
                    hits += 1
                    if self.budget is not None:
                        self.budget.refund()    # raced: no run happened
            sizes.append(size)
            mems.append(r.job_mem_bytes)
            results.append(r)
            return True

        def refit() -> None:
            nonlocal fit, prev_pred, early
            fit = self._fit(sizes, mems)
            pred = float(fit.predict(full_size))
            trace.append(pred)
            stable = (prev_pred is not None
                      and math.isfinite(pred) and pred != 0.0
                      and abs(pred - prev_pred)
                      <= self.stability_rtol * abs(pred))
            if (len(sizes) >= self.min_points
                    and getattr(fit, "confident", False) and stable):
                early = True
            prev_pred = pred

        # phase 1: walk the base ladder smallest-first, refit per point
        for i, s in enumerate(base):
            if not take(s):
                break
            if len(sizes) >= 2:
                refit()
            if early and len(sizes) < len(base):
                break

        # phase 2: escalate only when the candidates disagree
        if (fit is not None and not early and not exhausted
                and self.max_extra_points > 0
                and not getattr(fit, "confident", False)
                and self._disagreement(sizes, mems, fit, full_size)
                > self.disagree_rtol):
            for s in _gap_midpoints(sizes, self.max_extra_points):
                escalated = True
                if not take(s):
                    break
                refit()
                if getattr(fit, "confident", False):
                    break

        if fit is None:                  # budget denied even a second point
            fit = self._fit(sizes, mems)
        early = early and len(sizes) < len(base)
        return AdaptiveProfile(sizes, mems, results, fit, fresh, hits,
                               early, escalated, exhausted,
                               time.monotonic() - t0, trace)


def _gap_midpoints(sizes: Sequence[float], n: int) -> List[float]:
    """Midpoints of the `n` widest gaps between measured sizes — escalation
    densifies the calibrated range rather than extrapolating the runtime
    band the anchor was tuned for."""
    xs = sorted(set(sizes))
    if len(xs) < 2 or n <= 0:
        return []
    gaps = sorted(((xs[i + 1] - xs[i], 0.5 * (xs[i] + xs[i + 1]))
                   for i in range(len(xs) - 1)), reverse=True)
    return [mid for _gap, mid in gaps[:n]]


def calibrated_anchor(store, signature: str,
                      run_at_size: Callable[[float], float],
                      initial: float, **calibrate_kwargs) -> float:
    """`calibrate_anchor` with persistence: a signature calibrated by any
    process (or a past run) skips the measurement loop entirely."""
    if store is not None:
        known = store.get_anchor(signature)
        if known is not None:
            return known
    anchor = calibrate_anchor(run_at_size, initial, **calibrate_kwargs)
    if store is not None:
        store.put_anchor(signature, anchor)
    return anchor
