"""ProfilingExecutor: concurrent profiling work under one thread pool.

Two axes of independence the serial PR-1 pipeline left on the table, both
driven through `map_tasks`:

  * the points of a *fixed* ladder are independent measurements — the
    pipeline's acquisition stage fans them over the pool (budget gating
    and cache hierarchy live in `repro.pipeline.acquisition.PointSource`,
    the ONE implementation); adaptive schedules stay sequential by
    construction (each point's necessity depends on the previous refit);
  * distinct job signatures are independent jobs — the AllocationService
    fans a batch's signature groups out over the same pool.

Threads, not processes: profiling callables close over simulator state /
jax compilation contexts that do not pickle, and the real work (RSS
sampling of a child workload, XLA AOT compilation) releases the GIL.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, Optional, Sequence, TypeVar

from repro.profiling.budget import ProfilingBudget

T = TypeVar("T")
R = TypeVar("R")

DEFAULT_WORKERS = 4


class ProfilingExecutor:
    def __init__(self, max_workers: int = DEFAULT_WORKERS,
                 budget: Optional[ProfilingBudget] = None):
        self.budget = budget
        self._local = threading.local()
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="profiling",
            initializer=self._mark_worker)
        self._closed = False

    def _mark_worker(self) -> None:
        self._local.in_worker = True

    @property
    def in_worker(self) -> bool:
        """True on threads owned by this pool. Nested fan-out (a signature
        group task profiling its ladder through the same pool) must run
        inline instead of enqueueing-and-blocking: with every worker parked
        in a group task, the inner tasks would never start (deadlock)."""
        return getattr(self._local, "in_worker", False)

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "ProfilingExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- concurrent tasks ---------------------------------------------------
    def map_tasks(self, fn: Callable[[T], R], items: Sequence[T]
                  ) -> List[R]:
        """Run `fn` over independent items (signature groups) on the pool,
        preserving order. Exceptions propagate per item to the caller."""
        if self.in_worker:              # nested call: run inline
            return [fn(it) for it in items]
        futs = [self._pool.submit(fn, it) for it in items]
        out: List[R] = []
        for f in futs:
            out.append(f.result())
        return out
