"""ProfilingExecutor: concurrent profiling under one global budget.

Two axes of independence the serial PR-1 pipeline left on the table:

  * the points of a *fixed* ladder are independent measurements — a
    thread pool profiles them concurrently (`profile_ladder`); adaptive
    schedules stay sequential by construction (each point's necessity
    depends on the previous refit);
  * distinct job signatures are independent jobs — the AllocationService
    fans a batch's signature groups out over the same pool (`map_tasks`).

Every fresh profile run is gated by the shared `ProfilingBudget`, so the
paper's ten-minute envelope holds across all concurrent work, not per
ladder. A denied point yields a hole, never an error: `profile_ladder`
returns the points it could afford and the caller fits over the partial
ladder (an unconfident fit walks the normal fallback chain).

Threads, not processes: profiling callables close over simulator state /
jax compilation contexts that do not pickle, and the real work (RSS
sampling of a child workload, XLA AOT compilation) releases the GIL.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, Optional, Sequence, Tuple, TypeVar

from repro.core.profiler import ProfileResult
from repro.profiling.budget import ProfilingBudget

T = TypeVar("T")
R = TypeVar("R")

DEFAULT_WORKERS = 4


class ProfilingExecutor:
    def __init__(self, max_workers: int = DEFAULT_WORKERS,
                 budget: Optional[ProfilingBudget] = None):
        self.budget = budget
        self._local = threading.local()
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="profiling",
            initializer=self._mark_worker)
        self._closed = False

    def _mark_worker(self) -> None:
        self._local.in_worker = True

    @property
    def in_worker(self) -> bool:
        """True on threads owned by this pool. Nested fan-out (a signature
        group task profiling its ladder through the same pool) must run
        inline instead of enqueueing-and-blocking: with every worker parked
        in a group task, the inner tasks would never start (deadlock)."""
        return getattr(self._local, "in_worker", False)

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "ProfilingExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- concurrent ladders -------------------------------------------------
    def profile_ladder(
            self, sizes: Sequence[float],
            profile_point: Callable[[float], Tuple[ProfileResult, bool]],
            budget: Optional[ProfilingBudget] = None,
    ) -> List[Tuple[float, Optional[ProfileResult], bool]]:
        """Profile independent ladder points concurrently. Returns
        `(size, result_or_None, fresh)` in ladder order; `None` results are
        budget denials. `profile_point(size) -> (result, fresh)` must be
        thread-safe (the service's LRU/store lookups are); an optional
        `profile_point.peek(size)` serves cached points before the budget
        gate — an exhausted budget never denies free work."""
        budget = budget if budget is not None else self.budget
        peek = getattr(profile_point, "peek", None)

        def one(size: float):
            if peek is not None:
                cached = peek(size)
                if cached is not None:
                    return size, cached, False
            if budget is not None and not budget.try_spend():
                return size, None, False
            r, fresh = profile_point(size)
            if budget is not None:
                if fresh:
                    budget.charge(r.wall_s)
                else:
                    budget.refund()
            return size, r, fresh

        if self.in_worker:              # nested call from a group task
            return [one(s) for s in sizes]
        return list(self._pool.map(one, sizes))

    # -- concurrent signatures ----------------------------------------------
    def map_tasks(self, fn: Callable[[T], R], items: Sequence[T]
                  ) -> List[R]:
        """Run `fn` over independent items (signature groups) on the pool,
        preserving order. Exceptions propagate per item to the caller."""
        if self.in_worker:              # nested call: run inline
            return [fn(it) for it in items]
        futs = [self._pool.submit(fn, it) for it in items]
        out: List[R] = []
        for f in futs:
            out.append(f.result())
        return out
