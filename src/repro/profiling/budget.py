"""ProfilingBudget: the paper's ten-minute envelope as an enforced resource.

Crispy's pitch is that profiling costs "less than ten minutes per job on a
consumer-grade laptop" (§IV-B, Table II). The follow-up allocation study
(arXiv:2306.03672) argues profiling itself must be treated as a budgeted
resource: every profile point spent on one job is wall time unavailable to
another. `ProfilingBudget` makes that envelope explicit and shared — the
adaptive scheduler, the profiling executor and the AllocationService all
check the same budget before spending a point.

Three independent limits, any of which exhausts the budget:

  wall_s      real elapsed time since the budget started;
  charge_s    *accounted* profiling seconds — the sum of ProfileResult
              wall_s values charged via `charge()`. This is the limit the
              simulator-driven tests and benchmarks exercise: simulated
              profile runs report minutes of "wall time" while taking
              microseconds, so charging the reported time reproduces the
              paper's envelope deterministically;
  max_points  total profile runs across all jobs sharing the budget.

Two sharing scopes:

  local (default)      thread-safe within one process: many executor
                       workers / schedulers spend from one budget.
  shared (backend=)    the counters live in a `repro.state.StateBackend`
                       document and every reserve/charge/refund goes
                       through the backend's atomic lease primitive
                       (`reserve`), so N service *processes* arbitrate ONE
                       envelope instead of each owning a full copy. The
                       wall clock is anchored to a shared `started_at`
                       stamped by whichever process touches the envelope
                       first. Pass the same backend + namespace/key to
                       every process (a FileBackend directory or one
                       crispy-daemon socket).
"""
from __future__ import annotations

import math
import threading
import time
from typing import Dict, Optional

from repro.telemetry import default_registry


class BudgetExhausted(RuntimeError):
    """Raised by `spend()` when the budget cannot cover another point."""


class ProfilingBudget:
    def __init__(self, wall_s: Optional[float] = None,
                 charge_s: Optional[float] = None,
                 max_points: Optional[int] = None,
                 backend=None,              # repro.state StateBackend
                 namespace: str = "budget",
                 key: str = "envelope",
                 telemetry=None):           # repro.telemetry MetricsRegistry
        self.wall_s = wall_s
        self.charge_s = charge_s
        self.max_points = max_points
        self.backend = backend
        self.namespace = namespace
        self.key = key
        self._lock = threading.Lock()
        self._t0 = time.monotonic()
        # shared-mode wall anchor: the doc's started_at is stamped once
        # at creation and never rewritten, so it is safe to cache —
        # saves one backend round trip per wall-limited try_spend
        self._started_at: Optional[float] = None
        self._points = 0
        self._charged = 0.0
        self._denials = 0
        # envelope accounting audit trail: reserved vs refunded must net
        # out to points actually profiled
        tel = telemetry if telemetry is not None else default_registry()
        self._c_reserved = tel.counter("budget.reserved_points")
        self._c_refunded = tel.counter("budget.refunded_points")
        self._c_charged = tel.counter("budget.charged_seconds")
        self._c_denials = tel.counter("budget.denials")
        if backend is not None:
            self._ensure_doc()

    # -- shared-mode plumbing ------------------------------------------------
    def _ensure_doc(self) -> Dict:
        """Create the shared envelope document once (first toucher stamps
        `started_at`); any raced creation keeps the winner's stamp."""
        value, _version = self.backend.load(self.namespace, self.key)
        if value is not None:
            return self._note_started(value)
        doc = {"started_at": time.time(), "points": 0.0, "charged": 0.0,
               "denials": 0.0}
        won, current, _ver = self.backend.cas(self.namespace, self.key,
                                              0, doc)
        return self._note_started(doc if won else (current or doc))

    def _note_started(self, doc: Dict) -> Dict:
        if self._started_at is None and doc.get("started_at") is not None:
            self._started_at = float(doc["started_at"])
        return doc

    def _doc(self) -> Dict:
        value, _version = self.backend.load(self.namespace, self.key)
        return (self._note_started(value) if value is not None
                else self._ensure_doc())

    @property
    def shared(self) -> bool:
        return self.backend is not None

    # -- accounting ---------------------------------------------------------
    @property
    def points_spent(self) -> int:
        if self.shared:
            return int(self._doc().get("points", 0))
        with self._lock:
            return self._points

    @property
    def charged_s(self) -> float:
        if self.shared:
            return float(self._doc().get("charged", 0.0))
        with self._lock:
            return self._charged

    @property
    def denials(self) -> int:
        if self.shared:
            return int(self._doc().get("denials", 0))
        with self._lock:
            return self._denials

    def elapsed_s(self) -> float:
        if self.shared:
            started = (self._started_at if self._started_at is not None
                       else self._doc().get("started_at"))
            if started is not None:
                return max(0.0, time.time() - float(started))
        return time.monotonic() - self._t0

    def remaining_points(self) -> float:
        if self.max_points is None:
            return math.inf
        return max(0, self.max_points - self.points_spent)

    def remaining_s(self) -> float:
        """Most restrictive of the two time limits (inf if neither set)."""
        rem = math.inf
        if self.wall_s is not None:
            rem = min(rem, self.wall_s - self.elapsed_s())
        if self.charge_s is not None:
            rem = min(rem, self.charge_s - self.charged_s)
        return rem

    def exhausted(self) -> bool:
        return self.remaining_points() <= 0 or self.remaining_s() <= 0

    # -- spending -----------------------------------------------------------
    def try_spend(self, points: int = 1) -> bool:
        """Reserve `points` profile runs; False (and a recorded denial) if
        any limit is already crossed. Never blocks. In shared mode the
        reservation is an atomic backend lease, so concurrent processes
        can never over-grant one envelope."""
        if self.shared:
            granted = self._try_spend_shared(points)
            (self._c_reserved.inc(points) if granted
             else self._c_denials.inc())
            return granted
        with self._lock:
            over_points = (self.max_points is not None
                           and self._points + points > self.max_points)
            over_wall = (self.wall_s is not None
                         and time.monotonic() - self._t0 >= self.wall_s)
            over_charge = (self.charge_s is not None
                           and self._charged >= self.charge_s)
            if over_points or over_wall or over_charge:
                self._denials += 1
                granted = False
            else:
                self._points += points
                granted = True
        (self._c_reserved.inc(points) if granted else self._c_denials.inc())
        return granted

    def _try_spend_shared(self, points: int) -> bool:
        if self.wall_s is not None:
            # the wall check only needs the shared started_at stamp,
            # which is immutable after doc creation — the cached copy
            # (stamped by __init__'s _ensure_doc) makes the happy path
            # a single reserve round trip even with a wall limit
            if self._started_at is not None:
                started = self._started_at
            else:
                doc = self._ensure_doc()
                started = float(doc.get("started_at", time.time()))
            if time.time() - started >= self.wall_s:
                # wall time is monotone — no atomicity needed for the check,
                # only for the denial counter
                self.backend.reserve(self.namespace, self.key,
                                     {"denials": 1}, {})
                return False
        limits: Dict[str, float] = {}
        if self.max_points is not None:
            limits["points"] = float(self.max_points)
        if self.charge_s is not None:
            limits["charged"] = float(self.charge_s)
        granted, _doc = self.backend.reserve(
            self.namespace, self.key, {"points": float(points)}, limits)
        if not granted:
            self.backend.reserve(self.namespace, self.key,
                                 {"denials": 1}, {})
        return granted

    def spend(self, points: int = 1) -> None:
        if not self.try_spend(points):
            raise BudgetExhausted(
                f"profiling budget exhausted after {self.points_spent} "
                f"points / {self.charged_s:.1f}s charged / "
                f"{self.elapsed_s():.1f}s elapsed")

    def refund(self, points: int = 1) -> None:
        """Hand back a reservation that turned out not to need a profile
        run (the point was served from a cache/store)."""
        if self.shared:
            # clamped decrement: a double refund must not go negative, so
            # this is a CAS loop rather than a plain negative reserve
            while True:
                value, version = self.backend.load(self.namespace, self.key)
                doc = dict(value or {})
                doc["points"] = max(0.0,
                                    float(doc.get("points", 0)) - points)
                won, _cur, _ver = self.backend.cas(self.namespace, self.key,
                                                   version, doc)
                if won:
                    self._c_refunded.inc(points)
                    return
        with self._lock:
            self._points = max(0, self._points - points)
        self._c_refunded.inc(points)

    def charge(self, seconds: float) -> None:
        """Account a completed profile run's (reported) wall time."""
        self._c_charged.inc(max(0.0, float(seconds)))
        if self.shared:
            self.backend.reserve(self.namespace, self.key,
                                 {"charged": max(0.0, float(seconds))}, {})
            return
        with self._lock:
            self._charged += max(0.0, float(seconds))

    # -- reporting ----------------------------------------------------------
    def snapshot(self) -> Dict:
        """Wire-friendly state for endpoint/benchmark reporting."""
        base = {"wall_s": self.wall_s, "charge_s": self.charge_s,
                "max_points": self.max_points,
                "shared": self.shared,
                "backend": getattr(self.backend, "kind", None)}
        if self.shared:
            doc = self._doc()
            base.update({"points_spent": int(doc.get("points", 0)),
                         "charged_s": float(doc.get("charged", 0.0)),
                         "elapsed_s": self.elapsed_s(),
                         "denials": int(doc.get("denials", 0))})
            return base
        with self._lock:
            base.update({"points_spent": self._points,
                         "charged_s": self._charged,
                         "elapsed_s": time.monotonic() - self._t0,
                         "denials": self._denials})
            return base

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        s = self.snapshot()
        return (f"ProfilingBudget(points {s['points_spent']}"
                f"/{s['max_points']}, charged {s['charged_s']:.1f}"
                f"/{s['charge_s']}s, elapsed {s['elapsed_s']:.1f}"
                f"/{s['wall_s']}s, shared={s['shared']})")
