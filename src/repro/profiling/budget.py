"""ProfilingBudget: the paper's ten-minute envelope as an enforced resource.

Crispy's pitch is that profiling costs "less than ten minutes per job on a
consumer-grade laptop" (§IV-B, Table II). The follow-up allocation study
(arXiv:2306.03672) argues profiling itself must be treated as a budgeted
resource: every profile point spent on one job is wall time unavailable to
another. `ProfilingBudget` makes that envelope explicit and shared — the
adaptive scheduler, the profiling executor and the AllocationService all
check the same budget before spending a point.

Three independent limits, any of which exhausts the budget:

  wall_s      real elapsed time since the budget started (monotonic clock);
  charge_s    *accounted* profiling seconds — the sum of ProfileResult
              wall_s values charged via `charge()`. This is the limit the
              simulator-driven tests and benchmarks exercise: simulated
              profile runs report minutes of "wall time" while taking
              microseconds, so charging the reported time reproduces the
              paper's envelope deterministically;
  max_points  total profile runs across all jobs sharing the budget.

Thread-safe: many executor workers / schedulers spend from one budget.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Dict, Optional


class BudgetExhausted(RuntimeError):
    """Raised by `spend()` when the budget cannot cover another point."""


class ProfilingBudget:
    def __init__(self, wall_s: Optional[float] = None,
                 charge_s: Optional[float] = None,
                 max_points: Optional[int] = None):
        self.wall_s = wall_s
        self.charge_s = charge_s
        self.max_points = max_points
        self._lock = threading.Lock()
        self._t0 = time.monotonic()
        self._points = 0
        self._charged = 0.0
        self._denials = 0

    # -- accounting ---------------------------------------------------------
    @property
    def points_spent(self) -> int:
        with self._lock:
            return self._points

    @property
    def charged_s(self) -> float:
        with self._lock:
            return self._charged

    @property
    def denials(self) -> int:
        with self._lock:
            return self._denials

    def elapsed_s(self) -> float:
        return time.monotonic() - self._t0

    def remaining_points(self) -> float:
        if self.max_points is None:
            return math.inf
        with self._lock:
            return max(0, self.max_points - self._points)

    def remaining_s(self) -> float:
        """Most restrictive of the two time limits (inf if neither set)."""
        rem = math.inf
        if self.wall_s is not None:
            rem = min(rem, self.wall_s - self.elapsed_s())
        if self.charge_s is not None:
            with self._lock:
                rem = min(rem, self.charge_s - self._charged)
        return rem

    def exhausted(self) -> bool:
        return self.remaining_points() <= 0 or self.remaining_s() <= 0

    # -- spending -----------------------------------------------------------
    def try_spend(self, points: int = 1) -> bool:
        """Reserve `points` profile runs; False (and a recorded denial) if
        any limit is already crossed. Never blocks."""
        with self._lock:
            over_points = (self.max_points is not None
                           and self._points + points > self.max_points)
            over_wall = (self.wall_s is not None
                         and self.elapsed_s() >= self.wall_s)
            over_charge = (self.charge_s is not None
                           and self._charged >= self.charge_s)
            if over_points or over_wall or over_charge:
                self._denials += 1
                return False
            self._points += points
            return True

    def spend(self, points: int = 1) -> None:
        if not self.try_spend(points):
            raise BudgetExhausted(
                f"profiling budget exhausted after {self._points} points / "
                f"{self._charged:.1f}s charged / {self.elapsed_s():.1f}s "
                f"elapsed")

    def refund(self, points: int = 1) -> None:
        """Hand back a reservation that turned out not to need a profile
        run (the point was served from a cache/store)."""
        with self._lock:
            self._points = max(0, self._points - points)

    def charge(self, seconds: float) -> None:
        """Account a completed profile run's (reported) wall time."""
        with self._lock:
            self._charged += max(0.0, float(seconds))

    # -- reporting ----------------------------------------------------------
    def snapshot(self) -> Dict:
        """Wire-friendly state for endpoint/benchmark reporting."""
        with self._lock:
            return {"wall_s": self.wall_s, "charge_s": self.charge_s,
                    "max_points": self.max_points,
                    "points_spent": self._points,
                    "charged_s": self._charged,
                    "elapsed_s": self.elapsed_s(),
                    "denials": self._denials}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        s = self.snapshot()
        return (f"ProfilingBudget(points {s['points_spent']}"
                f"/{s['max_points']}, charged {s['charged_s']:.1f}"
                f"/{s['charge_s']}s, elapsed {s['elapsed_s']:.1f}"
                f"/{s['wall_s']}s)")
