"""Persistent, file-locked profile & anchor store shared across processes.

The PR-1 caches (ProfileResult LRU, ModelRegistry JSON) are per-process:
two AllocationService processes pointed at the same jobs re-profile every
ladder and clobber each other's registry file on flush (last-writer-wins
drops the other's models). This module makes the profiling state a real
multi-process resource:

  FileLock             fcntl advisory lock (LOCK_EX/LOCK_SH) with a bounded
                       busy-wait, usable as a context manager. Degrades to
                       a process-local lock where fcntl is unavailable.

  ProfileStore         append-only JSONL of profile points and calibrated
                       anchors. Appends happen under an exclusive lock as a
                       single O_APPEND write so concurrent writers never
                       interleave partial lines; readers pick up other
                       processes' rows incrementally via `refresh()`.
                       Repeat signatures skip `calibrate_anchor` entirely:
                       the calibrated anchor is persisted per signature.

  LockedModelRegistry  a ModelRegistry whose saves are read-merge-write
                       under the file lock: concurrent services flush
                       without losing each other's records (newest
                       `created_at` wins per signature), and each flush
                       absorbs the other process's models into memory.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, Optional, Tuple

try:
    import fcntl
    HAS_FCNTL = True
except ImportError:                      # non-POSIX: degrade gracefully
    fcntl = None
    HAS_FCNTL = False

from repro.allocator.registry import ModelRecord, ModelRegistry
from repro.core.profiler import ProfileResult

STORE_VERSION = 1


class FileLock:
    """fcntl advisory lock on `path` (created on demand). Reentrant within
    a process via a thread lock is NOT provided — hold it briefly."""

    def __init__(self, path: str, shared: bool = False,
                 timeout_s: float = 10.0, poll_s: float = 0.005):
        self.path = path
        self.shared = shared
        self.timeout_s = timeout_s
        self.poll_s = poll_s
        self._fd: Optional[int] = None

    def acquire(self) -> "FileLock":
        self._fd = os.open(self.path, os.O_RDWR | os.O_CREAT, 0o644)
        if not HAS_FCNTL:
            return self
        flag = fcntl.LOCK_SH if self.shared else fcntl.LOCK_EX
        deadline = time.monotonic() + self.timeout_s
        while True:
            try:
                fcntl.flock(self._fd, flag | fcntl.LOCK_NB)
                return self
            except (BlockingIOError, OSError):
                if time.monotonic() >= deadline:
                    os.close(self._fd)
                    self._fd = None
                    raise TimeoutError(
                        f"could not lock {self.path} within "
                        f"{self.timeout_s}s")
                time.sleep(self.poll_s)

    def release(self) -> None:
        if self._fd is None:
            return
        try:
            if HAS_FCNTL:
                fcntl.flock(self._fd, fcntl.LOCK_UN)
        finally:
            os.close(self._fd)
            self._fd = None

    def __enter__(self) -> "FileLock":
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()


def _lock_path(path: str) -> str:
    return path + ".lock"


class ProfileStore:
    """JSONL store of (signature, size) -> ProfileResult rows plus
    per-signature calibrated anchors.

    One row per line:
      {"kind": "profile", "sig": ..., "size": ..., "result": {...}}
      {"kind": "anchor",  "sig": ..., "anchor": ...}

    Later rows win (an anchor recalibration supersedes the old one), so the
    file needs no compaction for correctness. In-memory index is
    thread-safe; cross-process freshness is pull-based via `refresh()` —
    the AllocationService refreshes once per batch, so a point profiled by
    a sibling process is reused a batch later rather than re-measured.
    """

    def __init__(self, path: str, lock_timeout_s: float = 10.0):
        self.path = path
        self.lock_timeout_s = lock_timeout_s
        self._lock = threading.Lock()
        self._points: Dict[Tuple[str, float], ProfileResult] = {}
        self._anchors: Dict[str, float] = {}
        self._offset = 0                # bytes of the file already indexed
        self.refresh()

    # -- introspection ------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._points)

    def anchors(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._anchors)

    # -- reads --------------------------------------------------------------
    def get(self, signature: str, size: float) -> Optional[ProfileResult]:
        with self._lock:
            return self._points.get((signature, float(size)))

    def get_anchor(self, signature: str) -> Optional[float]:
        with self._lock:
            return self._anchors.get(signature)

    def refresh(self) -> int:
        """Index rows appended (by any process) since the last read.
        Returns the number of new rows."""
        if not os.path.exists(self.path):
            return 0
        with FileLock(_lock_path(self.path), shared=True,
                      timeout_s=self.lock_timeout_s):
            with open(self.path, "rb") as f:
                f.seek(self._offset)
                data = f.read()
        if not data:
            return 0
        new = 0
        with self._lock:
            # only consume complete lines; a torn tail (should not happen
            # under the lock, but be paranoid) is re-read next refresh
            end = data.rfind(b"\n") + 1
            for line in data[:end].splitlines():
                line = line.strip()
                if not line:
                    continue
                try:
                    row = json.loads(line)
                except ValueError:
                    continue            # skip a corrupt row, keep the rest
                self._apply_locked(row)
                new += 1
            self._offset += end
        return new

    def _apply_locked(self, row: Dict) -> None:
        kind = row.get("kind")
        if kind == "profile":
            key = (row["sig"], float(row["size"]))
            self._points[key] = ProfileResult.from_dict(row["result"])
        elif kind == "anchor":
            self._anchors[row["sig"]] = float(row["anchor"])

    # -- writes -------------------------------------------------------------
    def put(self, signature: str, size: float,
            result: ProfileResult) -> None:
        self._append({"kind": "profile", "sig": signature,
                      "size": float(size), "result": result.to_dict()})
        with self._lock:
            self._points[(signature, float(size))] = result

    def put_anchor(self, signature: str, anchor: float) -> None:
        self._append({"kind": "anchor", "sig": signature,
                      "anchor": float(anchor)})
        with self._lock:
            self._anchors[signature] = float(anchor)

    def _append(self, row: Dict) -> None:
        line = (json.dumps(row) + "\n").encode()
        with FileLock(_lock_path(self.path),
                      timeout_s=self.lock_timeout_s):
            fd = os.open(self.path, os.O_WRONLY | os.O_APPEND | os.O_CREAT,
                         0o644)
            try:
                os.write(fd, line)
            finally:
                os.close(fd)


class LockedModelRegistry(ModelRegistry):
    """ModelRegistry safe to share across processes.

    Saves are read-merge-write under an exclusive file lock: the on-disk
    records are reloaded, merged with ours (newest `created_at` wins per
    signature — concurrent flushes lose nothing), written atomically, and
    the merged view is absorbed into memory so each flush also *imports*
    sibling processes' confident models. `refresh()` imports without
    writing."""

    def __init__(self, path: str, autosave: bool = True,
                 lock_timeout_s: float = 10.0):
        self.lock_timeout_s = lock_timeout_s
        super().__init__(path, autosave=autosave)

    def _merge_locked(self, disk_records: Dict[str, ModelRecord]) -> None:
        for sig, rec in disk_records.items():
            mine = self._records.get(sig)
            if mine is None or rec.created_at > mine.created_at:
                self._records[sig] = rec

    def _read_disk(self) -> Dict[str, ModelRecord]:
        if self.path is None or not os.path.exists(self.path):
            return {}
        try:
            with open(self.path) as f:
                payload = json.load(f)
        except ValueError:              # half-written legacy file
            return {}
        return {sig: ModelRecord.from_dict(sig, d)
                for sig, d in payload.get("records", {}).items()}

    def _save_locked(self, path: str) -> None:
        with FileLock(_lock_path(path), timeout_s=self.lock_timeout_s):
            self._merge_locked(self._read_disk())
            super()._save_locked(path)

    def load(self, path: Optional[str] = None) -> int:
        path = path or self.path
        if path is None:
            raise ValueError("ModelRegistry has no path to load from")
        with FileLock(_lock_path(path), shared=True,
                      timeout_s=self.lock_timeout_s):
            return super().load(path)

    def refresh(self) -> int:
        """Merge sibling processes' on-disk records into memory (no write).
        Returns the number of records imported or updated."""
        if self.path is None or not os.path.exists(self.path):
            return 0
        with FileLock(_lock_path(self.path), shared=True,
                      timeout_s=self.lock_timeout_s):
            disk = self._read_disk()
        with self._lock:
            before = {sig: rec.created_at
                      for sig, rec in self._records.items()}
            self._merge_locked(disk)
            return sum(1 for sig, rec in self._records.items()
                       if before.get(sig) != rec.created_at)
