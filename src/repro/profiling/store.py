"""Profile store and model registry as thin views over a StateBackend.

PR 2 gave ProfileStore and LockedModelRegistry their own fcntl JSONL
machinery; this module now contains none of it. Both classes are views
over the `repro.state` StateBackend protocol, so the same code shares
state in-process (InMemoryBackend), across processes on one host
(FileBackend), or through the single-writer crispy-daemon
(DaemonBackend):

  ProfileStore           (signature, size) -> ProfileResult rows plus
                         per-signature calibrated anchors, kept in a
                         backend append-only log. Later rows win, so
                         readers never NEED compaction — but re-profiled
                         points and recalibrated anchors shadow earlier
                         rows forever, so `compact()` folds the log into
                         snapshot-plus-tail form (one row per identity,
                         tombstoned points dropped) and `evict()`
                         tombstones a point across every process sharing
                         the backend. Cross-process freshness is
                         pull-based via `refresh()` (the
                         AllocationService refreshes once per batch).
                         `ProfileStore(path)` keeps the PR-2 file layout:
                         a FileBackend JSONL at exactly that path.

  BackendModelRegistry   a ModelRegistry persisted as one versioned
                         backend document. Saves are read-merge-CAS:
                         on-disk records are merged with ours (newest
                         `created_at` wins per signature) and written only
                         if nobody raced us — a lost race re-merges and
                         retries, so concurrent flushes lose nothing and
                         each flush absorbs sibling processes' models.

  LockedModelRegistry    back-compat constructor: BackendModelRegistry
                         over a FileBackend rooted at the path's
                         directory. (The on-disk JSON is now the backend
                         document envelope; pre-StateBackend registry
                         files are treated as empty and rewritten on the
                         first flush.)

`FileLock` and `HAS_FCNTL` are re-exported from `repro.state` for
backward compatibility — no fcntl use remains outside `repro/state/`.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.allocator.registry import (ModelRecord, ModelRegistry,
                                      REGISTRY_VERSION)
from repro.core.profiler import ProfileResult
from repro.state import FileBackend, StateBackend
from repro.state.compaction import prune_registry_doc
from repro.state.file_backend import FileLock, HAS_FCNTL  # noqa: F401 (compat)

STORE_VERSION = 2


def _split_path(path: str, ext: str) -> Tuple[str, str]:
    """(backend root, namespace) for a legacy file path: the namespace is
    the basename minus `ext`, so FileBackend reproduces the same file."""
    root = os.path.dirname(path) or "."
    base = os.path.basename(path)
    if base.endswith(ext):
        base = base[:-len(ext)]
    else:
        base = os.path.splitext(base)[0] or base
    return root, base


class ProfileStore:
    """Backend-log store of profile points and calibrated anchors.

    One record per row:
      {"kind": "profile", "sig": ..., "size": ..., "result": {...}}
      {"kind": "anchor",  "sig": ..., "anchor": ...}

    In-memory index is thread-safe; `refresh()` pulls rows appended by
    any sibling process/client since the last read.
    """

    def __init__(self, path: Optional[str] = None,
                 lock_timeout_s: float = 10.0,
                 backend: Optional[StateBackend] = None,
                 namespace: Optional[str] = None):
        if backend is None:
            if path is None:
                raise ValueError("ProfileStore needs a path or a backend")
            root, stem = _split_path(path, ".jsonl")
            backend = FileBackend(root, lock_timeout_s=lock_timeout_s)
            namespace = namespace or stem
        self.backend = backend
        self.namespace = namespace or "profiles"
        self.path = path
        self._lock = threading.Lock()
        self._points: Dict[Tuple[str, float], ProfileResult] = {}
        self._anchors: Dict[str, float] = {}
        self._cursor = 0
        self.refresh()

    # -- introspection ------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._points)

    def anchors(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._anchors)

    # -- reads --------------------------------------------------------------
    def get(self, signature: str, size: float) -> Optional[ProfileResult]:
        with self._lock:
            return self._points.get((signature, float(size)))

    def get_anchor(self, signature: str) -> Optional[float]:
        with self._lock:
            return self._anchors.get(signature)

    def refresh(self) -> int:
        """Index rows appended (by any process) since the last read.
        Returns the number of new rows."""
        rows, cursor = self.backend.read(self.namespace, self._cursor)
        with self._lock:
            for row in rows:
                self._apply_locked(row)
            # rows are idempotent (later wins), so a concurrent refresh
            # racing us to a shorter cursor only re-applies, never loses
            self._cursor = max(self._cursor, cursor)
        return len(rows)

    def _apply_locked(self, row: Dict) -> None:
        kind = row.get("kind")
        if row.get("tombstone"):
            if kind == "profile":
                self._points.pop((row["sig"], float(row["size"])), None)
            elif kind == "anchor":
                self._anchors.pop(row["sig"], None)
            return
        if kind == "profile":
            key = (row["sig"], float(row["size"]))
            self._points[key] = ProfileResult.from_dict(row["result"])
        elif kind == "anchor":
            self._anchors[row["sig"]] = float(row["anchor"])

    # -- writes -------------------------------------------------------------
    def put(self, signature: str, size: float,
            result: ProfileResult) -> None:
        self.backend.append(self.namespace,
                            {"kind": "profile", "sig": signature,
                             "size": float(size),
                             "result": result.to_dict(),
                             "ts": time.time()})
        with self._lock:
            self._points[(signature, float(size))] = result

    def put_anchor(self, signature: str, anchor: float) -> None:
        self.backend.append(self.namespace,
                            {"kind": "anchor", "sig": signature,
                             "anchor": float(anchor), "ts": time.time()})
        with self._lock:
            self._anchors[signature] = float(anchor)

    def evict(self, signature: str, size: float) -> None:
        """Tombstone one profile point: siblings drop it on their next
        `refresh()`, and the next `compact()` erases it (and the
        tombstone) from the log for good."""
        self.backend.append(self.namespace,
                            {"kind": "profile", "sig": signature,
                             "size": float(size), "tombstone": True,
                             "ts": time.time()})
        with self._lock:
            self._points.pop((signature, float(size)), None)

    # -- maintenance --------------------------------------------------------
    KEY_FIELDS = ("kind", "sig", "size")

    def compact(self, max_age_s: Optional[float] = None) -> Dict:
        """Fold the backing log: one row per (kind, sig, size) identity —
        the LAST appended, which for an evicted point is its tombstone
        (kept so siblings with stale cursors still observe the
        deletion). Given `max_age_s`, surviving rows older than that —
        tombstones included — are evicted. Point counts are unchanged
        unless rows are tombstoned or over-age; cursors held by sibling
        processes stay valid. Returns the backend's
        {"before", "after", "dropped"} stats."""
        return self.backend.compact(self.namespace,
                                    key_fields=self.KEY_FIELDS,
                                    max_age_s=max_age_s)


class BackendModelRegistry(ModelRegistry):
    """ModelRegistry persisted as one versioned StateBackend document.

    Flushes are read-merge-CAS (see module docstring): safe for any
    number of concurrent services sharing one backend, on any transport.
    `refresh()` imports sibling records without writing."""

    DOC_KEY = "records"

    def __init__(self, backend: StateBackend, namespace: str = "registry",
                 autosave: bool = True, path: Optional[str] = None):
        self.backend = backend
        self.namespace = namespace
        # evictions, by time. They are PERSISTED in the backend document
        # ("tombstones"): without them the merge-before-CAS in
        # _save_locked — ours or any sibling process's — would re-import
        # the evicted record straight from the backend document and
        # resurrect it. A genuinely newer record still supersedes its
        # tombstone on both sides of the merge.
        self._tombstones: Dict[str, float] = {}
        super().__init__(path=None, autosave=autosave)
        # the base class persists iff `path is not None`; backend-only
        # registries get a descriptive sentinel so autosave still fires
        self.path = path if path is not None \
            else f"<{backend.kind}:{namespace}>"
        self.refresh()

    # how long a persisted eviction tombstone lives (see
    # repro.state.compaction.DEFAULT_TOMBSTONE_TTL_S)
    TOMBSTONE_TTL_S = 24 * 3600.0

    # -- codec --------------------------------------------------------------
    def _encode_locked(self) -> Dict:
        # a tombstone superseded by a newer record of the same signature —
        # or older than the TTL (every live sibling has long since merged
        # the eviction) — has done its job; don't persist it forever
        horizon = time.time() - self.TOMBSTONE_TTL_S
        tombstones = {
            sig: ts for sig, ts in self._tombstones.items()
            if ts >= horizon
            and (sig not in self._records
                 or self._records[sig].created_at <= ts)}
        return {"version": REGISTRY_VERSION,
                "records": {sig: rec.to_dict()
                            for sig, rec in self._records.items()},
                "tombstones": tombstones}

    @staticmethod
    def _decode(value: Optional[Dict]) -> Dict[str, ModelRecord]:
        if not value:
            return {}
        return {sig: ModelRecord.from_dict(sig, d)
                for sig, d in value.get("records", {}).items()}

    @staticmethod
    def _decode_tombstones(value: Optional[Dict]) -> Dict[str, float]:
        if not value:
            return {}
        return {sig: float(ts)
                for sig, ts in (value.get("tombstones") or {}).items()}

    def _merge_locked(self, disk_records: Dict[str, ModelRecord],
                      disk_tombstones: Optional[Dict[str, float]] = None
                      ) -> None:
        # sibling evictions first: they delete any copy of ours that is
        # not strictly newer than the eviction
        for sig, ts in (disk_tombstones or {}).items():
            mine = self._records.get(sig)
            if mine is not None and mine.created_at > ts:
                continue                # our record outlives the eviction
            self._records.pop(sig, None)
            self._tombstones[sig] = max(ts, self._tombstones.get(sig, ts))
        for sig, rec in disk_records.items():
            evicted_at = self._tombstones.get(sig)
            if evicted_at is not None:
                if rec.created_at <= evicted_at:
                    continue            # the copy this registry evicted
                del self._tombstones[sig]   # newer model supersedes it
            mine = self._records.get(sig)
            if mine is None or rec.created_at > mine.created_at:
                self._records[sig] = rec

    def put(self, signature: str, model, candidate: Optional[str] = None,
            sizes=(), mems=(), defer_save: bool = False):
        with self._lock:
            # re-registering a signature revokes our own eviction of it
            self._tombstones.pop(signature, None)
            return super().put(signature, model, candidate=candidate,
                               sizes=sizes, mems=mems,
                               defer_save=defer_save)

    def evict(self, signature: str) -> bool:
        with self._lock:
            gone = self._records.pop(signature, None) is not None
            if gone:
                self._tombstones[signature] = time.time()
                self._dirty = True
                if self.autosave and self.path is not None:
                    self._save_locked(self.path)
            return gone

    def prune(self, max_records: Optional[int] = None,
              max_age_s: Optional[float] = None) -> List[str]:
        """Evict records older than `max_age_s` and/or the oldest records
        beyond `max_records`, tombstoning each (shared across processes)
        with ONE flush. Same policy, same code as the daemon-side
        eviction: both delegate to `prune_registry_doc`. Returns the
        evicted signatures."""
        with self._lock:
            new_value, evicted = prune_registry_doc(
                self._encode_locked(), max_records=max_records,
                max_age_s=max_age_s, tombstone_ttl_s=self.TOMBSTONE_TTL_S)
            if evicted:
                self._records = self._decode(new_value)
                self._tombstones = self._decode_tombstones(new_value)
                self._dirty = True
                if self.autosave and self.path is not None:
                    self._save_locked(self.path)
            return evicted

    # -- persistence (overrides the file I/O of the base class) -------------
    def _save_locked(self, path: Optional[str] = None) -> None:
        while True:
            value, version = self.backend.load(self.namespace, self.DOC_KEY)
            self._merge_locked(self._decode(value),
                               self._decode_tombstones(value))
            won, _cur, _ver = self.backend.cas(
                self.namespace, self.DOC_KEY, version, self._encode_locked())
            if won:
                break
            # lost the flush race: merge the winner's records and retry
        self._dirty = False

    def load(self, path: Optional[str] = None) -> int:
        value, _version = self.backend.load(self.namespace, self.DOC_KEY)
        records = self._decode(value)
        with self._lock:
            # explicit reload adopts the backend wholesale, evictions
            # included
            self._records = records
            self._tombstones = self._decode_tombstones(value)
            self._dirty = False
            return len(self._records)

    def refresh(self) -> int:
        """Merge sibling processes' records AND evictions into memory (no
        write). Returns the number of records imported or updated."""
        value, _version = self.backend.load(self.namespace, self.DOC_KEY)
        with self._lock:
            before = {sig: rec.created_at
                      for sig, rec in self._records.items()}
            self._merge_locked(self._decode(value),
                               self._decode_tombstones(value))
            return sum(1 for sig, rec in self._records.items()
                       if before.get(sig) != rec.created_at)


class LockedModelRegistry(BackendModelRegistry):
    """Back-compat file-backed registry: a BackendModelRegistry over a
    FileBackend rooted next to `path` (same concurrency guarantees as any
    backend registry — concurrent flushes lose no records)."""

    def __init__(self, path: str, autosave: bool = True,
                 lock_timeout_s: float = 10.0):
        self.lock_timeout_s = lock_timeout_s
        root, stem = _split_path(path, ".json")
        super().__init__(FileBackend(root, lock_timeout_s=lock_timeout_s),
                         namespace=stem, autosave=autosave, path=path)
