"""Profile store and model registry as thin views over a StateBackend.

PR 2 gave ProfileStore and LockedModelRegistry their own fcntl JSONL
machinery; this module now contains none of it. Both classes are views
over the `repro.state` StateBackend protocol, so the same code shares
state in-process (InMemoryBackend), across processes on one host
(FileBackend), or through the single-writer crispy-daemon
(DaemonBackend):

  ProfileStore           (signature, size) -> ProfileResult rows plus
                         per-signature calibrated anchors, kept in a
                         backend append-only log. Later rows win, so
                         readers never NEED compaction — but re-profiled
                         points and recalibrated anchors shadow earlier
                         rows forever, so `compact()` folds the log into
                         snapshot-plus-tail form (one row per identity,
                         tombstoned points dropped) and `evict()`
                         tombstones a point across every process sharing
                         the backend. Cross-process freshness is
                         pull-based via `refresh()` (the
                         AllocationService refreshes once per batch).
                         `ProfileStore(path)` keeps the PR-2 file layout:
                         a FileBackend JSONL at exactly that path.

  BackendModelRegistry   a ModelRegistry persisted as one versioned
                         backend document. Saves are read-merge-CAS:
                         on-disk records are merged with ours (newest
                         `created_at` wins per signature) and written only
                         if nobody raced us — a lost race re-merges and
                         retries, so concurrent flushes lose nothing and
                         each flush absorbs sibling processes' models.

  LockedModelRegistry    back-compat constructor: BackendModelRegistry
                         over a FileBackend rooted at the path's
                         directory. (The on-disk JSON is now the backend
                         document envelope; pre-StateBackend registry
                         files are treated as empty and rewritten on the
                         first flush.)

Wire coalescing (PR 8): on a DaemonBackend every view method above is a
round trip, and the AllocationService's per-batch pattern — store
tail-read + registry doc load + N point/anchor appends + registry CAS —
paid for each one. Three hooks collapse that: `refresh_views(store,
registry)` fetches both views' refreshes in ONE `backend.batch()`
frame; `ProfileStore(write_behind=True)` buffers point/anchor/evict
rows (in-memory index updated immediately, a pending-identity set keeps
refresh from shadowing them) until `flush_writes()` sends them as one
batched append frame; and `BackendModelRegistry` flushes CAS-first
against its cached `_doc_version` — an unchanged version proves the
document unchanged since our last merge, so the uncontended flush is
one round trip, and a lost race merges the returned winner and retries
exactly as before. `sync_views(store, registry)` composes all three:
pending writes ride at the front of the refresh frame (batch frames
read their own writes), so a loaded service's steady state is ONE wire
frame per batch — batch N's writes carried by batch N+1's sync.

`FileLock` and `HAS_FCNTL` are re-exported from `repro.state` for
backward compatibility — no fcntl use remains outside `repro/state/`.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.allocator.registry import (ModelRecord, ModelRegistry,
                                      REGISTRY_VERSION)
from repro.core.profiler import ProfileResult
from repro.state import FileBackend, StateBackend
from repro.state.compaction import prune_registry_doc
from repro.state.file_backend import FileLock, HAS_FCNTL  # noqa: F401 (compat)

STORE_VERSION = 2


def _split_path(path: str, ext: str) -> Tuple[str, str]:
    """(backend root, namespace) for a legacy file path: the namespace is
    the basename minus `ext`, so FileBackend reproduces the same file."""
    root = os.path.dirname(path) or "."
    base = os.path.basename(path)
    if base.endswith(ext):
        base = base[:-len(ext)]
    else:
        base = os.path.splitext(base)[0] or base
    return root, base


class ProfileStore:
    """Backend-log store of profile points and calibrated anchors.

    One record per row:
      {"kind": "profile", "sig": ..., "size": ..., "result": {...}}
      {"kind": "anchor",  "sig": ..., "anchor": ...}

    In-memory index is thread-safe; `refresh()` pulls rows appended by
    any sibling process/client since the last read.
    """

    def __init__(self, path: Optional[str] = None,
                 lock_timeout_s: float = 10.0,
                 backend: Optional[StateBackend] = None,
                 namespace: Optional[str] = None,
                 write_behind: bool = False):
        if backend is None:
            if path is None:
                raise ValueError("ProfileStore needs a path or a backend")
            root, stem = _split_path(path, ".jsonl")
            backend = FileBackend(root, lock_timeout_s=lock_timeout_s)
            namespace = namespace or stem
        self.backend = backend
        self.namespace = namespace or "profiles"
        self.path = path
        self._lock = threading.Lock()
        self._points: Dict[Tuple[str, float], ProfileResult] = {}
        self._anchors: Dict[str, float] = {}
        self._cursor = 0
        # write-behind mode (see class docstring): writes update the
        # in-memory index immediately but buffer their backend rows
        # until flush_writes() sends them as ONE batched append frame.
        # _pending_ids guards refresh(): a backend row whose identity
        # has a newer buffered write must not shadow it.
        self.write_behind = bool(write_behind)
        self._pending: List[Dict] = []
        self._pending_ids: set = set()
        self.refresh()

    # -- introspection ------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._points)

    def anchors(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._anchors)

    # -- reads --------------------------------------------------------------
    def get(self, signature: str, size: float) -> Optional[ProfileResult]:
        with self._lock:
            return self._points.get((signature, float(size)))

    def get_anchor(self, signature: str) -> Optional[float]:
        with self._lock:
            return self._anchors.get(signature)

    def refresh(self) -> int:
        """Index rows appended (by any process) since the last read.
        Returns the number of new rows."""
        rows, cursor = self.backend.read(self.namespace, self._cursor)
        return self._apply_rows(rows, cursor)

    def refresh_op(self) -> Dict:
        """The wire-shaped read op `refresh()` would issue — for
        coalescing several views' refreshes into one backend.batch()
        frame (see `refresh_views`)."""
        return {"op": "read", "ns": self.namespace, "cursor": self._cursor}

    def apply_refresh(self, resp: Dict) -> int:
        """Apply one batch-result slot produced by `refresh_op()`. A
        failed slot ({"ok": false}) leaves the view stale — the cursor
        does not move, so the next refresh re-reads the same tail."""
        if not resp or not resp.get("ok"):
            return 0
        return self._apply_rows(resp.get("rows") or [],
                                int(resp.get("cursor", self._cursor)))

    def _apply_rows(self, rows: List[Dict], cursor: int) -> int:
        with self._lock:
            for row in rows:
                self._apply_locked(row)
            # rows are idempotent (later wins), so a concurrent refresh
            # racing us to a shorter cursor only re-applies, never loses
            self._cursor = max(self._cursor, cursor)
        return len(rows)

    @staticmethod
    def _row_identity(row: Dict) -> Tuple:
        if row.get("kind") == "anchor":
            return ("anchor", row.get("sig"))
        return ("profile", row.get("sig"), float(row.get("size", 0.0)))

    def _apply_locked(self, row: Dict) -> None:
        kind = row.get("kind")
        if self._pending_ids and self._row_identity(row) in self._pending_ids:
            # a buffered write-behind row for this identity is newer
            # than anything the backend can show us yet — don't let a
            # sibling's older row shadow it
            return
        if row.get("tombstone"):
            if kind == "profile":
                self._points.pop((row["sig"], float(row["size"])), None)
            elif kind == "anchor":
                self._anchors.pop(row["sig"], None)
            return
        if kind == "profile":
            key = (row["sig"], float(row["size"]))
            self._points[key] = ProfileResult.from_dict(row["result"])
        elif kind == "anchor":
            self._anchors[row["sig"]] = float(row["anchor"])

    # -- writes -------------------------------------------------------------
    def _write(self, row: Dict) -> None:
        if self.write_behind:
            with self._lock:
                self._pending.append(row)
                self._pending_ids.add(self._row_identity(row))
            return
        self.backend.append(self.namespace, row)

    def flush_ops(self) -> List[Dict]:
        """Pop buffered write-behind rows as wire-shaped append ops, for
        riding in a shared `backend.batch()` frame (see `sync_views`).
        The caller MUST follow up with `apply_flush(ops, results)` —
        with `results=None` on transport failure — or the popped rows
        are lost."""
        with self._lock:
            rows, self._pending = self._pending, []
            self._pending_ids = set()
        return [{"op": "append", "ns": self.namespace, "record": row}
                for row in rows]

    def apply_flush(self, ops: List[Dict],
                    results: Optional[List[Dict]]) -> int:
        """Settle a `flush_ops()` frame: rows whose append slot failed
        (or every row, when `results is None` — the frame never made it)
        are re-queued ahead of anything buffered meanwhile, so no write
        is lost. Returns rows durably flushed."""
        if results is None:
            failed = [op["record"] for op in ops]
        else:
            failed = [op["record"] for op, r in zip(ops, results)
                      if not (r and r.get("ok"))]
        if failed:
            with self._lock:
                self._pending = failed + self._pending
                self._pending_ids.update(
                    self._row_identity(r) for r in self._pending)
        return len(ops) - len(failed)

    def flush_writes(self) -> int:
        """Send buffered write-behind rows as ONE batched append frame
        (one round trip on a DaemonBackend regardless of how many points
        a service batch produced). Ordering is preserved. On transport
        failure the rows are re-queued ahead of anything buffered
        meanwhile, so no write is lost. Returns rows flushed."""
        with self._lock:
            rows, self._pending = self._pending, []
            self._pending_ids = set()
        if not rows:
            return 0
        try:
            if len(rows) == 1:
                self.backend.append(self.namespace, rows[0])
            else:
                results = self.backend.batch(
                    [{"op": "append", "ns": self.namespace, "record": row}
                     for row in rows])
                failed = [r for r in results if not r.get("ok")]
                if failed:
                    raise RuntimeError(
                        f"{len(failed)}/{len(rows)} batched profile "
                        f"appends failed: {failed[0].get('error')}")
        except BaseException:
            with self._lock:
                self._pending = rows + self._pending
                self._pending_ids.update(
                    self._row_identity(r) for r in self._pending)
            raise
        return len(rows)

    def put(self, signature: str, size: float,
            result: ProfileResult) -> None:
        self._write({"kind": "profile", "sig": signature,
                     "size": float(size), "result": result.to_dict(),
                     "ts": time.time()})
        with self._lock:
            self._points[(signature, float(size))] = result

    def put_anchor(self, signature: str, anchor: float) -> None:
        self._write({"kind": "anchor", "sig": signature,
                     "anchor": float(anchor), "ts": time.time()})
        with self._lock:
            self._anchors[signature] = float(anchor)

    def evict(self, signature: str, size: float) -> None:
        """Tombstone one profile point: siblings drop it on their next
        `refresh()`, and the next `compact()` erases it (and the
        tombstone) from the log for good."""
        self._write({"kind": "profile", "sig": signature,
                     "size": float(size), "tombstone": True,
                     "ts": time.time()})
        with self._lock:
            self._points.pop((signature, float(size)), None)

    # -- maintenance --------------------------------------------------------
    KEY_FIELDS = ("kind", "sig", "size")

    def compact(self, max_age_s: Optional[float] = None) -> Dict:
        """Fold the backing log: one row per (kind, sig, size) identity —
        the LAST appended, which for an evicted point is its tombstone
        (kept so siblings with stale cursors still observe the
        deletion). Given `max_age_s`, surviving rows older than that —
        tombstones included — are evicted. Point counts are unchanged
        unless rows are tombstoned or over-age; cursors held by sibling
        processes stay valid. Returns the backend's
        {"before", "after", "dropped"} stats."""
        return self.backend.compact(self.namespace,
                                    key_fields=self.KEY_FIELDS,
                                    max_age_s=max_age_s)


class BackendModelRegistry(ModelRegistry):
    """ModelRegistry persisted as one versioned StateBackend document.

    Flushes are read-merge-CAS (see module docstring): safe for any
    number of concurrent services sharing one backend, on any transport.
    `refresh()` imports sibling records without writing."""

    DOC_KEY = "records"

    def __init__(self, backend: StateBackend, namespace: str = "registry",
                 autosave: bool = True, path: Optional[str] = None):
        self.backend = backend
        self.namespace = namespace
        # evictions, by time. They are PERSISTED in the backend document
        # ("tombstones"): without them the merge-before-CAS in
        # _save_locked — ours or any sibling process's — would re-import
        # the evicted record straight from the backend document and
        # resurrect it. A genuinely newer record still supersedes its
        # tombstone on both sides of the merge.
        self._tombstones: Dict[str, float] = {}
        # last version at which we observed (and merged) the backend
        # document — lets _save_locked CAS first instead of paying a
        # load round-trip per flush (see _save_locked)
        self._doc_version = 0
        super().__init__(path=None, autosave=autosave)
        # the base class persists iff `path is not None`; backend-only
        # registries get a descriptive sentinel so autosave still fires
        self.path = path if path is not None \
            else f"<{backend.kind}:{namespace}>"
        self.refresh()

    # how long a persisted eviction tombstone lives (see
    # repro.state.compaction.DEFAULT_TOMBSTONE_TTL_S)
    TOMBSTONE_TTL_S = 24 * 3600.0

    # -- codec --------------------------------------------------------------
    def _encode_locked(self) -> Dict:
        # a tombstone superseded by a newer record of the same signature —
        # or older than the TTL (every live sibling has long since merged
        # the eviction) — has done its job; don't persist it forever
        horizon = time.time() - self.TOMBSTONE_TTL_S
        tombstones = {
            sig: ts for sig, ts in self._tombstones.items()
            if ts >= horizon
            and (sig not in self._records
                 or self._records[sig].created_at <= ts)}
        return {"version": REGISTRY_VERSION,
                "records": {sig: rec.to_dict()
                            for sig, rec in self._records.items()},
                "tombstones": tombstones}

    @staticmethod
    def _decode(value: Optional[Dict]) -> Dict[str, ModelRecord]:
        if not value:
            return {}
        return {sig: ModelRecord.from_dict(sig, d)
                for sig, d in value.get("records", {}).items()}

    @staticmethod
    def _decode_tombstones(value: Optional[Dict]) -> Dict[str, float]:
        if not value:
            return {}
        return {sig: float(ts)
                for sig, ts in (value.get("tombstones") or {}).items()}

    def _merge_locked(self, disk_records: Dict[str, ModelRecord],
                      disk_tombstones: Optional[Dict[str, float]] = None
                      ) -> None:
        # sibling evictions first: they delete any copy of ours that is
        # not strictly newer than the eviction
        for sig, ts in (disk_tombstones or {}).items():
            mine = self._records.get(sig)
            if mine is not None and mine.created_at > ts:
                continue                # our record outlives the eviction
            self._records.pop(sig, None)
            self._tombstones[sig] = max(ts, self._tombstones.get(sig, ts))
        for sig, rec in disk_records.items():
            evicted_at = self._tombstones.get(sig)
            if evicted_at is not None:
                if rec.created_at <= evicted_at:
                    continue            # the copy this registry evicted
                del self._tombstones[sig]   # newer model supersedes it
            mine = self._records.get(sig)
            if mine is None or rec.created_at > mine.created_at:
                self._records[sig] = rec

    def put(self, signature: str, model, candidate: Optional[str] = None,
            sizes=(), mems=(), defer_save: bool = False,
            runtime_model=None, runtime_candidate: Optional[str] = None,
            walls=()):
        with self._lock:
            # re-registering a signature revokes our own eviction of it
            self._tombstones.pop(signature, None)
            return super().put(signature, model, candidate=candidate,
                               sizes=sizes, mems=mems,
                               defer_save=defer_save,
                               runtime_model=runtime_model,
                               runtime_candidate=runtime_candidate,
                               walls=walls)

    def evict(self, signature: str) -> bool:
        with self._lock:
            gone = self._records.pop(signature, None) is not None
            if gone:
                self._tombstones[signature] = time.time()
                self._dirty = True
                if self.autosave and self.path is not None:
                    self._save_locked(self.path)
            return gone

    def prune(self, max_records: Optional[int] = None,
              max_age_s: Optional[float] = None) -> List[str]:
        """Evict records older than `max_age_s` and/or the oldest records
        beyond `max_records`, tombstoning each (shared across processes)
        with ONE flush. Same policy, same code as the daemon-side
        eviction: both delegate to `prune_registry_doc`. Returns the
        evicted signatures."""
        with self._lock:
            new_value, evicted = prune_registry_doc(
                self._encode_locked(), max_records=max_records,
                max_age_s=max_age_s, tombstone_ttl_s=self.TOMBSTONE_TTL_S)
            if evicted:
                self._records = self._decode(new_value)
                self._tombstones = self._decode_tombstones(new_value)
                self._dirty = True
                if self.autosave and self.path is not None:
                    self._save_locked(self.path)
            return evicted

    # -- persistence (overrides the file I/O of the base class) -------------
    def _save_locked(self, path: Optional[str] = None) -> None:
        # optimistic CAS-first flush: `_doc_version` is the version at
        # which we last merged the backend document (refresh/load/a won
        # CAS), and an unchanged version means an unchanged document —
        # so our in-memory state is already a superset and the CAS is
        # safe without re-loading. One round trip per uncontended flush
        # instead of two; a lost race falls back to merge-and-retry on
        # the loser's returned (value, version), same as before.
        version = self._doc_version
        while True:
            won, cur, ver = self.backend.cas(
                self.namespace, self.DOC_KEY, version, self._encode_locked())
            if won:
                self._doc_version = ver
                break
            # lost the flush race: merge the winner's document and retry
            self._merge_locked(self._decode(cur),
                               self._decode_tombstones(cur))
            version = ver
        self._dirty = False

    def load(self, path: Optional[str] = None) -> int:
        value, version = self.backend.load(self.namespace, self.DOC_KEY)
        records = self._decode(value)
        with self._lock:
            # explicit reload adopts the backend wholesale, evictions
            # included
            self._records = records
            self._tombstones = self._decode_tombstones(value)
            self._doc_version = version
            self._dirty = False
            return len(self._records)

    def refresh(self) -> int:
        """Merge sibling processes' records AND evictions into memory (no
        write). Returns the number of records imported or updated."""
        value, version = self.backend.load(self.namespace, self.DOC_KEY)
        return self._merge_refresh(value, version)

    def refresh_op(self) -> Dict:
        """The wire-shaped load op `refresh()` would issue — for
        coalescing with other views' refreshes into one backend.batch()
        frame (see `refresh_views`)."""
        return {"op": "load", "ns": self.namespace, "key": self.DOC_KEY}

    def apply_refresh(self, resp: Dict) -> int:
        """Apply one batch-result slot produced by `refresh_op()`. A
        failed slot leaves the registry stale (and `_doc_version`
        untouched, so the next flush just takes the CAS-retry path)."""
        if not resp or not resp.get("ok"):
            return 0
        return self._merge_refresh(resp.get("value"),
                                   int(resp.get("version", 0)))

    def flush_ops(self) -> List[Dict]:
        """The wire-shaped CAS op a dirty registry's flush would issue
        ([] when clean) — for riding in a shared `backend.batch()` frame
        (see `sync_views`). Settle with `apply_flush(ops, results)`."""
        with self._lock:
            if not self._dirty:
                return []
            return [{"op": "cas", "ns": self.namespace, "key": self.DOC_KEY,
                     "version": self._doc_version,
                     "value": self._encode_locked()}]

    def apply_flush(self, ops: List[Dict],
                    results: Optional[List[Dict]]) -> int:
        """Settle a `flush_ops()` frame. A won CAS marks the registry
        clean; a lost race merges the winner's document and LEAVES the
        registry dirty — the next sync (or `flush()`) retries against
        the winner's version, exactly like `_save_locked`'s retry loop
        but amortized across frames. A failed/absent slot changes
        nothing (still dirty, same version)."""
        if not ops:
            return 0
        resp = results[0] if results else None
        if not (resp and resp.get("ok")):
            return 0
        with self._lock:
            self._doc_version = int(resp.get("version", self._doc_version))
            if resp.get("won"):
                self._dirty = False
                return 1
            self._merge_locked(self._decode(resp.get("value")),
                               self._decode_tombstones(resp.get("value")))
        return 0

    def _merge_refresh(self, value: Optional[Dict], version: int) -> int:
        with self._lock:
            before = {sig: rec.created_at
                      for sig, rec in self._records.items()}
            self._merge_locked(self._decode(value),
                               self._decode_tombstones(value))
            self._doc_version = version
            return sum(1 for sig, rec in self._records.items()
                       if before.get(sig) != rec.created_at)


def refresh_views(*views) -> int:
    """Refresh several backend views (ProfileStore, BackendModelRegistry,
    anything with `refresh_op()`/`apply_refresh()`) in as few round trips
    as possible: views sharing ONE backend object are coalesced into a
    single `backend.batch()` call — one wire frame on a DaemonBackend
    instead of one per view — and applied in order. Views on distinct
    backends (or without the coalescing hooks) fall back to their own
    `refresh()`. Returns the total number of rows/records applied.

    Per-op error isolation carries through: a failed slot leaves that
    view stale (it re-reads the same tail next time) without aborting
    its neighbors.

    Over a sharded backend (repro.state.sharding.ShardedBackend) the
    coalesced frame is split by owning shard INSIDE `batch()` — each
    view's namespace lives on exactly one shard, sub-frames fan out
    concurrently, and results come back in this frame's order — so the
    one-call-per-backend pattern here needs no sharding awareness."""
    total = 0
    groups: List[Tuple[StateBackend, List]] = []
    for view in views:
        if view is None:
            continue
        if not (hasattr(view, "refresh_op")
                and hasattr(view, "apply_refresh")):
            refresh = getattr(view, "refresh", None)
            if callable(refresh):
                result = refresh()
                total += result if isinstance(result, int) else 0
            continue
        for backend, members in groups:
            if backend is view.backend:
                members.append(view)
                break
        else:
            groups.append((view.backend, [view]))
    for backend, members in groups:
        if len(members) == 1:
            total += members[0].refresh()
            continue
        results = backend.batch([v.refresh_op() for v in members])
        for view, resp in zip(members, results):
            total += view.apply_refresh(resp)
    return total


def sync_views(*views) -> int:
    """Flush AND refresh several backend views in ONE round trip per
    shared backend: each view's pending writes (`flush_ops()` — buffered
    write-behind rows, a dirty registry's CAS) ride at the FRONT of the
    frame, followed by every view's `refresh_op()`. Batch frames read
    their own earlier writes, so each refresh observes the flush it
    shares a frame with. This is the AllocationService's steady-state
    wire pattern: batch N's writes are carried by batch N+1's sync, so a
    loaded service pays exactly one frame per batch.

    Failure semantics compose from the parts: a failed append slot
    re-queues its row (`ProfileStore.apply_flush`), a lost CAS merges
    the winner and stays dirty (`BackendModelRegistry.apply_flush`), a
    failed refresh slot leaves that view stale, and a transport error
    mid-frame restores every popped row before propagating. Views
    without the hooks fall back to their own `flush_writes`/`flush` +
    `refresh`. Returns rows/records applied by the refresh half.

    Sharded backends keep every one of those guarantees: a view's flush
    and refresh ops share a namespace, hence a shard, hence relative
    order within that shard's sub-frame (refresh still reads its own
    flush); a shard whose primary AND standby are down degrades to
    {"ok": false} slots for ITS ops only, so exactly the affected
    views re-queue while views on healthy shards proceed."""
    total = 0
    groups: List[Tuple[StateBackend, List]] = []
    for view in views:
        if view is None:
            continue
        if not (hasattr(view, "refresh_op")
                and hasattr(view, "apply_refresh")):
            for name in ("flush_writes", "flush"):
                fn = getattr(view, name, None)
                if callable(fn):
                    fn()
                    break
            refresh = getattr(view, "refresh", None)
            if callable(refresh):
                result = refresh()
                total += result if isinstance(result, int) else 0
            continue
        for backend, members in groups:
            if backend is view.backend:
                members.append(view)
                break
        else:
            groups.append((view.backend, [view]))
    for backend, members in groups:
        flushes = [(v, v.flush_ops() if hasattr(v, "flush_ops") else [])
                   for v in members]
        ops = [op for _v, vops in flushes for op in vops]
        ops += [v.refresh_op() for v in members]
        try:
            results = backend.batch(ops)
        except BaseException:
            for v, vops in flushes:
                if vops:
                    v.apply_flush(vops, None)
            raise
        i = 0
        for v, vops in flushes:
            if vops:
                v.apply_flush(vops, results[i:i + len(vops)])
            i += len(vops)
        for v in members:
            total += v.apply_refresh(results[i])
            i += 1
    return total


class LockedModelRegistry(BackendModelRegistry):
    """Back-compat file-backed registry: a BackendModelRegistry over a
    FileBackend rooted next to `path` (same concurrency guarantees as any
    backend registry — concurrent flushes lose no records)."""

    def __init__(self, path: str, autosave: bool = True,
                 lock_timeout_s: float = 10.0):
        self.lock_timeout_s = lock_timeout_s
        root, stem = _split_path(path, ".json")
        super().__init__(FileBackend(root, lock_timeout_s=lock_timeout_s),
                         namespace=stem, autosave=autosave, path=path)
