"""Profiling orchestration: budgeted, adaptive, concurrent, multi-process.

Crispy's whole value proposition is cheap profiling — under ten minutes
per job on a laptop — yet the PR-1 pipeline spends a fixed 5-point ladder
serially on every new signature and keeps its caches per-process. This
package turns profiling itself into a managed resource:

  budget.py     `ProfilingBudget` — the paper's ten-minute envelope as an
                enforced, thread-safe limit (wall clock, accounted profile
                seconds, and point count) shared by everything below.

  scheduler.py  `AdaptiveLadderScheduler` — profiles smallest-first,
                refits the model zoo after each point, stops once the
                selected candidate is confident and its full-size
                requirement prediction has stabilized; escalates beyond
                the base ladder only when candidates disagree (Ruya-style
                iterative spend, arXiv:2211.04240). `calibrated_anchor`
                persists per-signature anchors so repeat signatures skip
                `calibrate_anchor` entirely.

  executor.py   `ProfilingExecutor` — thread pool that profiles fixed
                ladders point-concurrently and fans independent signature
                groups out, all under one global budget.

  store.py      `ProfileStore` (profile points + calibrated anchors in a
                backend append-only log), `BackendModelRegistry`
                (read-merge-CAS registry flushes: concurrent services
                lose no records) and the back-compat `LockedModelRegistry`
                file constructor. All sharing is delegated to the
                `repro.state` StateBackend protocol (memory / fcntl file
                / crispy-daemon); no fcntl lives here anymore.

`repro.allocator.service.AllocationService` delegates its profiling path
here (`adaptive=True`, `budget=`, `store=`, `executor=`);
`repro.core.crispy.CrispyAllocator.allocate` grows the same knobs for the
one-shot path; `benchmarks/profiling_adaptive.py` measures fixed-vs-
adaptive points, wall time and requirement error.
"""
from repro.profiling.budget import BudgetExhausted, ProfilingBudget
from repro.profiling.executor import DEFAULT_WORKERS, ProfilingExecutor
from repro.profiling.scheduler import (AdaptiveLadderScheduler,
                                       AdaptiveProfile, DISAGREE_RTOL,
                                       MAX_EXTRA_POINTS, MIN_POINTS,
                                       STABILITY_RTOL, calibrated_anchor)
from repro.profiling.store import (BackendModelRegistry, FileLock,
                                   HAS_FCNTL, LockedModelRegistry,
                                   ProfileStore)

__all__ = [
    "AdaptiveLadderScheduler", "AdaptiveProfile", "BackendModelRegistry",
    "BudgetExhausted", "DEFAULT_WORKERS", "DISAGREE_RTOL", "FileLock",
    "HAS_FCNTL", "LockedModelRegistry", "MAX_EXTRA_POINTS", "MIN_POINTS",
    "ProfileStore", "ProfilingBudget", "ProfilingExecutor",
    "STABILITY_RTOL", "calibrated_anchor",
]
