"""Profiling orchestration: budgeted, adaptive, concurrent, multi-process.

Crispy's whole value proposition is cheap profiling — under ten minutes
per job on a laptop — yet the PR-1 pipeline spends a fixed 5-point ladder
serially on every new signature and keeps its caches per-process. This
package turns profiling itself into a managed resource:

  budget.py     `ProfilingBudget` — the paper's ten-minute envelope as an
                enforced, thread-safe limit (wall clock, accounted profile
                seconds, and point count) shared by everything below.

  scheduler.py  `AdaptiveLadderScheduler` — now a budget-gating driver
                over the `repro.pipeline` placement strategies: the PR-2
                ladder-prefix behavior lives in
                `repro.pipeline.placement.LadderPlacer` (smallest-first,
                refit per point, early stop on confident+stable,
                gap-midpoint escalation while candidates disagree;
                Ruya-style iterative spend, arXiv:2211.04240), and
                `placement="infogain"` swaps in information-optimal
                placement. `calibrated_anchor` persists per-signature
                anchors so repeat signatures skip `calibrate_anchor`
                entirely.

  executor.py   `ProfilingExecutor` — thread pool the pipeline fans
                fixed-ladder points and the service fans independent
                signature groups over (`map_tasks`); budget gating lives
                in the pipeline's acquisition stage, not here.

  store.py      `ProfileStore` (profile points + calibrated anchors in a
                backend append-only log), `BackendModelRegistry`
                (read-merge-CAS registry flushes: concurrent services
                lose no records) and the back-compat `LockedModelRegistry`
                file constructor. All sharing is delegated to the
                `repro.state` StateBackend protocol (memory / fcntl file
                / crispy-daemon); no fcntl lives here anymore.

The acquisition loop itself now lives in `repro.pipeline` (PointSource +
drive_placement): both `AllocationService` and `CrispyAllocator` reach
these resources through the unified pipeline's `budget=`, `store=` and
`executor=` knobs; `benchmarks/profiling_adaptive.py` measures fixed-vs-
adaptive points, wall time and requirement error, and
`benchmarks/point_placement.py` compares placement strategies.
"""
from repro.profiling.budget import BudgetExhausted, ProfilingBudget
from repro.profiling.executor import DEFAULT_WORKERS, ProfilingExecutor
from repro.profiling.scheduler import (AdaptiveLadderScheduler,
                                       AdaptiveProfile, DISAGREE_RTOL,
                                       MAX_EXTRA_POINTS, MIN_POINTS,
                                       STABILITY_RTOL, calibrated_anchor)
from repro.profiling.store import (BackendModelRegistry, FileLock,
                                   HAS_FCNTL, LockedModelRegistry,
                                   ProfileStore)

__all__ = [
    "AdaptiveLadderScheduler", "AdaptiveProfile", "BackendModelRegistry",
    "BudgetExhausted", "DEFAULT_WORKERS", "DISAGREE_RTOL", "FileLock",
    "HAS_FCNTL", "LockedModelRegistry", "MAX_EXTRA_POINTS", "MIN_POINTS",
    "ProfileStore", "ProfilingBudget", "ProfilingExecutor",
    "STABILITY_RTOL", "calibrated_anchor",
]
