import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: run named variants of the three chosen cells,
record the roofline terms per variant into experiments/perf/.

Each variant encodes an explicit hypothesis (see EXPERIMENTS.md §Perf);
the 256-chip count is held constant — mesh shape, remat policy, microbatch
count, MoE dispatch and gradient compression are the knobs.

  PYTHONPATH=src python -m repro.launch.perf --cell ds7b --variant tp8
  PYTHONPATH=src python -m repro.launch.perf --all
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import SHAPES, get_arch
from repro.configs.base import MeshConfig, RunConfig
from repro.launch.dryrun import run_cell
from repro.launch.mesh import compat_make_mesh
from repro.launch.presets import preset_run


def mesh_of(shape, axes=("data", "model")):
    return compat_make_mesh(shape, axes)


def ds7b_variants():
    cfg = get_arch("deepseek-7b")
    shape = SHAPES["train_4k"]

    def base_run(mesh_shape):
        mc = MeshConfig(mesh_shape, ("data", "model"))
        return preset_run(cfg, shape, mc)

    return cfg, shape, [
        # (name, mesh shape, run)
        ("baseline", (16, 16), base_run((16, 16))),
        # H1: remat 'dots' removes the recompute forward's TP all-reduces
        # (1/3 of activation-collective volume) at +stash memory
        ("remat_dots", (16, 16), base_run((16, 16)).with_(remat="dots")),
        # H2: TP=8/DP=32 — TP all-reduce volume per device is
        # tokens-per-device * d * L; doubling DP halves it; params/shard
        # 2x (3.5 GiB bf16-equiv, fits)
        ("tp8", (32, 8), base_run((32, 8))),
        # H3: TP=4/DP=64 + dots — collective down ~4x vs baseline, compute
        # unchanged; expect memory-bound
        ("tp4_dots", (64, 4), base_run((64, 4)).with_(remat="dots")),
        # H4: H3 + bf16 gradient all-reduce (halves the DP gradient wire)
        ("tp4_dots_gcomp", (64, 4),
         base_run((64, 4)).with_(remat="dots", grad_compression=True)),
        # H5: H3 exceeded the 16 GiB budget (f32 grads + f32 params at
        # TP=4). bf16 params/moments/accumulator + ZeRO-1 master brings it
        # back under while keeping the collective win
        ("tp4_dots_bf16", (64, 4),
         base_run((64, 4)).with_(remat="dots", param_dtype="bfloat16",
                                 moment_dtype="bfloat16",
                                 accum_dtype="bfloat16")),
        # H6: budget-compliant TP=4: keep remat=boundaries (no dots stash);
        # collective gets the recompute psums back (~+33%) but memory/dev
        # drops below 16 GiB with bf16 params+accum
        ("tp4_bound_bf16", (64, 4),
         base_run((64, 4)).with_(param_dtype="bfloat16",
                                 moment_dtype="bfloat16",
                                 accum_dtype="bfloat16")),
    ]


def dsv3_variants():
    cfg = get_arch("deepseek-v3-671b")
    shape = SHAPES["train_4k"]
    cfg_a2a = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl="ep_a2a"))

    def base_run(mesh_shape, **kw):
        mc = MeshConfig(mesh_shape, ("data", "model"))
        return preset_run(cfg, shape, mc).with_(**kw)

    return None, shape, [
        ("baseline", (16, 16), base_run((16, 16)), cfg),
        # H1: a2a EP (experts over model x data, 1 expert/device) removes
        # the per-microbatch FSDP weight all-gathers (~3.7 TB/step wire);
        # token a2a costs 2*T*k*d instead
        ("ep_a2a", (16, 16), base_run((16, 16), fsdp_experts=False),
         cfg_a2a),
        # H2: + remat dots (drop recompute psums; stash fits: +~7 GiB)
        ("ep_a2a_dots", (16, 16),
         base_run((16, 16), fsdp_experts=False, remat="dots"), cfg_a2a),
        # H3: + microbatches 16->8: expert/attn weights re-read half as
        # often (memory term), a2a volume unchanged
        ("ep_a2a_dots_mb8", (16, 16),
         base_run((16, 16), fsdp_experts=False, remat="dots",
                  microbatches=8), cfg_a2a),
        # H4: TP 16->8 on top of a2a: attention TP psums halve; the a2a
        # exchange (over 'data') is unchanged; experts stay 1/device
        # (8 model x 32 data)
        ("ep_a2a_tp8", (32, 8),
         dataclasses.replace(base_run((32, 8)), fsdp_experts=False),
         cfg_a2a),
    ]


def whisper_variants():
    cfg = get_arch("whisper-small")
    shape = SHAPES["train_4k"]

    def base_run(mesh_shape):
        mc = MeshConfig(mesh_shape, ("data", "model"))
        return preset_run(cfg, shape, mc)

    return cfg, shape, [
        ("baseline", (16, 16), base_run((16, 16))),
        # H1: a 244M-param model has no business on TP=16 — 12 heads can't
        # shard, every projection all-gathers. Crispy-style config choice:
        # pure DP-256 (the 'right cluster shape for the job')
        ("dp256", (256, 1), base_run((256, 1))),
        # H2: middle ground TP=2 (heads 12 % 2 == 0): check whether any TP
        # helps at this scale
        ("dp128_tp2", (128, 2), base_run((128, 2))),
    ]


CELLS = {
    "ds7b": ds7b_variants,
    "dsv3": dsv3_variants,
    "whisper": whisper_variants,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(CELLS), default=None)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cells = sorted(CELLS) if args.all else [args.cell]
    for cname in cells:
        spec = CELLS[cname]()
        base_cfg, shape, variants = spec[0], spec[1], spec[2]
        for v in variants:
            if len(v) == 4:
                name, mshape, run, cfg = v
            else:
                name, mshape, run = v
                cfg = base_cfg
            if args.variant and name != args.variant:
                continue
            path = os.path.join(args.out, f"{cname}__{name}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {cname}/{name}")
                continue
            try:
                mesh = mesh_of(mshape)
                rec = run_cell(cfg, shape, mesh, run)
                rec["variant"] = name
                rec["mesh_shape"] = list(mshape)
                rec["run_config"] = dataclasses.asdict(run)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                print(f"[ok] {cname}/{name}: mesh={mshape} "
                      f"comp={r['compute_s']:.3f} mem={r['memory_s']:.3f} "
                      f"coll={r['collective_s']:.3f} dom={r['dominant']} "
                      f"MFU={r['mfu_bound']:.3f} "
                      f"gib={rec['memory']['per_device_gib']}", flush=True)
            except Exception as e:
                print(f"[FAIL] {cname}/{name}: {type(e).__name__}: {e}",
                      flush=True)
                traceback.print_exc()


if __name__ == "__main__":
    main()
