"""Per-(arch x shape x mesh) RunConfig presets — the baseline points the
perf hillclimb starts from. Tuned for v5e (16 GiB HBM/chip):

* microbatches sized so each device sees ~1 sequence per microbatch at
  train_4k (activation stash = n_layers * S * d * 2B per device with
  remat='boundaries');
* FSDP (2D weight sharding over data x model) for >=30B-param archs —
  a 123B bf16 replica over only the model axis would be 15.4 GiB/chip;
* expert FSDP for deepseek-v3 (652B expert params need sharding over both
  axes: 256 experts / 16 model-shards x ff/16 over data);
* decode/prefill run microbatches=1 and keep ZeRO off (no optimizer).
"""
from __future__ import annotations

from repro.configs.base import MeshConfig, ModelConfig, RunConfig, ShapeConfig

_BIG_PARAMS = 30e9


def preset_run(cfg: ModelConfig, shape: ShapeConfig,
               mesh_cfg: MeshConfig) -> RunConfig:
    n_params = cfg.param_count()
    big = n_params >= _BIG_PARAMS
    run = RunConfig(
        attn_impl="blocked",
        remat="boundaries",
        compute_dtype="bfloat16",
        param_dtype="bfloat16" if big else "float32",
        moment_dtype="bfloat16" if big else "float32",
        fsdp_params=big,
        fsdp_experts=(cfg.moe is not None and cfg.moe.n_experts >= 128),
        zero1=True,
    )
    if shape.mode == "train":
        dp = mesh_cfg.dp
        mb = max(1, shape.global_batch // dp)
        # small models can afford 2 seqs per microbatch
        if cfg.d_model < 4096 and mb % 2 == 0:
            mb //= 2
        run = run.with_(microbatches=mb)
    else:
        run = run.with_(microbatches=1, zero1=False, remat="nothing")
    if shape.seq_len >= 32768:
        run = run.with_(attn_block_q=1024, attn_block_kv=2048)
    return run
