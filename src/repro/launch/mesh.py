"""Production mesh builders.

`make_production_mesh` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
while smoke tests and benches see 1 device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

try:                                    # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:                     # older jax: meshes are Auto-only
    AxisType = None

from repro.configs.base import MeshConfig, MULTI_POD, SINGLE_POD

HAS_AXIS_TYPE = AxisType is not None

def compat_shard_map(body, mesh, in_specs, out_specs, check_vma=None):
    """`jax.shard_map` across jax versions: top-level API with `check_vma`
    on new jax, `jax.experimental.shard_map.shard_map` with the older
    `check_rep` spelling of the same knob otherwise."""
    kw = {} if check_vma is None else {"check_vma": check_vma}
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, **kw)


def compat_cost_analysis(compiled) -> dict:
    """`compiled.cost_analysis()` across jax versions: newer jax returns
    one dict, jax <= 0.4.x a list with one dict per partitioned program —
    normalize to the first (host-local) program's dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return cost


def compat_axis_size(axis_name: str):
    """`lax.axis_size` inside a shard_map/pmap body across jax versions;
    older jax uses the classic constant-folded `psum(1, axis)` idiom."""
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def compat_make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """`jax.make_mesh` with Auto axis types where the installed jax supports
    them, plain mesh otherwise (older jax is Auto-only, so semantics match)."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return compat_make_mesh(cfg.shape, cfg.axes)


def make_local_mesh(model: int = 1, data: Optional[int] = None):
    """Mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return compat_make_mesh((data, model), ("data", "model"))


def mesh_config(mesh) -> MeshConfig:
    return MeshConfig(tuple(mesh.shape[a] for a in mesh.axis_names),
                      tuple(mesh.axis_names))
