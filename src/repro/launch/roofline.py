"""Roofline term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_wire_bytes_per_device / link_bw

`cost_analysis()` reports per-device FLOPs / bytes (XLA SPMD partitions
before costing). Collective bytes are NOT in cost_analysis — we parse the
optimized HLO and charge each op its ring-algorithm wire bytes per device:

    all-gather(out S, group n):      S * (n-1)/n
    reduce-scatter(in S, group n):   S * (n-1)/n
    all-reduce(S, group n):          2 * S * (n-1)/n
    all-to-all(S, group n):          S * (n-1)/n
    collective-permute(S):           S

Hardware constants (v5e class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (brief-specified).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_kind: Dict[str, float] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[-1][:40]:
            continue
        # the op's result type appears right after '= '
        eq = line.find("= ")
        if eq < 0:
            continue
        typ_text = line[eq + 2: line.find("(", eq)]
        size = _shape_bytes(typ_text)
        if size == 0:
            continue
        kind = m.group(1)
        n = max(2, _group_size(line, n_devices))
        ring = (n - 1) / n
        if kind == "all-reduce":
            wire = 2.0 * size * ring
        elif kind == "collective-permute":
            wire = float(size)
        else:
            wire = size * ring
        stats.wire_bytes += wire
        stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + wire
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * n_dev): remat/redundancy waste."""
        if self.flops_per_dev <= 0:
            return 0.0
        return self.model_flops / self.flops_per_dev

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound (the score):
        model_flops / (bound_s * peak) per device."""
        if self.bound_s <= 0:
            return 0.0
        return self.model_flops / (self.bound_s * PEAK_FLOPS)


def roofline_from(cost: dict, coll: CollectiveStats, n_devices: int,
                  model_flops_total: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll.wire_bytes / ICI_BW,
        flops_per_dev=flops,
        bytes_per_dev=byts,
        coll_bytes_per_dev=coll.wire_bytes,
        model_flops=model_flops_total / max(n_devices, 1),
    )


def roofline_from_hlo(hc, n_devices: int, model_flops_total: float,
                      extra_hbm_bytes: float = 0.0) -> Roofline:
    """Build roofline terms from trip-count-aware HLO costs
    (launch/hlo_costs.py). `extra_hbm_bytes`: analytic non-dot HBM traffic
    per device (optimizer elementwise update: read+write of params/moments/
    master — outside the parsed dot set)."""
    byts = hc.dot_bytes + extra_hbm_bytes
    return Roofline(
        compute_s=hc.dot_flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=hc.coll_wire_bytes / ICI_BW,
        flops_per_dev=hc.dot_flops,
        bytes_per_dev=byts,
        coll_bytes_per_dev=hc.coll_wire_bytes,
        model_flops=model_flops_total / max(n_devices, 1),
    )


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs for the whole step across all devices.

    train:    6 * N_active * tokens     (fwd 2 + bwd 4)
    prefill:  2 * N_active * tokens
    decode:   2 * N_active * batch      (one token per sequence)
    (Attention score FLOPs excluded by convention — MODEL_FLOPS = 6·N·D.)
    """
    n = cfg.active_param_count()
    if shape.mode == "train":
        return 6.0 * n * shape.tokens
    if shape.mode == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch
