import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape) cell against the production mesh — single-pod
(16,16) data x model and multi-pod (2,16,16) pod x data x model — with no
real allocation (ShapeDtypeStruct inputs), then record:

  * compiled.memory_analysis()  — proves the per-device working set,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline,
  * collective wire bytes parsed from the optimized HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, SHAPES, get_arch, shape_applicable,
                           cell_id)
from repro.configs.base import RunConfig
from repro.launch.mesh import (compat_cost_analysis, make_production_mesh,
                               mesh_config)
from repro.launch.presets import preset_run
from repro.launch.hlo_costs import analyze as hlo_analyze
from repro.launch.roofline import model_flops, roofline_from_hlo
from repro.models.model import Model, input_specs
from repro.optim import AdamWConfig, init_adamw
from repro.sharding.rules import (batch_spec, cache_specs, named,
                                  opt_state_specs, param_specs)
from repro.train.step import TrainState, make_train_step

GiB = 1024 ** 3


def _abstract(tree):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        tree)


def build_lowered(cfg, shape, mesh, run: RunConfig = None):
    """Construct the step function + abstract inputs + shardings for a cell
    and return the jax .lower() result."""
    mcfg = mesh_config(mesh)
    run = run or preset_run(cfg, shape, mcfg)
    model = Model(cfg, run)
    batch, caches = input_specs(cfg, shape, run)
    p_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_specs(p_abs, mesh, run)
    dp = 1
    for ax in ("pod", "data"):
        try:
            dp *= mesh.shape[ax]
        except KeyError:
            pass

    def bshard(s):
        # batch dim shards over (pod, data) only when divisible
        # (long_500k has global_batch=1: replicate)
        if s.shape and s.shape[0] % dp == 0:
            return named(mesh, batch_spec(mesh, len(s.shape)))
        from jax.sharding import PartitionSpec as P
        return named(mesh, P())

    bspec = jax.tree.map(bshard, batch)

    if shape.mode == "train":
        acfg = AdamWConfig(moment_dtype=run.moment_dtype,
                           keep_master=(run.param_dtype != "float32"))
        opt_abs = jax.eval_shape(lambda p: init_adamw(p, acfg), p_abs)
        o_specs = opt_state_specs(opt_abs, p_specs, p_abs, mesh, run)
        state_abs = TrainState(p_abs, opt_abs, None)
        state_shard = TrainState(
            jax.tree.map(lambda s: named(mesh, s), p_specs),
            jax.tree.map(lambda s: named(mesh, s), o_specs),
            None)
        step = make_train_step(model, acfg, mesh)
        fn = jax.jit(step, in_shardings=(state_shard, bspec),
                     donate_argnums=(0,) if run.donate else ())
        return fn.lower(state_abs, batch), model

    p_shard = jax.tree.map(lambda s: named(mesh, s), p_specs)
    if shape.mode == "prefill":
        def prefill_fn(params, b):
            return model.prefill(params, b, shape.seq_len, mesh)

        # constrain the returned caches (otherwise XLA replicates the
        # zero-init caches of the ssm/hybrid/vlm fallback path: measured
        # 191 GiB/dev on zamba2 = its full 195 GB cache, per device)
        out_abs = jax.eval_shape(prefill_fn, p_abs, batch)
        c_specs = cache_specs(out_abs[1], mesh, run, shape.global_batch)
        out_shard = (None, jax.tree.map(lambda s: named(mesh, s), c_specs))
        fn = jax.jit(prefill_fn, in_shardings=(p_shard, bspec),
                     out_shardings=out_shard)
        return fn.lower(p_abs, batch), model

    # decode
    c_specs = cache_specs(caches, mesh, run, shape.global_batch)
    c_shard = jax.tree.map(lambda s: named(mesh, s), c_specs)

    def decode_fn(params, b, c):
        return model.decode_step(params, b, c, mesh)

    fn = jax.jit(decode_fn, in_shardings=(p_shard, bspec, c_shard),
                 donate_argnums=(2,) if run.donate else ())
    return fn.lower(p_abs, batch, caches), model


def run_cell(cfg, shape, mesh, run: RunConfig = None, hlo_out: str = None):
    t0 = time.monotonic()
    lowered, model = build_lowered(cfg, shape, mesh, run)
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0
    ma = compiled.memory_analysis()
    cost = compat_cost_analysis(compiled)
    hlo = compiled.as_text()
    if hlo_out:
        with open(hlo_out, "w") as f:
            f.write(hlo)
    n_dev = mesh.devices.size
    hc = hlo_analyze(hlo, n_dev)
    mf = model_flops(cfg, shape)
    # analytic non-dot HBM traffic: optimizer elementwise update reads and
    # writes params + m + v (+ master) once per step
    extra = 0.0
    if shape.mode == "train":
        extra = 2.0 * float(ma.argument_size_in_bytes)
    roof = roofline_from_hlo(hc, n_dev, mf, extra_hbm_bytes=extra)
    per_dev_bytes = (ma.argument_size_in_bytes + ma.output_size_in_bytes +
                     ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    rec = {
        "cell": cell_id(cfg.name, shape.name),
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": list(mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "n_devices": int(n_dev),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0)),
            "per_device_bytes": int(per_dev_bytes),
            "per_device_gib": round(per_dev_bytes / GiB, 3),
        },
        "cost_analysis_raw": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
        "hlo_costs": {
            "dot_flops_per_dev": hc.dot_flops,
            "dot_bytes_per_dev": hc.dot_bytes,
            "n_while": hc.n_while,
            "max_trip_multiplier": hc.max_mult,
        },
        "collectives": {
            "wire_bytes_per_dev": hc.coll_wire_bytes,
            "by_kind": hc.coll_by_kind,
            "counts": hc.coll_counts,
        },
        "roofline": {
            "compute_s": roof.compute_s,
            "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "dominant": roof.dominant,
            "model_flops_total": mf,
            "model_flops_per_dev": roof.model_flops,
            "hlo_flops_per_dev": roof.flops_per_dev,
            "useful_flops_fraction": roof.useful_flops_fraction,
            "mfu_bound": roof.mfu_bound,
        },
        "params": {
            "total": cfg.param_count(),
            "active": cfg.active_param_count(),
        },
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    os.makedirs(args.out, exist_ok=True)
    suffix = "multipod" if args.multi_pod else "singlepod"

    cells = []
    if args.all:
        for cfg in ARCHS.values():
            for shape in SHAPES.values():
                cells.append((cfg, shape))
    else:
        cells.append((get_arch(args.arch), SHAPES[args.shape]))

    failures = 0
    for cfg, shape in cells:
        name = f"{cfg.name}__{shape.name}__{suffix}"
        path = os.path.join(args.out, name + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {name}")
            continue
        if not shape_applicable(cfg, shape):
            rec = {"cell": cell_id(cfg.name, shape.name), "skipped": True,
                   "reason": "long_500k requires sub-quadratic attention "
                             "(DESIGN.md §4)"}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[SKIP-BY-DESIGN] {name}")
            continue
        try:
            rec = run_cell(cfg, shape, mesh)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            m = rec["memory"]["per_device_gib"]
            r = rec["roofline"]
            print(f"[ok] {name}: {m} GiB/dev, dominant={r['dominant']}, "
                  f"mfu_bound={r['mfu_bound']:.3f}, "
                  f"compile={rec['compile_s']}s", flush=True)
        except Exception as e:
            failures += 1
            print(f"[FAIL] {name}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
            with open(path + ".err", "w") as f:
                f.write(traceback.format_exc())
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
