"""Serving launcher: drive the continuous-batching engine from the CLI.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.configs.base import RunConfig
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).reduced()
    run = RunConfig(attn_impl="full", remat="nothing",
                    compute_dtype="float32",
                    kv_cache_dtype="int8" if args.int8_kv else "compute")
    model = Model(cfg, run)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    for rid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=3).tolist()
        engine.submit(Request(rid, prompt=prompt, max_new_tokens=args.max_new,
                              temperature=args.temperature))
    done = engine.run()
    wall = time.monotonic() - t0
    toks = sum(len(r.out_tokens) for r in done)
    lats = [r.finished_at - r.submitted_at for r in done]
    print(f"[serve] {cfg.name}: {len(done)} requests, {toks} tokens in "
          f"{wall:.2f}s ({toks / wall:.1f} tok/s, slots={args.slots}, "
          f"kv={'int8' if args.int8_kv else run.compute_dtype})")
    print(f"[serve] latency p50={np.percentile(lats, 50):.2f}s "
          f"p95={np.percentile(lats, 95):.2f}s")
    return done


if __name__ == "__main__":
    main()
