"""Trip-count-aware cost extraction from optimized HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — while
loops (every lax.scan: microbatch accumulation, scan-over-layers, blocked
attention) are counted per-iteration, underreporting FLOPs by the product
of trip counts (validated in tests/test_roofline.py). This module parses
the per-device optimized HLO and:

  1. builds the computation call graph (while bodies, fusions, calls,
     conditionals) with static trip counts recovered from each while
     condition's ``compare(iv, constant(N)), direction=LT``;
  2. charges every ``dot`` 2 * out_elems * contraction_size FLOPs and
     lhs+rhs+out bytes, every collective its ring wire bytes — each
     multiplied by the product of enclosing trip counts.

The result is the honest per-device roofline numerator set.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|"
    r"c64|c128)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*{")
_CALLED_RE = re.compile(
    r"(?:body|condition|to_apply|called_computations=\{[^}]*|true_computation|"
    r"false_computation|branch_computations=\{[^}]*)=?%?([\w.\-]+)")
_WHILE_RE = re.compile(r"\bwhile\(")
_DOT_RE = re.compile(r"\bdot\(([^)]*)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\-?\d+)\)")
_CMP_RE = re.compile(r"compare\(([^)]*)\).*direction=(\w+)")


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _nbytes(text: str) -> int:
    total = 0
    for dt, dims in _shape_dims(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _nelems(text: str) -> int:
    total = 0
    for _, dims in _shape_dims(text):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class Computation:
    name: str
    lines: List[str] = field(default_factory=list)
    defs: Dict[str, str] = field(default_factory=dict)  # op name -> rhs text


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1))
                if stripped.startswith("ENTRY"):
                    entry = cur.name
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(stripped)
        dm = _DEF_RE.match(stripped)
        if dm:
            cur.defs[dm.group(1)] = dm.group(2)
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Recover N from the while condition (lax.scan lowers to
    `iv < constant(N)`; XLA usually wraps the compare in a kLoop fusion with
    the constant as a fusion operand). Strategy: direct compare-operand
    lookup first, else the max constant defined in the condition — scan
    conditions contain exactly the bound (plus possibly 0/1 increments)."""
    consts: Dict[str, int] = {}
    for name, rhs in cond.defs.items():
        cm = _CONST_RE.search(rhs)
        if cm:
            consts[name] = int(cm.group(1))
    for rhs in cond.defs.values():
        m = _CMP_RE.search(rhs)
        if not m:
            continue
        ops = [o.strip().lstrip("%") for o in m.group(1).split(",")]
        ops = [o.split(" ")[-1].lstrip("%") for o in ops]
        for o in ops:
            if o in consts and consts[o] > 0:
                return consts[o]
    if consts:
        best = max(consts.values())
        if best >= 1:
            return best
    return 1


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, int]:
    """Effective execution count of each computation from the entry."""
    mult: Dict[str, int] = {}

    import sys
    old_limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old_limit, 10000))

    def visit(name: str, factor: int):
        if name not in comps:
            return
        # accumulate (a computation can be called from several sites)
        mult[name] = mult.get(name, 0) + factor
        comp = comps[name]
        for line in comp.lines:
            if _WHILE_RE.search(line):
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", line)
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if cond and cond in comps:
                    visit(cond, factor * (trips + 1))
                if body and body in comps:
                    visit(body, factor * trips)
            else:
                for m in re.finditer(
                        r"(?:to_apply|true_computation|false_computation|"
                        r"calls)=%?([\w.\-]+)", line):
                    visit(m.group(1), factor)
                m = re.search(r"called_computations=\{([^}]*)\}", line)
                if m:
                    for c in m.group(1).split(","):
                        visit(c.strip().lstrip("%"), factor)
        return

    visit(entry, 1)
    sys.setrecursionlimit(old_limit)
    return mult


@dataclass
class HloCosts:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, int] = field(default_factory=dict)
    n_while: int = 0
    max_mult: int = 1


def _operand_names(argtext: str) -> List[str]:
    names = []
    depth = 0
    cur = ""
    for ch in argtext:
        if ch == "(" or ch == "{" or ch == "[":
            depth += 1
        elif ch == ")" or ch == "}" or ch == "]":
            depth -= 1
        if ch == "," and depth == 0:
            names.append(cur.strip())
            cur = ""
        else:
            cur += ch
    if cur.strip():
        names.append(cur.strip())
    out = []
    for n in names:
        n = n.split(" ")[-1]
        out.append(n.lstrip("%"))
    return out


def analyze(hlo: str, n_devices: int) -> HloCosts:
    comps, entry = parse_computations(hlo)
    mult = _multipliers(comps, entry)
    costs = HloCosts()
    # global def map for operand shape lookup (names are module-unique)
    gdefs: Dict[str, str] = {}
    for comp in comps.values():
        gdefs.update(comp.defs)
        # parameters: "p = f32[..] parameter(0)" are in defs already
    for cname, comp in comps.items():
        factor = mult.get(cname, 0)
        if factor <= 0:
            continue
        costs.max_mult = max(costs.max_mult, factor)
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            out_type = rhs.split(" ")[0]
            d = _DOT_RE.search(rhs)
            if d and " dot(" in " " + rhs:
                out_elems = _nelems(out_type)
                ops = _operand_names(d.group(1))
                lhs_shape = _shape_dims(gdefs.get(ops[0], "")) if ops else []
                contract = 1
                cm = _CONTRACT_RE.search(rhs)
                if cm and lhs_shape:
                    dims = lhs_shape[0][1]
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            contract *= dims[int(idx)]
                flops = 2.0 * out_elems * contract
                io = _nbytes(out_type)
                for o in ops[:2]:
                    io += _nbytes(gdefs.get(o, "").split(" ")[0])
                costs.dot_flops += flops * factor
                costs.dot_bytes += io * factor
                continue
            c = _COLL_RE.search(rhs)
            if c and "-done" not in rhs[:40]:
                size = _nbytes(out_type)
                if size == 0:
                    continue
                kind = c.group(1)
                n = _group_size(rhs, n_devices)
                ring = (n - 1) / max(n, 1)
                if kind == "all-reduce":
                    wire = 2.0 * size * ring
                elif kind == "collective-permute":
                    wire = float(size)
                else:
                    wire = size * ring
                costs.coll_wire_bytes += wire * factor
                costs.coll_by_kind[kind] = \
                    costs.coll_by_kind.get(kind, 0.0) + wire * factor
                costs.coll_counts[kind] = \
                    costs.coll_counts.get(kind, 0) + factor
            if _WHILE_RE.search(rhs):
                costs.n_while += 1
    return costs


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices
