"""Training launcher.

CPU-runnable end to end with --reduced (the quickstart path); at full scale
the same flags drive the dry-run compile of the exact production job. The
Crispy HBM planner can be consulted first (--plan) to pick the mesh.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.configs import get_arch
from repro.configs.base import RunConfig
from repro.data.pipeline import ShardedLoader, SyntheticLMDataset
from repro.models.model import Model
from repro.optim import AdamWConfig
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    run = RunConfig(attn_impl="full" if args.seq <= 512 else "blocked",
                    remat="nothing", compute_dtype="float32",
                    microbatches=args.microbatches,
                    grad_compression=args.grad_compression)
    model = Model(cfg, run)
    acfg = AdamWConfig(lr=args.lr)
    state = init_train_state(model, jax.random.PRNGKey(args.seed), acfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(
        state.params))
    print(f"[launch] {cfg.name} ({'reduced' if args.reduced else 'full'}): "
          f"{n_params / 1e6:.2f}M params")

    step_fn = jax.jit(make_train_step(model, acfg, None,
                                      total_steps=args.steps),
                      donate_argnums=(0,))
    ds = SyntheticLMDataset(cfg.vocab_size, args.seed)
    loader = ShardedLoader(ds, args.batch, args.seq)

    def wrapped(state, batch):
        if cfg.family == "vlm":
            batch = dict(batch, media=np.zeros(
                (args.batch, cfg.cross_attn.n_media_tokens, cfg.d_model),
                np.float32))
        if cfg.family == "audio":
            batch = dict(batch, frames=np.zeros(
                (args.batch, cfg.encdec.enc_len, cfg.d_model), np.float32))
        return step_fn(state, batch)

    lcfg = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=10)
    state, report = train_loop(state, wrapped, loader, lcfg)
    print(f"[done] final loss {report.losses[-1]:.4f} "
          f"(first {report.losses[0]:.4f}) over {report.final_step} steps; "
          f"stragglers: {len(report.stragglers)}")
    return report


if __name__ == "__main__":
    main()
