"""Crispy §III-B: job profiling backends.

``RSSProfiler`` — the paper's literal method: run the job on this machine
while a background thread samples OS-level memory (/proc/self/statm and
/proc/meminfo), with aggressive garbage collection between samples (the
analogue of the paper's JVM NewRatio tuning, Fig. 4: measure live objects,
not allocator slack).

``XLACompileProfiler`` — the at-scale adaptation: "run" = AOT-compile a
scaled-down job and read XLA's buffer-assignment peak from
``compiled.memory_analysis()``. No accelerator needed; minutes per point;
the measured quantity is exactly the per-device working set the real job
would occupy.
"""
from __future__ import annotations

import ctypes
import gc
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

GiB = 1024 ** 3
_PAGE = os.sysconf("SC_PAGE_SIZE")

try:
    _LIBC = ctypes.CDLL("libc.so.6")
except OSError:                                    # non-glibc platforms
    _LIBC = None


def _malloc_trim():
    """Return freed arena pages to the OS so RSS tracks live memory.
    This is the userspace analogue of the paper's aggressive-GC tuning
    (Fig. 4): without it, consecutive profiling runs in one process read
    the allocator high-water mark, the memory(size) relation flattens and
    the R2 gate wrongly rejects linear jobs (measured in
    benchmarks/fig4_measurement_hygiene.py)."""
    if _LIBC is not None:
        try:
            _LIBC.malloc_trim(0)
        except Exception:
            pass


def _rss_bytes() -> int:
    with open("/proc/self/statm") as f:
        return int(f.read().split()[1]) * _PAGE


@dataclass
class ProfileResult:
    size: float                  # the scale knob value (bytes / tokens / ...)
    peak_mem_bytes: float        # measured peak
    base_mem_bytes: float        # pre-run baseline (subtracted by caller)
    wall_s: float
    trace: List[float] = field(default_factory=list)   # sampled series
    trace_t: List[float] = field(default_factory=list)

    @property
    def job_mem_bytes(self) -> float:
        """Paper: 'the system-wide allocated memory before the start of
        execution is captured and accounted for'."""
        return max(0.0, self.peak_mem_bytes - self.base_mem_bytes)

    def to_dict(self, with_trace: bool = False) -> dict:
        """JSON-safe form (allocator registry / profile caches persist
        these). Traces are dropped by default — they dominate the payload
        and only the scalar summary feeds the memory models."""
        d = {"size": self.size, "peak_mem_bytes": self.peak_mem_bytes,
             "base_mem_bytes": self.base_mem_bytes, "wall_s": self.wall_s}
        if with_trace:
            d["trace"] = list(self.trace)
            d["trace_t"] = list(self.trace_t)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ProfileResult":
        return cls(float(d["size"]), float(d["peak_mem_bytes"]),
                   float(d["base_mem_bytes"]), float(d["wall_s"]),
                   list(d.get("trace", [])), list(d.get("trace_t", [])))


class RSSProfiler:
    """Profile a python callable's peak RSS with a sampler thread."""

    def __init__(self, interval_s: float = 0.005, aggressive_gc: bool = True):
        self.interval_s = interval_s
        self.aggressive_gc = aggressive_gc

    def profile(self, job: Callable[[], object], size: float) -> ProfileResult:
        gc.collect()
        if self.aggressive_gc:
            _malloc_trim()
        base = _rss_bytes()
        peak = [base]
        trace: List[float] = []
        trace_t: List[float] = []
        stop = threading.Event()
        t0 = time.monotonic()

        def sampler():
            n = 0
            while not stop.is_set():
                rss = _rss_bytes()
                peak[0] = max(peak[0], rss)
                trace.append(rss)
                trace_t.append(time.monotonic() - t0)
                n += 1
                # aggressive GC: reclaim short-lived objects so the reading
                # tracks live use (paper Fig. 4). Do it sparsely — a full
                # collect per sample would distort the wall time it charges.
                if self.aggressive_gc and n % 20 == 0:
                    gc.collect(0)
                    _malloc_trim()
                time.sleep(self.interval_s)

        th = threading.Thread(target=sampler, daemon=True)
        th.start()
        try:
            job()
        finally:
            stop.set()
            th.join(timeout=1.0)
        wall = time.monotonic() - t0
        peak[0] = max(peak[0], _rss_bytes())
        return ProfileResult(size, float(peak[0]), float(base), wall,
                             trace, trace_t)


class XLACompileProfiler:
    """Profile per-device memory of a JAX step function by AOT compiling it
    against ShapeDtypeStructs — the 'single machine' profiling run of the
    TPU adaptation. ``job`` must return a lowered-compilable callable and
    its abstract inputs."""

    def profile(self, lower: Callable[[], object], size: float,
                donate_normalized: bool = True) -> ProfileResult:
        t0 = time.monotonic()
        compiled = lower()
        wall = time.monotonic() - t0
        ma = compiled.memory_analysis()
        peak = _memory_analysis_bytes(ma)
        return ProfileResult(size, float(peak), 0.0, wall)


def _memory_analysis_bytes(ma) -> float:
    """Total per-device bytes from an XLA memory analysis object: live
    arguments + outputs + temp + generated code. Argument/output aliasing
    (donation) is already reflected by XLA."""
    for attrs in (("argument_size_in_bytes", "output_size_in_bytes",
                   "temp_size_in_bytes", "generated_code_size_in_bytes",
                   "alias_size_in_bytes"),):
        try:
            arg = getattr(ma, attrs[0])
            out = getattr(ma, attrs[1])
            tmp = getattr(ma, attrs[2])
            gen = getattr(ma, attrs[3])
            alias = getattr(ma, attrs[4], 0)
            return float(arg + out + tmp + gen - alias)
        except AttributeError:
            continue
    return float("nan")
