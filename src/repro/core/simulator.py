"""Scout-like corpus simulator (evaluation substrate for Table I / Fig. 1).

The paper evaluates on the scout dataset (1031 Spark/Hadoop runs over 69 AWS
configs; github.com/oxhead/scout) which is not redistributable/offline, so
the Table-I benchmark runs against a *simulated* corpus with the same
structure: the 16 (algorithm x framework x dataset-size) jobs, the
{c,m,r} x {large,xlarge,2xlarge} x scale-out catalog, and a documented
cost model whose single essential property is the one the paper measures —
**a memory-bottleneck step function**:

    T = T_compute + T_io
    T_compute = cpu_hours / (total_cores ** alpha)          (alpha < 1:
                diminishing parallel returns)
    T_io      = passes * dataset / agg_disk_bw
    passes    = 1                              if job never caches
              = 1 + (iters-1) * miss_fraction  if caching job
    miss_fraction = max(0, 1 - usable_mem / working_set)

so a caching, iterative job falls off a cost cliff exactly when the working
set stops fitting in usable cluster memory — Fig. 1's shape. cost = T * $/h.

Profiling traces are generated per job from its declared memory profile:
  linear —  mem(s) = ws_factor*s + jvm_base (+0.2% noise): R2 > .99, Crispy
            extrapolates (K-Means, Naive Bayes, PageRank-on-Spark);
  noisy  —  same slope but 6-12% multiplicative noise from 'rapidly
            generated objects' (paper §III-C): fails the gate (Log./Lin.
            Regression);
  flat   —  memory independent of input (Hadoop jobs, streaming sort/join):
            R2 of a flat+noise series fails the gate, requirement 0.

The validated claims are structural (bench/table1): cost(Crispy) <=
cost(BFA) per job, integer-factor wins on bottleneck-prone jobs, graceful
fallback elsewhere — not the paper's exact 56%, which is a property of
their private measurements.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass
from typing import Callable, Dict, List

import numpy as np

from repro.core.catalog import ClusterConfig, aws_like_catalog
from repro.core.history import Execution, ExecutionHistory
from repro.core.profiler import ProfileResult

GiB = 1024 ** 3

ALPHA = 0.95            # parallel-efficiency exponent (data-parallel jobs
                        # scale near-linearly; cost is then ~flat in cores
                        # and memory effects dominate — the scout regime)
DISK_BW_GIB_S = 0.05    # per-node effective scan bandwidth (HDD-era, HiBench)
SPILL_PENALTY = 4.0     # spill/recompute passes cost more than a clean scan
JVM_BASE_GIB = 1.6      # profiling-machine framework baseline
OVERHEAD_GIB = 2.0      # per-node OS+framework (paper §III-D)


@dataclass(frozen=True)
class JobSpec:
    name: str
    framework: str          # spark | hadoop
    dataset_gib: float
    cpu_hours: float        # total compute work
    working_set_factor: float   # cached bytes per input byte
    iterations: int         # data passes (iterative ML jobs re-read)
    caching: bool           # Spark RDD caching (Hadoop: never)
    mem_profile: str        # linear | noisy | flat

    @property
    def working_set_gib(self) -> float:
        return self.working_set_factor * self.dataset_gib


def scout_like_jobs() -> List[JobSpec]:
    J = JobSpec
    return [
        # name                 fw      GiB   cpuh  wsf  iters cache profile
        J("naivebayes/spark/bigdata", "spark", 300, 10.0, 0.9, 4, True, "linear"),
        J("naivebayes/spark/huge", "spark", 90, 3.2, 0.9, 4, True, "linear"),
        # K-Means caches *deserialized* vectors: JVM object overhead makes
        # the working set several times the on-disk bytes — this is what
        # puts the Fig. 1 cliff beyond BFA's aggregate memory
        J("kmeans/spark/bigdata", "spark", 240, 14.0, 4.5, 12, True, "linear"),
        J("kmeans/spark/huge", "spark", 72, 4.5, 4.5, 12, True, "linear"),
        J("linregression/spark/bigdata", "spark", 360, 8.0, 1.0, 6, True, "noisy"),
        J("linregression/spark/huge", "spark", 110, 2.6, 1.0, 6, True, "noisy"),
        J("logregression/spark/bigdata", "spark", 300, 12.0, 1.1, 10, True, "noisy"),
        J("logregression/spark/huge", "spark", 90, 3.8, 1.1, 10, True, "noisy"),
        J("pagerank/spark/bigdata", "spark", 60, 16.0, 2.4, 8, True, "linear"),
        J("pagerank/spark/huge", "spark", 18, 5.0, 2.4, 8, True, "linear"),
        J("join/spark/bigdata", "spark", 420, 6.0, 0.25, 1, False, "flat"),
        J("join/spark/huge", "spark", 130, 1.9, 0.25, 1, False, "flat"),
        J("pagerank/hadoop/bigdata", "hadoop", 60, 20.0, 0.0, 8, False, "flat"),
        J("pagerank/hadoop/huge", "hadoop", 18, 6.5, 0.0, 8, False, "flat"),
        J("terasort/hadoop/bigdata", "hadoop", 900, 9.0, 0.0, 3, False, "flat"),
        J("terasort/hadoop/huge", "hadoop", 280, 3.0, 0.0, 3, False, "flat"),
    ]


# ---------------------------------------------------------------------------
# ground-truth cost model
# ---------------------------------------------------------------------------


def runtime_s(job: JobSpec, cfg: ClusterConfig) -> float:
    cores = cfg.total_cores
    t_compute = job.cpu_hours * 3600.0 / (cores ** ALPHA)
    usable = cfg.usable_mem_gib(OVERHEAD_GIB)
    if job.caching and job.working_set_gib > 0:
        miss = max(0.0, 1.0 - usable / job.working_set_gib)
        # misses re-read AND spill: each missed pass costs SPILL_PENALTY
        # scans (write-out + read-back + recompute) — the Fig. 1 cliff
        passes = 1.0 + (job.iterations - 1) * miss * SPILL_PENALTY
    else:
        passes = float(job.iterations)
    agg_bw = DISK_BW_GIB_S * cfg.scale_out
    t_io = passes * job.dataset_gib / agg_bw
    # fixed per-job startup (scheduling, JVM spin-up) grows mildly w/ nodes
    t_start = 30.0 + 0.5 * cfg.scale_out
    return t_compute + t_io + t_start


def cost_usd(job: JobSpec, cfg: ClusterConfig) -> float:
    return runtime_s(job, cfg) / 3600.0 * cfg.usd_per_hour


def build_history(jobs: List[JobSpec] = None,
                  catalog: List[ClusterConfig] = None) -> ExecutionHistory:
    jobs = jobs or scout_like_jobs()
    catalog = catalog or aws_like_catalog()
    hist = ExecutionHistory()
    for j in jobs:
        for c in catalog:
            t = runtime_s(j, c)
            hist.add(Execution(j.name, c.name, t, t / 3600.0 * c.usd_per_hour))
    return hist


# ---------------------------------------------------------------------------
# synthetic profiling traces (what the laptop would have measured)
# ---------------------------------------------------------------------------


def make_profile_fn(job: JobSpec, seed: int = 0) -> Callable[[float],
                                                             ProfileResult]:
    def profile_at(size_bytes: float) -> ProfileResult:
        # deterministic per (job, size) ACROSS processes: crc32, not
        # hash() — string hashing is randomized per interpreter
        # (PYTHONHASHSEED), which made the noisy jobs' gate outcome flaky
        key = f"{job.name}|{seed}|{round(size_bytes)}".encode()
        rng = np.random.default_rng(zlib.crc32(key))
        s_gib = size_bytes / GiB
        base = JVM_BASE_GIB * GiB
        if job.mem_profile == "linear":
            mem = job.working_set_factor * size_bytes
            mem *= 1.0 + rng.normal(0.0, 0.002)
        elif job.mem_profile == "noisy":
            mem = job.working_set_factor * size_bytes
            mem *= 1.0 + rng.normal(0.0, 0.09) + 0.08 * math.sin(s_gib * 17.0)
        else:  # flat
            mem = 0.35 * GiB * (1.0 + rng.normal(0.0, 0.08))
        wall = 20.0 + 40.0 * s_gib     # seconds; matches paper's 0.5-3 min/run
        return ProfileResult(size_bytes, base + max(mem, 0.0), base, wall)

    return profile_at
