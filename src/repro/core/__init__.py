from repro.core.memory_model import LinearMemoryModel, fit_memory_model, R2_GATE
from repro.core.crispy import CrispyAllocator, CrispyReport, ModelFitter
from repro.core.selector import (Selection, select_bfa, select_crispy,
                                 select_like, select_medium,
                                 random_expected_cost)
from repro.core.catalog import (ClusterConfig, NodeType, aws_like_catalog,
                                tpu_catalog, medium_config)
from repro.core.history import Execution, ExecutionHistory
from repro.core.profiler import RSSProfiler, XLACompileProfiler, ProfileResult
