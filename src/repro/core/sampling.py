"""Crispy §III-A step 1 / §III-B: the 5-point sample-size ladder.

The paper: start from ~1% of the dataset, adjust so one profiling run takes
0.5–3 minutes, then take five equally spaced sizes up to that anchor. For
the XLA-compile backend the 'runtime' is compile time, and the knob is a
job-size parameter (tokens per device, layer count) instead of input bytes;
the ladder logic is identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Sequence

N_SAMPLES = 5                    # paper: five differently sized samples


@dataclass
class Ladder:
    sizes: List[float]
    anchor: float


def ladder_from_anchor(anchor: float, n: int = N_SAMPLES,
                       lo_frac: float = 0.2) -> Ladder:
    """Equally spaced sizes in [lo_frac*anchor, anchor] (paper: 'equally
    spaced and reasonably far apart')."""
    lo = anchor * lo_frac
    step = (anchor - lo) / (n - 1)
    return Ladder([lo + i * step for i in range(n)], anchor)


def calibrate_anchor(run_at_size: Callable[[float], float],
                     initial: float,
                     target_lo_s: float = 0.5,
                     target_hi_s: float = 30.0,
                     max_iters: int = 6) -> float:
    """Adjust the anchor size until a run's wall time lands in the target
    band (paper: cancel & restart with a smaller portion if too slow). The
    default band is scaled down from the paper's 30–180 s to keep the bench
    suite fast; the paper's band is a parameter."""
    size = initial
    for _ in range(max_iters):
        wall = run_at_size(size)
        if wall > target_hi_s:
            size *= max(0.25, (target_hi_s * 0.6) / wall)
        elif wall < target_lo_s:
            size *= min(4.0, (target_lo_s * 2.0) / max(wall, 1e-6))
        else:
            return size
    return size


def integer_ladder(anchor: int, n: int = N_SAMPLES, lo: int = 1) -> List[int]:
    """Ladder over an integer knob (layers, microbatch rows, ...)."""
    lo = max(lo, 1)
    if anchor <= lo:
        return [max(1, anchor)]
    step = (anchor - lo) / (n - 1)
    sizes = sorted({int(round(lo + i * step)) for i in range(n)})
    return sizes
