"""Crispy orchestration (paper §III-A): sample -> profile -> model -> select.

`CrispyAllocator` is backend-agnostic: give it a `profile_at(size)` callable
(RSS-based for local dataflow jobs, XLA-compile-based for TPU jobs via
core/hbm_planner.py) and a full-size target, and it runs the paper's four
steps end to end.

The modeling step is pluggable: `fitter(sizes, mems)` must return an object
with `requirement(full_size, leeway)` and `confident` (the memory-model
interface of core/memory_model.py). The default is the paper's OLS linear
fit; pass `repro.allocator.model_zoo.zoo_fitter()` for the multi-candidate
model zoo.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence

from repro.core.catalog import ClusterConfig
from repro.core.history import ExecutionHistory
from repro.core.memory_model import fit_memory_model
from repro.core.profiler import ProfileResult
from repro.core.sampling import Ladder, ladder_from_anchor
from repro.core.selector import (DEFAULT_OVERHEAD_GIB, Selection,
                                 select_crispy)

GiB = 1024 ** 3

# (sizes, mems) -> memory model (predict/confident/requirement)
ModelFitter = Callable[[Sequence[float], Sequence[float]], Any]


@dataclass
class CrispyReport:
    job: str
    sizes: List[float]
    mems_bytes: List[float]
    model: Any                       # LinearMemoryModel or a zoo model
    requirement_gib: float
    selection: Selection
    profiling_wall_s: float
    results: List[ProfileResult] = field(default_factory=list)
    early_stop: bool = False         # adaptive: stopped before the ladder end
    escalated: bool = False          # adaptive: spent extra points
    budget_exhausted: bool = False   # a point was denied by the budget

    @property
    def points_profiled(self) -> int:
        return len(self.sizes)


class CrispyAllocator:
    def __init__(self, catalog: List[ClusterConfig],
                 history: ExecutionHistory,
                 overhead_per_node_gib: float = DEFAULT_OVERHEAD_GIB,
                 leeway: float = 0.0,
                 fitter: ModelFitter = fit_memory_model):
        self.catalog = catalog
        self.history = history
        self.overhead = overhead_per_node_gib
        self.leeway = leeway
        self.fitter = fitter

    def allocate(self, job: str,
                 profile_at: Callable[[float], ProfileResult],
                 full_size: float,
                 anchor: Optional[float] = None,
                 sizes: Optional[List[float]] = None,
                 exclude_job_in_history: bool = True,
                 adaptive: bool = False,
                 budget=None,
                 store=None) -> CrispyReport:
        """Paper steps 1-4. With `adaptive=True` (or a
        `repro.profiling.ProfilingBudget` passed as `budget=`) the ladder
        runs through the AdaptiveLadderScheduler: smallest point first,
        refit after each, early stop once the model is confident and its
        requirement prediction has stabilized — strictly fewer profile
        runs than the fixed ladder on clean jobs, same fallback behavior
        on noisy ones.

        `store=` (a `repro.profiling.ProfileStore`, over any
        `repro.state` backend) makes the one-shot path a shared-state
        citizen too: ladder points and calibrated anchors profiled by any
        process are reused instead of re-measured, and fresh points are
        written back. Pass `budget=ProfilingBudget(..., backend=...)` to
        arbitrate one cross-process envelope as well."""
        t0 = time.monotonic()
        if sizes is None:
            if anchor is None and store is not None:
                anchor = store.get_anchor(job)
            elif anchor is not None and store is not None \
                    and store.get_anchor(job) is None:
                store.put_anchor(job, float(anchor))
            ladder = ladder_from_anchor(anchor if anchor is not None
                                        else full_size * 0.01)
            sizes = ladder.sizes

        def point(s: float):
            if store is not None:
                cached = store.get(job, s)
                if cached is not None:
                    return cached, False
            r = profile_at(s)
            if store is not None:
                store.put(job, s, r)
            return r, True
        if store is not None:
            point.peek = lambda s: store.get(job, s)

        if adaptive or budget is not None:
            # deferred import: repro.profiling depends on allocator modules
            from repro.profiling.scheduler import AdaptiveLadderScheduler
            sched = AdaptiveLadderScheduler(fitter=self.fitter,
                                            budget=budget)
            ap = sched.run(sizes, full_size, point)
            sizes, mems, results = ap.sizes, ap.mems, ap.results
            model = ap.fit
            flags = (ap.early_stop, ap.escalated, ap.budget_exhausted)
        else:
            results = [point(s)[0] for s in sizes]
            mems = [r.job_mem_bytes for r in results]
            model = self.fitter(sizes, mems)
            flags = (False, False, False)
        req_gib = model.requirement(full_size, self.leeway) / GiB
        sel = select_crispy(
            self.catalog, self.history, req_gib,
            overhead_per_node_gib=self.overhead,
            exclude_job=job if exclude_job_in_history else None)
        wall = time.monotonic() - t0
        return CrispyReport(job, list(sizes), mems, model, req_gib, sel,
                            wall, results, *flags)
