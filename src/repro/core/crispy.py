"""Crispy orchestration (paper §III-A): sample -> profile -> model -> select.

`CrispyAllocator` is backend-agnostic: give it a `profile_at(size)` callable
(RSS-based for local dataflow jobs, XLA-compile-based for TPU jobs via
core/hbm_planner.py) and a full-size target, and it runs the paper's four
steps end to end.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.core.catalog import ClusterConfig
from repro.core.history import ExecutionHistory
from repro.core.memory_model import LinearMemoryModel, fit_memory_model
from repro.core.profiler import ProfileResult
from repro.core.sampling import Ladder, ladder_from_anchor
from repro.core.selector import (DEFAULT_OVERHEAD_GIB, Selection,
                                 select_crispy)

GiB = 1024 ** 3


@dataclass
class CrispyReport:
    job: str
    sizes: List[float]
    mems_bytes: List[float]
    model: LinearMemoryModel
    requirement_gib: float
    selection: Selection
    profiling_wall_s: float
    results: List[ProfileResult] = field(default_factory=list)


class CrispyAllocator:
    def __init__(self, catalog: List[ClusterConfig],
                 history: ExecutionHistory,
                 overhead_per_node_gib: float = DEFAULT_OVERHEAD_GIB,
                 leeway: float = 0.0):
        self.catalog = catalog
        self.history = history
        self.overhead = overhead_per_node_gib
        self.leeway = leeway

    def allocate(self, job: str,
                 profile_at: Callable[[float], ProfileResult],
                 full_size: float,
                 anchor: Optional[float] = None,
                 sizes: Optional[List[float]] = None,
                 exclude_job_in_history: bool = True) -> CrispyReport:
        t0 = time.monotonic()
        if sizes is None:
            ladder = ladder_from_anchor(anchor if anchor is not None
                                        else full_size * 0.01)
            sizes = ladder.sizes
        results = [profile_at(s) for s in sizes]
        mems = [r.job_mem_bytes for r in results]
        model = fit_memory_model(sizes, mems)
        req_gib = model.requirement(full_size, self.leeway) / GiB
        sel = select_crispy(
            self.catalog, self.history, req_gib,
            overhead_per_node_gib=self.overhead,
            exclude_job=job if exclude_job_in_history else None)
        wall = time.monotonic() - t0
        return CrispyReport(job, list(sizes), mems, model, req_gib, sel,
                            wall, results)
