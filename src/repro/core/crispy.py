"""Crispy orchestration (paper §III-A): sample -> profile -> model -> select.

`CrispyAllocator` is the one-shot convenience wrapper over the unified
`repro.pipeline.AllocationPipeline` — the same staged decision path the
batched `AllocationService` drives (see repro/pipeline/__init__.py for
the stage diagram). Give it a `profile_at(size)` callable (RSS-based for
local dataflow jobs, XLA-compile-based for TPU jobs via
core/hbm_planner.py) and a full-size target, and it runs the paper's four
steps end to end, returning a `CrispyReport` built from the shared
`PipelineTrace`.

The modeling step is pluggable: `fitter(sizes, mems)` must return an
object with `requirement(full_size, leeway)` and `confident` (the
memory-model interface of core/memory_model.py). The default is the
paper's OLS linear fit; pass `repro.allocator.model_zoo.zoo_fitter()` for
the multi-candidate model zoo (which also unlocks information-optimal
point placement — `placement="infogain"` needs candidate models to
disagree about).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Callable, List, Optional, Sequence

from repro.core.catalog import ClusterConfig
from repro.core.history import ExecutionHistory
from repro.core.memory_model import fit_memory_model
from repro.core.profiler import ProfileResult
from repro.core.selector import DEFAULT_OVERHEAD_GIB, Selection

if TYPE_CHECKING:       # runtime import is deferred: repro.pipeline's
    # acquisition stage imports repro.core submodules
    from repro.pipeline import AllocationPipeline, PipelineTrace

GiB = 1024 ** 3

# (sizes, mems) -> memory model (predict/confident/requirement)
ModelFitter = Callable[[Sequence[float], Sequence[float]], Any]


@dataclass
class CrispyReport:
    job: str
    sizes: List[float]
    mems_bytes: List[float]
    model: Any                       # LinearMemoryModel or a zoo model
    requirement_gib: float
    selection: Selection
    profiling_wall_s: float
    results: List[ProfileResult] = field(default_factory=list)
    early_stop: bool = False         # adaptive: stopped before the ladder end
    escalated: bool = False          # adaptive: spent extra points
    budget_exhausted: bool = False   # a point was denied by the budget
    trace: Optional[PipelineTrace] = None    # the full staged-path record
    runtime_model: Any = None        # runtime companion fit (feeds the
                                     # min_cost/min_runtime objectives)

    @property
    def points_profiled(self) -> int:
        return len(self.sizes)

    @classmethod
    def from_trace(cls, trace: "PipelineTrace") -> "CrispyReport":
        plan = trace.plan
        return cls(trace.job, list(plan.sizes), list(plan.mems),
                   plan.fit if plan.fit is not None else plan.model,
                   trace.requirement_gib, trace.selection, trace.wall_s,
                   list(plan.results), plan.early_stop, plan.escalated,
                   plan.budget_exhausted, trace,
                   runtime_model=plan.runtime_fit)


class CrispyAllocator:
    def __init__(self, catalog: List[ClusterConfig],
                 history: ExecutionHistory,
                 overhead_per_node_gib: float = DEFAULT_OVERHEAD_GIB,
                 leeway: float = 0.0,
                 fitter: ModelFitter = fit_memory_model,
                 placement="infogain"):
        self.catalog = catalog
        self.history = history
        self.overhead = overhead_per_node_gib
        self.leeway = leeway
        self.fitter = fitter
        self.placement = placement

    def _pipeline(self, budget=None, store=None) -> "AllocationPipeline":
        from repro.pipeline import AllocationPipeline
        return AllocationPipeline(
            self.catalog, self.history, fitter=self.fitter,
            overhead_per_node_gib=self.overhead, leeway=self.leeway,
            placement=self.placement, budget=budget, store=store)

    def allocate(self, job: str,
                 profile_at: Callable[[float], ProfileResult],
                 full_size: float,
                 anchor: Optional[float] = None,
                 sizes: Optional[List[float]] = None,
                 exclude_job_in_history: bool = True,
                 adaptive: bool = False,
                 budget=None,
                 store=None,
                 placement=None,
                 objective: str = "cheapest_fit") -> CrispyReport:
        """Paper steps 1-4 through the unified pipeline. With
        `adaptive=True` (or a `repro.profiling.ProfilingBudget` passed as
        `budget=`) point placement is strategy-driven: the default
        `placement="infogain"` profiles whichever size is expected to
        shrink candidate-model disagreement at full size the most and
        stops when further measurement would not change the answer;
        `placement="ladder"` keeps the PR-2 smallest-first prefix with
        gap-midpoint escalation. Both profile strictly fewer points than
        the fixed ladder on clean jobs and fall back identically on noisy
        ones.

        `store=` (a `repro.profiling.ProfileStore`, over any
        `repro.state` backend) makes the one-shot path a shared-state
        citizen too: ladder points and calibrated anchors profiled by any
        process are reused instead of re-measured (the acquisition stage
        refreshes the store, so sibling points are never double-charged),
        and fresh points are written back. Pass
        `budget=ProfilingBudget(..., backend=...)` to arbitrate one
        cross-process envelope as well."""
        from repro.pipeline import PipelineRequest
        pipeline = self._pipeline(budget=budget, store=store)
        trace = pipeline.run(PipelineRequest(
            job, profile_at, full_size, anchor=anchor, sizes=sizes,
            adaptive=adaptive or budget is not None,
            placement=placement,
            exclude_job_in_history=exclude_job_in_history,
            objective=objective))
        return CrispyReport.from_trace(trace)
