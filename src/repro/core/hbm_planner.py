"""Crispy for TPU slices: the paper's pipeline applied to mesh selection.

Paper step                      ->  here
1. five small dataset samples   ->  five reduced-DEPTH variants of the job
                                    (n_layers ladder; same family, same
                                    shape — depth is the knob per-device
                                    memory is linear in: layer params +
                                    optimizer state + activation stash)
2. profile on a single machine  ->  AOT-compile each variant on this CPU
                                    host against a small profile mesh and
                                    read compiled.memory_analysis()
3. OLS + R^2 > .99 gate         ->  identical (core/memory_model.py)
4. pick cheapest feasible config->  BFA over the TPU catalog restricted to
                                    configs with enough aggregate HBM

The extrapolation target is aggregate HBM = per-device bytes x devices,
the analogue of the paper's total-cluster-memory requirement; per-chip
feasibility is additionally checked on the (divided) per-device estimate.
Validation against ground-truth full compiles: EXPERIMENTS.md §Planner.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax

from repro.configs.base import MeshConfig, ModelConfig, RunConfig, ShapeConfig
from repro.core.catalog import ClusterConfig, NodeType, tpu_catalog
from repro.core.history import ExecutionHistory
from repro.core.memory_model import LinearMemoryModel, fit_memory_model
from repro.core.sampling import integer_ladder
from repro.core.selector import Selection, select_bfa

GiB = 1024 ** 3
TPU_OVERHEAD_GIB = 1.25       # XLA runtime / infeed / collective scratch


def _reduced_depth(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    """Same architecture, fewer layers (hybrid/vlm keep group structure)."""
    if cfg.hybrid is not None:
        period = cfg.hybrid.period
        n_layers = max(period, (n_layers // period) * period)
    if cfg.cross_attn is not None:
        period = cfg.cross_attn.period
        n_layers = max(period, (n_layers // period) * period)
    return dataclasses.replace(cfg, n_layers=n_layers)


@dataclass
class PlanReport:
    job: str
    ladder: List[int]
    per_dev_bytes: List[float]
    model: LinearMemoryModel
    predicted_per_dev_gib: float      # at full depth, on the profile mesh
    requirement_gib: float            # aggregate, extrapolated
    selection: Optional[Selection]
    profile_wall_s: float
    profile_mesh_devices: int


class HBMPlanner:
    def __init__(self, catalog: Optional[List[ClusterConfig]] = None,
                 history: Optional[ExecutionHistory] = None,
                 overhead_gib: float = TPU_OVERHEAD_GIB,
                 leeway: float = 0.05):
        self.catalog = catalog if catalog is not None else tpu_catalog()
        self.history = history
        self.overhead = overhead_gib
        self.leeway = leeway

    # -- profiling ----------------------------------------------------------
    def profile_memory(self, cfg: ModelConfig, shape: ShapeConfig, mesh,
                       run: Optional[RunConfig] = None) -> float:
        """Per-device bytes of the job's step on `mesh` via AOT compile."""
        from repro.launch.dryrun import build_lowered
        lowered, _ = build_lowered(cfg, shape, mesh, run)
        ma = lowered.compile().memory_analysis()
        return float(ma.argument_size_in_bytes + ma.output_size_in_bytes +
                     ma.temp_size_in_bytes - ma.alias_size_in_bytes)

    def plan(self, cfg: ModelConfig, shape: ShapeConfig, profile_mesh,
             run: Optional[RunConfig] = None,
             anchor_layers: Optional[int] = None,
             select: bool = True) -> PlanReport:
        t0 = time.monotonic()
        n_dev = profile_mesh.devices.size
        anchor = anchor_layers or max(2, min(cfg.n_layers // 4, 12))
        # lo >= 2: a length-1 scan is inlined by XLA and its buffer liveness
        # differs from the scanned steady state — the analogue of the
        # paper's "sample large enough that startup doesn't dominate"
        lo = 2
        if cfg.hybrid is not None:
            lo = cfg.hybrid.period
            anchor = max(anchor, 3 * lo)
        if cfg.cross_attn is not None:
            lo = cfg.cross_attn.period
            anchor = max(anchor, 3 * lo)
        ladder = integer_ladder(anchor, n=5, lo=lo)
        mems = []
        for L in ladder:
            small = _reduced_depth(cfg, L)
            mems.append(self.profile_memory(small, shape, profile_mesh, run))
        # fit vs the *effective* layer counts after family rounding
        eff = [_reduced_depth(cfg, L).n_layers for L in ladder]
        model = fit_memory_model(eff, mems)
        pred_dev = model.requirement(cfg.n_layers, self.leeway)
        req_gib = pred_dev * n_dev / GiB
        wall = time.monotonic() - t0
        sel = None
        if select:
            sel = self.select(req_gib, pred_dev / GiB if model.confident
                              else 0.0, job=f"{cfg.name}:{shape.name}")
        return PlanReport(f"{cfg.name}:{shape.name}", list(eff), mems, model,
                          pred_dev / GiB, req_gib, sel, wall, n_dev)

    # -- selection ------------------------------------------------------------
    def select(self, requirement_gib: float, per_dev_gib_at_profile: float,
               job: str = "") -> Selection:
        feasible = []
        for c in self.catalog:
            usable = c.usable_mem_gib(self.overhead)
            if usable < requirement_gib:
                continue
            # per-chip check: aggregate requirement divided over this slice
            if requirement_gib > 0 and \
                    requirement_gib / c.scale_out > c.node.mem_gib - self.overhead:
                continue
            feasible.append(c)
        fell_back = requirement_gib <= 0.0
        if not feasible:
            feasible = sorted(
                self.catalog,
                key=lambda c: -c.usable_mem_gib(self.overhead))[:1]
            fell_back = True
        if self.history is not None:
            cfg = select_bfa(feasible, self.history, exclude_job=job)
        else:
            cfg = min(feasible, key=lambda c: c.usd_per_hour)
        return Selection(cfg, "crispy-hbm", requirement_gib, len(feasible),
                         fell_back)
