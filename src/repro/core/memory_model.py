"""Crispy §III-C: memory usage modeling.

Ordinary least squares `mem = a * size + b` over the profiling samples, with
the paper's train-set R² > 0.99 linearity gate. No sklearn — the closed form
is two lines and this *is* the paper's model (LinearRegression + r2_score).

`LinearMemoryModel` is also the reference implementation of the memory-model
interface the allocator subsystem generalizes over (repro/allocator/
model_zoo.py): `predict(size)`, `confident`, `requirement(full_size, leeway)`
plus `to_dict`/`from_dict` for the persistent model registry.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import ClassVar, Dict, Optional, Sequence, Tuple

import numpy as np

R2_GATE = 0.99          # paper §III-A step 3


def ols_fit(x: np.ndarray, y: np.ndarray) -> Optional[Tuple[float, float]]:
    """Closed-form OLS `(slope, intercept)`; None for degenerate x (<2
    points or no spread) — shared by the paper's model and every zoo
    candidate that fits a line in some transformed space."""
    if x.size < 2 or np.allclose(x, x[0]):
        return None
    xm, ym = x.mean(), y.mean()
    sxx = float(((x - xm) ** 2).sum())
    slope = float(((x - xm) * (y - ym)).sum()) / sxx
    return slope, float(ym - slope * xm)


def r2_score(y: np.ndarray, pred: np.ndarray) -> float:
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    if ss_tot == 0.0:
        # flat target: a constant-memory job; the fit is exact iff residuals
        # are zero, in which case extrapolation is trivially safe. Plain
        # Python -inf (not np.float64): the gate path compares against
        # Python floats and the value must survive JSON round-trips of
        # registry records exactly.
        return 1.0 if ss_res == 0.0 else -math.inf
    return 1.0 - ss_res / ss_tot


class GatedMemoryModel:
    """Gate + clamp semantics every memory model shares: extrapolate only
    when the train fit is (near-)perfect, and clamp a negative
    extrapolation (negative intercept at small full_size) to 0 rather than
    crediting memory back. Subclasses provide `r2` and `predict`."""

    @property
    def confident(self) -> bool:
        return self.r2 > R2_GATE

    def requirement(self, full_size: float, leeway: float = 0.0) -> float:
        """Total memory requirement for the full dataset (0 if the model is
        not confident — Crispy then degenerates to the BFA baseline)."""
        if not self.confident:
            return 0.0
        return max(0.0, self.predict(full_size)) * (1.0 + leeway)


@dataclass
class LinearMemoryModel(GatedMemoryModel):
    slope: float
    intercept: float
    r2: float
    n: int

    kind: ClassVar[str] = "linear"

    def predict(self, size: float) -> float:
        return self.slope * size + self.intercept

    def to_dict(self) -> Dict:
        return {"kind": self.kind, "slope": self.slope,
                "intercept": self.intercept, "r2": self.r2, "n": self.n}

    @classmethod
    def from_dict(cls, d: Dict) -> "LinearMemoryModel":
        return cls(float(d["slope"]), float(d["intercept"]),
                   float(d["r2"]), int(d["n"]))


def fit_memory_model(sizes: Sequence[float],
                     mems: Sequence[float]) -> LinearMemoryModel:
    x = np.asarray(sizes, dtype=np.float64)
    y = np.asarray(mems, dtype=np.float64)
    coef = ols_fit(x, y)
    if coef is None:
        return LinearMemoryModel(0.0, float(y.mean()) if y.size else 0.0,
                                 -math.inf, int(x.size))
    slope, intercept = coef
    r2 = r2_score(y, slope * x + intercept)
    return LinearMemoryModel(slope, intercept, r2, int(x.size))
