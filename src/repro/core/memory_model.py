"""Crispy §III-C: memory usage modeling.

Ordinary least squares `mem = a * size + b` over the profiling samples, with
the paper's train-set R² > 0.99 linearity gate. No sklearn — the closed form
is two lines and this *is* the paper's model (LinearRegression + r2_score).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

R2_GATE = 0.99          # paper §III-A step 3


@dataclass
class LinearMemoryModel:
    slope: float
    intercept: float
    r2: float
    n: int

    @property
    def confident(self) -> bool:
        """Paper's gate: extrapolate only if the fit is (near-)perfectly
        linear on its own training points."""
        return self.r2 > R2_GATE

    def predict(self, size: float) -> float:
        return self.slope * size + self.intercept

    def requirement(self, full_size: float, leeway: float = 0.0) -> float:
        """Total memory requirement for the full dataset (0 if the model is
        not confident — Crispy then degenerates to the BFA baseline)."""
        if not self.confident:
            return 0.0
        return max(0.0, self.predict(full_size)) * (1.0 + leeway)


def fit_memory_model(sizes: Sequence[float],
                     mems: Sequence[float]) -> LinearMemoryModel:
    x = np.asarray(sizes, dtype=np.float64)
    y = np.asarray(mems, dtype=np.float64)
    if x.size < 2 or np.allclose(x, x[0]):
        return LinearMemoryModel(0.0, float(y.mean()) if y.size else 0.0,
                                 -np.inf, int(x.size))
    xm, ym = x.mean(), y.mean()
    sxx = float(((x - xm) ** 2).sum())
    sxy = float(((x - xm) * (y - ym)).sum())
    slope = sxy / sxx
    intercept = ym - slope * xm
    pred = slope * x + intercept
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - ym) ** 2).sum())
    if ss_tot == 0.0:
        # flat target: a constant-memory job; the fit is exact iff residuals
        # are zero, in which case extrapolation is trivially safe
        r2 = 1.0 if ss_res == 0.0 else -np.inf
    else:
        r2 = 1.0 - ss_res / ss_tot
    return LinearMemoryModel(slope, intercept, r2, int(x.size))
