"""Cluster configuration catalogs.

Two catalogs, one per evaluation half (DESIGN.md §2):

* ``aws_like_catalog()`` — the paper's search space: {c,m,r} x {large,
  xlarge, 2xlarge} x scale-outs 4..48 (the scout dataset's 69 configs were
  drawn from this space). Memory/core and $/h follow the c4/m4/r4 families
  the paper used (us-east-1 on-demand list prices, 2017-era to match scout).

* ``tpu_catalog()`` — the at-scale analogue: chip generations (node types)
  x slice sizes (scale-outs). HBM/chip, peak bf16 FLOP/s and $/chip-h from
  public list prices. The v5e numbers (16 GB, 197 TFLOP/s, 819 GB/s) are the
  roofline constants used throughout EXPERIMENTS.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

GiB = 1024 ** 3


@dataclass(frozen=True)
class NodeType:
    name: str
    cores: int               # cores (VMs) / chips-per-host (TPU)
    mem_gib: float           # memory per node (VM RAM / TPU HBM per chip)
    usd_per_hour: float
    peak_tflops: float = 0.0     # accelerators only
    hbm_gbps: float = 0.0
    ici_gbps: float = 0.0


@dataclass(frozen=True)
class ClusterConfig:
    node: NodeType
    scale_out: int           # number of nodes (VMs / chips)

    @property
    def name(self) -> str:
        return f"{self.node.name}x{self.scale_out}"

    @property
    def total_mem_gib(self) -> float:
        return self.node.mem_gib * self.scale_out

    @property
    def total_cores(self) -> int:
        return self.node.cores * self.scale_out

    @property
    def usd_per_hour(self) -> float:
        return self.node.usd_per_hour * self.scale_out

    def usable_mem_gib(self, overhead_per_node_gib: float) -> float:
        """Paper §III-D: subtract the fixed per-node OS/framework overhead
        (~2 GiB for Spark/Hadoop on Ubuntu; ~1.25 GiB XLA reserve on TPU)."""
        return max(0.0, (self.node.mem_gib - overhead_per_node_gib)
                   * self.scale_out)


# -- AWS-like (paper evaluation space) --------------------------------------

_AWS_NODES = [
    #        name        cores mem$/h
    NodeType("c4.large", 2, 3.75, 0.100),
    NodeType("c4.xlarge", 4, 7.5, 0.199),
    NodeType("c4.2xlarge", 8, 15.0, 0.398),
    NodeType("m4.large", 2, 8.0, 0.100),
    NodeType("m4.xlarge", 4, 16.0, 0.200),
    NodeType("m4.2xlarge", 8, 32.0, 0.400),
    NodeType("r4.large", 2, 15.25, 0.133),
    NodeType("r4.xlarge", 4, 30.5, 0.266),
    NodeType("r4.2xlarge", 8, 61.0, 0.532),
]

_AWS_SCALEOUTS = [4, 6, 8, 10, 12, 16, 24, 32, 40, 48]


def aws_like_catalog() -> List[ClusterConfig]:
    return [ClusterConfig(n, s) for n in _AWS_NODES for s in _AWS_SCALEOUTS]


def medium_config(catalog: List[ClusterConfig]) -> ClusterConfig:
    """Paper baseline 2: a medium VM at medium scale-out (12x m4.xlarge in
    the paper's dataset). Generalized: median node by memory, median
    scale-out."""
    nodes = sorted({c.node.name: c.node for c in catalog}.values(),
                   key=lambda n: (n.cores, n.mem_gib))
    node = nodes[len(nodes) // 2]
    scales = sorted({c.scale_out for c in catalog})
    scale = scales[len(scales) // 2]
    want = ClusterConfig(node, scale)
    for c in catalog:
        if c.name == want.name:
            return c
    return want


# -- TPU (at-scale adaptation) ----------------------------------------------

V5E = NodeType("v5e", 1, 16.0, 1.20, peak_tflops=197.0, hbm_gbps=819.0,
               ici_gbps=50.0)
V4 = NodeType("v4", 1, 32.0, 3.22, peak_tflops=275.0, hbm_gbps=1228.0,
              ici_gbps=50.0)
V5P = NodeType("v5p", 1, 95.0, 4.20, peak_tflops=459.0, hbm_gbps=2765.0,
               ici_gbps=100.0)

_TPU_SLICES = [16, 32, 64, 128, 256, 512, 1024, 2048]


def tpu_catalog() -> List[ClusterConfig]:
    return [ClusterConfig(n, s) for n in (V5E, V4, V5P) for s in _TPU_SLICES]
