"""Crispy §III-D / §IV-C: configuration selection + the three baselines.

* Random — expected cost of a uniformly random pick (paper evaluates this as
  the average normalized cost over the catalog).
* Medium — fixed medium VM, medium scale-out.
* BFA ("Best For All") — config with the lowest mean normalized cost over
  all *other* jobs.
* Crispy — BFA restricted to configs whose usable total memory satisfies the
  extrapolated requirement. Requirement 0 (no confident model) == exactly BFA
  — the never-worse-than-fallback property the paper reports.

Objective axis (arXiv:2306.03672): fully-in-memory is often not
cost-optimal. When a confident *runtime* model is available,
`objective="min_cost"` ranks the memory-feasible configs by
`usd_per_hour × predicted_runtime(config)` on the (cost, runtime) Pareto
front, and `objective="min_runtime"` by predicted runtime. Per-config
runtime scales the model's profiling-machine prediction by relative
compute capacity — `peak_tflops` against the roofline peak when the
catalog carries it, total cores otherwise — with sublinear parallel
efficiency. Whenever the runtime model is missing or unconfident both
objectives degrade to `cheapest_fit` (the paper's selection), preserving
never-worse-than-BFA.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.catalog import ClusterConfig, medium_config
from repro.core.history import ExecutionHistory
from repro.launch.roofline import PEAK_FLOPS

DEFAULT_OVERHEAD_GIB = 2.0      # Spark/Hadoop+OS per node (paper §III-D)

OBJECTIVES = ("cheapest_fit", "min_cost", "min_runtime")

# runtime ∝ 1 / capacity^eff: doubling the cluster does not halve the wall
# time (stragglers, shuffle, coordination), which is exactly what makes
# over-provisioning cost-inefficient under the min_cost objective
PARALLEL_EFFICIENCY = 0.9


@dataclass
class Selection:
    config: ClusterConfig
    method: str
    mem_requirement_gib: float
    feasible_count: int
    fell_back: bool
    objective: str = "cheapest_fit"
    predicted_runtime_s: Optional[float] = None
    predicted_cost_usd: Optional[float] = None
    objective_fell_back: bool = False   # runtime objective degraded to
                                        # cheapest_fit (unconfident model)


def select_bfa(catalog: List[ClusterConfig], history: ExecutionHistory,
               exclude_job: Optional[str] = None) -> ClusterConfig:
    # one precomputed score table per (history state, exclude_job) — see
    # ExecutionHistory.bfa_scores — then an O(catalog) argmin; the
    # AllocationService no longer re-runs the jobs x configs scan per
    # request, and feasibility-restricted subsets reuse the same table
    scores = history.bfa_scores(exclude_job=exclude_job)
    inf = float("inf")
    return min(catalog,
               key=lambda c: (scores.get(c.name, inf), c.usd_per_hour))


def select_medium(catalog: List[ClusterConfig]) -> ClusterConfig:
    return medium_config(catalog)


def config_capacity(config: ClusterConfig) -> float:
    """Relative compute capacity of a config. Accelerator catalogs carry
    `peak_tflops` (normalized against the roofline peak so TPU and CPU
    capacities live on one scale); CPU catalogs fall back to core count."""
    node = config.node
    peak = getattr(node, "peak_tflops", 0.0) or 0.0
    if peak > 0.0:
        return (peak * 1e12 / PEAK_FLOPS) * config.scale_out
    return float(config.total_cores)


def predicted_runtime_s(runtime_model, full_size: float,
                        config: ClusterConfig,
                        parallel_efficiency: float = PARALLEL_EFFICIENCY,
                        ) -> Optional[float]:
    """Wall-time estimate for `config` on the full dataset, or None when
    the model's base prediction is unusable (non-finite / non-positive)."""
    try:
        base = float(runtime_model.predict(float(full_size)))
    except (OverflowError, ValueError, ZeroDivisionError):
        return None
    if not math.isfinite(base) or base <= 0.0:
        return None
    cap = max(config_capacity(config), 1.0)
    return base / cap ** parallel_efficiency


def predicted_cost_usd(runtime_s: float, config: ClusterConfig) -> float:
    return config.usd_per_hour * runtime_s / 3600.0


def pareto_front(scored: List[Tuple[ClusterConfig, float, float]]
                 ) -> List[Tuple[ClusterConfig, float, float]]:
    """Non-dominated subset of `(config, cost, runtime)` rows: a row stays
    iff no other row is at least as good on both axes and strictly better
    on one."""
    front = []
    for row in scored:
        _, cost, rt = row
        dominated = any(
            (o_cost <= cost and o_rt <= rt
             and (o_cost < cost or o_rt < rt))
            for _o, o_cost, o_rt in scored)
        if not dominated:
            front.append(row)
    return front


def _score_feasible(feasible: List[ClusterConfig], runtime_model,
                    full_size: float, parallel_efficiency: float,
                    ) -> Optional[List[Tuple[ClusterConfig, float, float]]]:
    """(config, predicted cost, predicted runtime) rows, or None whenever
    the runtime model cannot back a ranking (the cheapest_fit fallback)."""
    if runtime_model is None:
        return None
    if not getattr(runtime_model, "confident", False):
        return None
    if not full_size or full_size <= 0.0:
        return None
    rows = []
    for c in feasible:
        rt = predicted_runtime_s(runtime_model, full_size, c,
                                 parallel_efficiency)
        if rt is None:
            return None
        rows.append((c, predicted_cost_usd(rt, c), rt))
    return rows


def select_crispy(catalog: List[ClusterConfig], history: ExecutionHistory,
                  mem_requirement_gib: float,
                  overhead_per_node_gib: float = DEFAULT_OVERHEAD_GIB,
                  exclude_job: Optional[str] = None,
                  objective: str = "cheapest_fit",
                  runtime_model=None,
                  full_size: float = 0.0,
                  parallel_efficiency: float = PARALLEL_EFFICIENCY,
                  ) -> Selection:
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"expected one of {OBJECTIVES}")
    feasible = [c for c in catalog
                if c.usable_mem_gib(overhead_per_node_gib)
                >= mem_requirement_gib]
    fell_back = False
    if not feasible:
        # nothing satisfies the requirement (requirement larger than the
        # biggest cluster): take the largest-memory config — still the
        # bottleneck-minimizing choice — breaking usable-memory ties by
        # price so an infeasible requirement never lands on a strictly
        # dominated config
        feasible = [min(catalog,
                        key=lambda c: (-c.usable_mem_gib(
                            overhead_per_node_gib), c.usd_per_hour))]
        fell_back = True
    fell_back = fell_back or mem_requirement_gib <= 0.0
    objective_fell_back = False
    if objective != "cheapest_fit":
        scored = _score_feasible(feasible, runtime_model, full_size,
                                 parallel_efficiency)
        if scored is not None:
            front = pareto_front(scored)
            if objective == "min_cost":
                cfg, cost, rt = min(
                    front, key=lambda r: (r[1], r[2],
                                          r[0].usd_per_hour, r[0].name))
            else:   # min_runtime
                cfg, cost, rt = min(
                    front, key=lambda r: (r[2], r[1],
                                          r[0].usd_per_hour, r[0].name))
            return Selection(cfg, "crispy", mem_requirement_gib,
                             len(feasible), fell_back,
                             objective=objective,
                             predicted_runtime_s=rt,
                             predicted_cost_usd=cost)
        objective_fell_back = True
    cfg = select_bfa(feasible, history, exclude_job=exclude_job)
    return Selection(cfg, "crispy", mem_requirement_gib, len(feasible),
                     fell_back, objective=objective,
                     objective_fell_back=objective_fell_back)


def select_like(catalog: List[ClusterConfig], history: ExecutionHistory,
                neighbor_job: str) -> Optional[Selection]:
    """Flora-style transfer (arXiv:2502.21046): when a job's own profile is
    unusable, allocate what worked best for its nearest classified neighbor.
    None if the neighbor has no usable record in this catalog."""
    best = history.best_config_name(neighbor_job)
    if best is None:
        return None
    cfg = next((c for c in catalog if c.name == best), None)
    if cfg is None:
        return None
    return Selection(cfg, "classifier", 0.0, 1, False)


def random_expected_cost(catalog: List[ClusterConfig],
                         history: ExecutionHistory, job: str) -> float:
    """Paper baseline 1: the expectation of a uniform random selection =
    mean normalized cost over configs with a recorded execution."""
    nc = history.normalized_costs(job)
    vals = [nc[c.name] for c in catalog if c.name in nc]
    return sum(vals) / len(vals) if vals else float("inf")
