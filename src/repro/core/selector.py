"""Crispy §III-D / §IV-C: configuration selection + the three baselines.

* Random — expected cost of a uniformly random pick (paper evaluates this as
  the average normalized cost over the catalog).
* Medium — fixed medium VM, medium scale-out.
* BFA ("Best For All") — config with the lowest mean normalized cost over
  all *other* jobs.
* Crispy — BFA restricted to configs whose usable total memory satisfies the
  extrapolated requirement. Requirement 0 (no confident model) == exactly BFA
  — the never-worse-than-fallback property the paper reports.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core.catalog import ClusterConfig, medium_config
from repro.core.history import ExecutionHistory

DEFAULT_OVERHEAD_GIB = 2.0      # Spark/Hadoop+OS per node (paper §III-D)


@dataclass
class Selection:
    config: ClusterConfig
    method: str
    mem_requirement_gib: float
    feasible_count: int
    fell_back: bool


def select_bfa(catalog: List[ClusterConfig], history: ExecutionHistory,
               exclude_job: Optional[str] = None) -> ClusterConfig:
    # one precomputed score table per (history state, exclude_job) — see
    # ExecutionHistory.bfa_scores — then an O(catalog) argmin; the
    # AllocationService no longer re-runs the jobs x configs scan per
    # request, and feasibility-restricted subsets reuse the same table
    scores = history.bfa_scores(exclude_job=exclude_job)
    inf = float("inf")
    return min(catalog,
               key=lambda c: (scores.get(c.name, inf), c.usd_per_hour))


def select_medium(catalog: List[ClusterConfig]) -> ClusterConfig:
    return medium_config(catalog)


def select_crispy(catalog: List[ClusterConfig], history: ExecutionHistory,
                  mem_requirement_gib: float,
                  overhead_per_node_gib: float = DEFAULT_OVERHEAD_GIB,
                  exclude_job: Optional[str] = None) -> Selection:
    feasible = [c for c in catalog
                if c.usable_mem_gib(overhead_per_node_gib)
                >= mem_requirement_gib]
    fell_back = False
    if not feasible:
        # nothing satisfies the requirement (requirement larger than the
        # biggest cluster): take the largest-memory config — still the
        # bottleneck-minimizing choice
        feasible = sorted(catalog,
                          key=lambda c: -c.usable_mem_gib(
                              overhead_per_node_gib))[:1]
        fell_back = True
    cfg = select_bfa(feasible, history, exclude_job=exclude_job)
    return Selection(cfg, "crispy", mem_requirement_gib, len(feasible),
                     fell_back or mem_requirement_gib <= 0.0)


def select_like(catalog: List[ClusterConfig], history: ExecutionHistory,
                neighbor_job: str) -> Optional[Selection]:
    """Flora-style transfer (arXiv:2502.21046): when a job's own profile is
    unusable, allocate what worked best for its nearest classified neighbor.
    None if the neighbor has no usable record in this catalog."""
    best = history.best_config_name(neighbor_job)
    if best is None:
        return None
    cfg = next((c for c in catalog if c.name == best), None)
    if cfg is None:
        return None
    return Selection(cfg, "classifier", 0.0, 1, False)


def random_expected_cost(catalog: List[ClusterConfig],
                         history: ExecutionHistory, job: str) -> float:
    """Paper baseline 1: the expectation of a uniform random selection =
    mean normalized cost over configs with a recorded execution."""
    nc = history.normalized_costs(job)
    vals = [nc[c.name] for c in catalog if c.name in nc]
    return sum(vals) / len(vals) if vals else float("inf")
