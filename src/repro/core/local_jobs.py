"""The seven HiBench-family algorithms as single-machine jobs (numpy/JAX) —
the profiling targets for the paper-faithful local reproduction (paper §IV:
K-Means, PageRank, Linear/Logistic Regression, Naive Bayes, Join, Sort).

Each factory takes `size_bytes` and returns a zero-arg callable whose peak
RSS the profiler measures. Working-set shape mirrors the Spark versions:
iterative ML jobs *cache* their dataset (hold it live across iterations);
Join/Sort stream with transient intermediates.
"""
from __future__ import annotations

import numpy as np

_F8 = 8  # float64 bytes


def kmeans_job(size_bytes: int, d: int = 16, k: int = 8, iters: int = 8):
    n = max(64, int(size_bytes / (d * _F8)))

    def run():
        rng = np.random.default_rng(0)
        data = rng.standard_normal((n, d))          # cached dataset
        centers = data[:k].copy()
        # allocation-free iterations (||x-c||^2 = ||x||^2 - 2 x.c + ||c||^2
        # with preallocated buffers): the measured footprint is the cached
        # dataset + fixed work buffers, linear in input — as in Spark
        xsq = np.square(data).sum(1)
        d2 = np.empty((n, k))
        for _ in range(iters):
            np.matmul(data, centers.T, out=d2)
            d2 *= -2.0
            d2 += xsq[:, None]
            d2 += np.square(centers).sum(1)[None, :]
            idx = d2.argmin(1)
            for j in range(k):
                m = idx == j
                if m.any():
                    centers[j] = data[m].mean(0)
        return centers

    return run


def pagerank_job(size_bytes: int, iters: int = 8):
    m = max(256, int(size_bytes / (2 * _F8)))       # edges

    def run():
        rng = np.random.default_rng(0)
        n = max(64, m // 8)
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)                 # cached edge list
        rank = np.full(n, 1.0 / n)
        deg = np.maximum(np.bincount(src, minlength=n), 1)
        for _ in range(iters):
            contrib = rank[src] / deg[src]
            new = np.zeros(n)
            np.add.at(new, dst, contrib)
            rank = 0.15 / n + 0.85 * new
        return rank

    return run


def linregression_job(size_bytes: int, d: int = 32, iters: int = 6):
    n = max(64, int(size_bytes / (d * _F8)))

    def run():
        rng = np.random.default_rng(0)
        X = rng.standard_normal((n, d))
        y = X @ rng.standard_normal(d) + 0.1 * rng.standard_normal(n)
        w = np.zeros(d)
        for _ in range(iters):                      # gradient descent passes
            g = X.T @ (X @ w - y) / n
            w -= 0.1 * g
        return w

    return run


def logregression_job(size_bytes: int, d: int = 32, iters: int = 10):
    n = max(64, int(size_bytes / (d * _F8)))

    def run():
        rng = np.random.default_rng(0)
        X = rng.standard_normal((n, d))
        y = (X @ rng.standard_normal(d) > 0).astype(np.float64)
        w = np.zeros(d)
        for _ in range(iters):
            p = 1.0 / (1.0 + np.exp(-(X @ w)))
            w -= 0.5 * (X.T @ (p - y)) / n
        return w

    return run


def naivebayes_job(size_bytes: int, vocab: int = 4096, classes: int = 4):
    n = max(64, int(size_bytes / (16 * 4)))         # 16 int32 tokens per doc

    def run():
        rng = np.random.default_rng(0)
        docs = rng.integers(0, vocab, (n, 16)).astype(np.int32)
        labels = rng.integers(0, classes, n)
        counts = np.zeros((classes, vocab))
        for c in range(classes):
            np.add.at(counts[c], docs[labels == c].ravel(), 1.0)
        logp = np.log((counts + 1) / (counts.sum(1, keepdims=True) + vocab))
        return logp

    return run


def join_job(size_bytes: int):
    n = max(64, int(size_bytes / (2 * _F8)))

    def run():
        rng = np.random.default_rng(0)
        left_k = rng.integers(0, n // 2, n)
        left_v = rng.standard_normal(n)
        right_k = rng.integers(0, n // 2, n // 4)
        right_v = rng.standard_normal(n // 4)
        order = np.argsort(right_k, kind="stable")  # sort-merge join
        rk, rv = right_k[order], right_v[order]
        pos = np.searchsorted(rk, left_k)
        ok = (pos < rk.size)
        pos = np.clip(pos, 0, rk.size - 1)
        match = ok & (rk[pos] == left_k)
        return float((left_v[match] + rv[pos[match]]).sum())

    return run


def sort_job(size_bytes: int):
    n = max(64, int(size_bytes / _F8))

    def run():
        rng = np.random.default_rng(0)
        data = rng.standard_normal(n)
        return np.sort(data, kind="stable")[-1]     # terasort stand-in

    return run


LOCAL_JOBS = {
    "kmeans": kmeans_job,
    "pagerank": pagerank_job,
    "linregression": linregression_job,
    "logregression": logregression_job,
    "naivebayes": naivebayes_job,
    "join": join_job,
    "sort": sort_job,
}
