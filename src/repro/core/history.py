"""Execution history + normalized-cost bookkeeping (paper §IV-C).

Cost of a (job, config) execution is normalized per job to the cheapest
config for that job, so the best possible selection scores 1.0 — Table I's
metric. ``ExecutionHistory`` is what BFA averages over: records of *other*
jobs (Crispy never assumes the job at hand recurs)."""
from __future__ import annotations

import threading
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

from repro.core.catalog import ClusterConfig


@dataclass(frozen=True)
class Execution:
    job: str
    config_name: str
    runtime_s: float
    usd: float


class ExecutionHistory:
    def __init__(self, executions: Iterable[Execution] = ()):
        self._by_job: Dict[str, Dict[str, Execution]] = defaultdict(dict)
        # normalized_costs is the selection hot path (BFA scans every
        # config x every job per request); memoize per job, drop on add.
        # The RLock closes the check-then-set race with a concurrent add()
        # (the AllocationService worker reads while submitters may record).
        self._nc_cache: Dict[str, Dict[str, float]] = {}
        # the full BFA score table (config -> mean normalized cost over all
        # jobs but one), memoized per exclude_job: one O(jobs x configs)
        # scan amortized over every selection until the history changes
        self._bfa_cache: Dict[Optional[str], Dict[str, float]] = {}
        self._lock = threading.RLock()
        self._version = 0
        for e in executions:
            self.add(e)

    @property
    def version(self) -> int:
        """Bumped on every add() — lets derived caches (e.g. the
        AllocationService plan cache) detect that selections computed from
        this history are stale."""
        with self._lock:
            return self._version

    def add(self, e: Execution) -> None:
        with self._lock:
            self._by_job[e.job][e.config_name] = e
            self._nc_cache.pop(e.job, None)
            self._bfa_cache.clear()     # every exclude_job view is stale
            self._version += 1

    def jobs(self) -> List[str]:
        with self._lock:
            return sorted(self._by_job)

    def cost(self, job: str, config_name: str) -> Optional[float]:
        with self._lock:
            e = self._by_job.get(job, {}).get(config_name)
            return None if e is None else e.usd

    def normalized_costs(self, job: str) -> Dict[str, float]:
        """config name -> cost / best cost, for one job. Returns a copy —
        callers may mutate it without poisoning the memo."""
        return dict(self._normalized_costs_cached(job))

    def _normalized_costs_cached(self, job: str) -> Dict[str, float]:
        """Internal shared dict for the BFA hot loop; do not mutate."""
        with self._lock:
            cached = self._nc_cache.get(job)
            if cached is not None:
                return cached
            ex = self._by_job.get(job, {})
            if not ex:
                return {}
            best = min(e.usd for e in ex.values())
            nc = {name: e.usd / best for name, e in ex.items()}
            self._nc_cache[job] = nc
            return nc

    def best_config_name(self, job: str) -> Optional[str]:
        """Cheapest recorded config for `job` (None if the job never ran) —
        what a Flora-style classifier transfers from a neighboring job."""
        with self._lock:
            ex = self._by_job.get(job, {})
            if not ex:
                return None
            return min(ex, key=lambda name: ex[name].usd)

    def bfa_scores(self, exclude_job: Optional[str] = None
                   ) -> Dict[str, float]:
        """config name -> mean normalized cost over all jobs but
        `exclude_job` — the whole BFA ranking table in one scan, memoized
        per exclude_job and invalidated whenever the history gains a run.
        Catalog-independent (keyed by config name), so any catalog subset
        the selector restricts to reuses the same table. Do not mutate."""
        with self._lock:
            cached = self._bfa_cache.get(exclude_job)
            if cached is not None:
                return cached
            sums: Dict[str, float] = defaultdict(float)
            counts: Dict[str, int] = defaultdict(int)
            for job in self._by_job:
                if job == exclude_job:
                    continue
                for name, v in self._normalized_costs_cached(job).items():
                    sums[name] += v
                    counts[name] += 1
            scores = {name: sums[name] / counts[name] for name in sums}
            self._bfa_cache[exclude_job] = scores
            return scores

    def mean_normalized_cost(self, config_name: str,
                             exclude_job: Optional[str] = None) -> float:
        """Average normalized cost of `config_name` over all *other* jobs —
        the BFA ranking signal. inf if the config never ran."""
        return self.bfa_scores(exclude_job).get(config_name, float("inf"))

    def config_names(self) -> List[str]:
        with self._lock:
            names = set()
            for ex in self._by_job.values():
                names.update(ex)
            return sorted(names)
