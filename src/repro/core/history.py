"""Execution history + normalized-cost bookkeeping (paper §IV-C).

Cost of a (job, config) execution is normalized per job to the cheapest
config for that job, so the best possible selection scores 1.0 — Table I's
metric. ``ExecutionHistory`` is what BFA averages over: records of *other*
jobs (Crispy never assumes the job at hand recurs)."""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

from repro.core.catalog import ClusterConfig


@dataclass(frozen=True)
class Execution:
    job: str
    config_name: str
    runtime_s: float
    usd: float


class ExecutionHistory:
    def __init__(self, executions: Iterable[Execution] = ()):
        self._by_job: Dict[str, Dict[str, Execution]] = defaultdict(dict)
        for e in executions:
            self.add(e)

    def add(self, e: Execution) -> None:
        self._by_job[e.job][e.config_name] = e

    def jobs(self) -> List[str]:
        return sorted(self._by_job)

    def cost(self, job: str, config_name: str) -> Optional[float]:
        e = self._by_job.get(job, {}).get(config_name)
        return None if e is None else e.usd

    def normalized_costs(self, job: str) -> Dict[str, float]:
        """config name -> cost / best cost, for one job."""
        ex = self._by_job.get(job, {})
        if not ex:
            return {}
        best = min(e.usd for e in ex.values())
        return {name: e.usd / best for name, e in ex.items()}

    def mean_normalized_cost(self, config_name: str,
                             exclude_job: Optional[str] = None) -> float:
        """Average normalized cost of `config_name` over all *other* jobs —
        the BFA ranking signal. inf if the config never ran."""
        vals = []
        for job in self._by_job:
            if job == exclude_job:
                continue
            nc = self.normalized_costs(job)
            if config_name in nc:
                vals.append(nc[config_name])
        return sum(vals) / len(vals) if vals else float("inf")

    def config_names(self) -> List[str]:
        names = set()
        for ex in self._by_job.values():
            names.update(ex)
        return sorted(names)
