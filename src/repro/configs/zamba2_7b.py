"""zamba2-7b [hybrid] — 81 Mamba2 blocks + shared attention blocks (2
alternating weight sets) applied periodically. [arXiv:2411.15242; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,               # Mamba2 blocks (shared attn applied every 6)
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,                # shared-block MLP ff
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    hybrid=HybridConfig(period=6, n_shared_sets=2, shared_d_ff=14336),
    source="[arXiv:2411.15242; unverified]",
)
