"""nemotron-4-15b [dense] — GQA 48/8, squared-ReLU MLP, partial RoPE.
[arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp_kind="relu2",
    rope_kind="partial",
    rope_fraction=0.5,
    source="[arXiv:2402.16819; unverified]",
)
