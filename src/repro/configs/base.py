"""Configuration dataclasses for models, shapes, meshes and runs.

Every assigned architecture is expressed as a ``ModelConfig``; the launcher
combines it with a ``ShapeConfig`` (one of the four assigned input shapes) and
a ``MeshConfig`` to produce a concrete job. ``RunConfig`` carries the
performance knobs that the Crispy HBM planner and the perf hillclimb iterate
over (remat policy, microbatching, sharding variants, attention impl).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    first_dense_layers: int = 0          # leading dense layers (deepseek-v3: 3)
    d_ff_dense: int = 0                  # ff dim of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2
    impl: str = "dense"                  # "dense" (GShard einsum) | "ep_tp" (expert//model psum)


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256                     # SSD chunk length
    n_groups: int = 1


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style shared transformer blocks interleaved with SSM blocks."""
    period: int = 6                      # shared attn applied every `period` SSM blocks
    n_shared_sets: int = 2               # alternating shared weight sets
    shared_d_ff: int = 0                 # ff of the shared block (0 -> model d_ff)


@dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder/decoder split. Frontend is a stub: input_specs()
    provides precomputed frame embeddings of shape (B, enc_len, d_model)."""
    n_encoder_layers: int = 12
    enc_len: int = 1500


@dataclass(frozen=True)
class CrossAttnConfig:
    """Llama-3.2-vision-style gated cross-attention layers. Frontend is a
    stub: input_specs() provides patch embeddings (B, n_media_tokens, d)."""
    period: int = 5                      # every `period`-th layer cross-attends
    n_media_tokens: int = 1601


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                      # 0 -> d_model // n_heads
    # attention
    attention_kind: str = "gqa"          # gqa | mla | none
    rope_kind: str = "full"              # full | partial | 2d | none
    rope_fraction: float = 1.0
    rope_theta: float = 10000.0
    # mlp
    mlp_kind: str = "swiglu"             # swiglu | relu2 | gelu
    # optional components
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    cross_attn: Optional[CrossAttnConfig] = None
    mtp_depth: int = 0                   # deepseek-v3 multi-token-prediction heads
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: int = 0              # 0 = disabled
    source: str = ""                     # provenance note "[arXiv:...; tier]"

    def __post_init__(self):
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # -- derived ----------------------------------------------------------
    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec)

    def param_count(self) -> int:
        """Analytic parameter count (used by roofline MODEL_FLOPS and the
        Crispy catalog cost model; cross-checked against real init in tests)."""
        from repro.models.model import analytic_param_count
        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.model import analytic_param_count
        return analytic_param_count(self, active_only=True)

    def reduced(self, **over) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests and Crispy profiling
        ladders: few layers, narrow width, small vocab — same code paths."""
        d_model = over.pop("d_model", 64)
        n_heads = max(2, min(self.n_heads, 4)) if self.n_heads else 0
        n_kv = max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads else 0
        kw = dict(
            n_layers=over.pop("n_layers", 4 if self.hybrid is None else 4),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=d_model // n_heads if n_heads else 0,
            d_ff=over.pop("d_ff", 128),
            vocab_size=over.pop("vocab_size", 256),
        )
        if self.moe is not None:
            kw["moe"] = replace(
                self.moe, n_experts=over.pop("n_experts", 8), top_k=2,
                d_ff_expert=64, first_dense_layers=min(self.moe.first_dense_layers, 1),
                d_ff_dense=96)
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
            kw["d_head"] = 0
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=16, chunk=32)
        if self.hybrid is not None:
            kw["hybrid"] = replace(self.hybrid, period=2)
            kw["n_layers"] = 4
        if self.encdec is not None:
            kw["encdec"] = EncDecConfig(n_encoder_layers=2, enc_len=16)
        if self.cross_attn is not None:
            kw["cross_attn"] = CrossAttnConfig(period=2, n_media_tokens=16)
            kw["n_layers"] = 4
        if self.mtp_depth:
            kw["mtp_depth"] = 1
        kw.update(over)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Shapes (assigned grid)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                            # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Mesh / run
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def dp(self) -> int:
        n = 1
        for ax, s in zip(self.axes, self.shape):
            if ax in ("pod", "data"):
                n *= s
        return n

    @property
    def tp(self) -> int:
        for ax, s in zip(self.axes, self.shape):
            if ax == "model":
                return s
        return 1


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class RunConfig:
    """Performance/distribution knobs — the hillclimb surface."""
    microbatches: int = 1                # gradient accumulation steps
    remat: str = "boundaries"            # nothing | dots | boundaries
    zero1: bool = True                   # shard optimizer state over data axis
    param_dtype: str = "float32"         # master/param storage dtype
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"        # adam m/v storage (bf16 = compressed)
    attn_impl: str = "blocked"           # blocked | full | pallas
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    seq_shard: bool = False              # sequence parallelism for prefill
    fsdp_experts: bool = False           # 2D-shard expert weights over data axis
    fsdp_params: bool = False            # FSDP dense weights over data axis
    scan_layers: bool = True
    donate: bool = True
    grad_compression: bool = False       # bf16 all-reduce w/ error feedback
    accum_dtype: str = "float32"         # microbatch gradient accumulator
    kv_cache_dtype: str = "compute"      # "compute" | "int8" (quantized KV)

    def with_(self, **kw) -> "RunConfig":
        return replace(self, **kw)


def cell_id(arch: str, shape: str) -> str:
    return f"{arch}:{shape}"
