"""whisper-small [audio] — enc-dec; conv frontend stubbed (precomputed frame
embeddings). 12 encoder + 12 decoder layers. [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig, EncDecConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,               # decoder layers; encoder in encdec config
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    rope_kind="none",          # whisper uses learned/sinusoidal positions
    mlp_kind="gelu",
    encdec=EncDecConfig(n_encoder_layers=12, enc_len=1500),
    source="[arXiv:2212.04356; unverified]",
)
