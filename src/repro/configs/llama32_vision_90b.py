"""llama-3.2-vision-90b [vlm] — 100 layers, gated cross-attn image layers
every 5th layer; vision frontend stubbed (precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ModelConfig, CrossAttnConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn=CrossAttnConfig(period=5, n_media_tokens=1601),
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
)
