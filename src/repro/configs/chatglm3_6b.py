"""chatglm3-6b [dense] — 2d RoPE, GQA 32/2. [arXiv:2406.12793; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_kind="2d",
    rope_fraction=0.5,
    source="[arXiv:2406.12793; hf]",
)
