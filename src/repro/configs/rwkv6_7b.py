"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,                # wkv heads = d_model / head_dim(64)
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    attention_kind="none",
    rope_kind="none",
    ssm=SSMConfig(d_state=64, head_dim=64, chunk=256),  # head_dim == wkv state dim
    source="[arXiv:2404.05892; hf]",
)
