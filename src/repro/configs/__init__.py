"""Architecture registry: --arch <id> resolves here."""
from repro.configs.base import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                                HybridConfig, EncDecConfig, CrossAttnConfig,
                                ShapeConfig, MeshConfig, RunConfig,
                                SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K,
                                LONG_500K, SINGLE_POD, MULTI_POD, cell_id)

from repro.configs.deepseek_v3_671b import CONFIG as _dsv3
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.zamba2_7b import CONFIG as _zamba2
from repro.configs.mistral_large_123b import CONFIG as _mistral
from repro.configs.deepseek_7b import CONFIG as _ds7b
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.chatglm3_6b import CONFIG as _chatglm
from repro.configs.rwkv6_7b import CONFIG as _rwkv
from repro.configs.llama32_vision_90b import CONFIG as _llamav
from repro.configs.whisper_small import CONFIG as _whisper

ARCHS = {c.name: c for c in (
    _dsv3, _olmoe, _zamba2, _mistral, _ds7b,
    _nemotron, _chatglm, _rwkv, _llamav, _whisper)}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """DESIGN.md §4 grid skips: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


def grid(include_skipped: bool = False):
    """All (arch, shape) cells of the assigned grid."""
    for name, cfg in ARCHS.items():
        for shape in SHAPES.values():
            if include_skipped or shape_applicable(cfg, shape):
                yield cfg, shape
