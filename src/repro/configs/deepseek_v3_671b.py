"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 experts, MTP.
[arXiv:2412.19437; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,            # MLA: heads share a compressed latent, not GQA
    d_ff=2048,                 # per-expert ff (spec); dense layers use d_ff_dense
    vocab_size=129280,
    attention_kind="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, first_dense_layers=3, d_ff_dense=18432,
                  impl="ep_tp"),
    mtp_depth=1,
    rope_theta=10000.0,
    source="[arXiv:2412.19437; hf]",
)
