"""Transformer / hybrid / SSM stacks with scan-over-layers.

Every stack is expressed as `stacked params` (leading n_layers axis on every
leaf, built by vmapping the per-layer init) consumed by lax.scan — HLO size
is O(1) in depth, which keeps 100-layer × 512-device dry-run compiles fast.
Remat policy wraps the scan body (RunConfig.remat).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rwkv as R
from repro.models import ssm as SSM


# ---------------------------------------------------------------------------
# remat
# ---------------------------------------------------------------------------


def remat_wrap(fn, policy: str):
    if policy == "nothing":
        return fn
    if policy == "dots":
        # weight matmuls only: saving *batched* dots would stash the
        # attention score matrices and defeat blocked attention's O(block)
        # memory (measured: +16 GiB/dev on deepseek-7b tp4 — §Perf H3/H5)
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    # "boundaries": save only the scan carry (layer inputs)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


# ---------------------------------------------------------------------------
# standard decoder block (dense MLP or MoE)
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, kind: str = "dense",
               d_ff: Optional[int] = None):
    """kind: dense | moe | cross (cross-attention block for VLM)."""
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.ones((cfg.d_model,)), "ln2": jnp.ones((cfg.d_model,))}
    if kind == "cross":
        p["attn"] = A.init_cross_attn(ks[0], cfg)
    elif cfg.attention_kind == "mla":
        p["attn"] = A.init_mla(ks[0], cfg)
    else:
        p["attn"] = A.init_gqa(ks[0], cfg)
    if kind == "moe":
        p["moe"] = M.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, d_ff or cfg.d_ff,
                              cfg.mlp_kind)
    return p


def block(params, x, cfg: ModelConfig, run: RunConfig, *, kind="dense",
          mesh=None, positions=None, causal=True, media_kv=None):
    """One transformer block. Returns (x, aux_loss)."""
    h = L.rms_norm(x, params["ln1"], cfg.norm_eps)
    if kind == "cross":
        h = A.cross_attn(params["attn"], h, media_kv, run)
    elif cfg.attention_kind == "mla":
        h = A.mla(params["attn"], h, cfg, run, positions=positions,
                  causal=causal)
    else:
        h = A.gqa(params["attn"], h, cfg, run, positions=positions,
                  causal=causal)
    x = x + h
    h = L.rms_norm(x, params["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if kind == "moe":
        h, aux = M.moe(params["moe"], h, cfg, run, mesh)
    else:
        h = L.mlp(params["mlp"], h, cfg.mlp_kind)
    return x + h, aux


def block_decode(params, x, cache, cfg: ModelConfig, run: RunConfig, *,
                 kind="dense", mesh=None, media_kv=None):
    """One-token decode through a block; returns (x, new_cache)."""
    h = L.rms_norm(x, params["ln1"], cfg.norm_eps)
    if kind == "cross":
        h = A.cross_attn(params["attn"], h, media_kv, run)
        new_cache = cache
    elif cfg.attention_kind == "mla":
        h, new_cache = A.mla_decode(params["attn"], h, cache, cfg, run)
    else:
        h, new_cache = A.gqa_decode(params["attn"], h, cache, cfg, run)
    x = x + h
    h = L.rms_norm(x, params["ln2"], cfg.norm_eps)
    if kind == "moe":
        h, _ = M.moe(params["moe"], h, cfg, run, mesh)
    else:
        h = L.mlp(params["mlp"], h, cfg.mlp_kind)
    return x + h, new_cache


# ---------------------------------------------------------------------------
# stacked (scan) application
# ---------------------------------------------------------------------------


def init_stack(key, cfg: ModelConfig, n: int, kind="dense", d_ff=None):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_block(k, cfg, kind, d_ff))(keys)


def stack(params, x, cfg, run, *, kind="dense", mesh=None, positions=None,
          causal=True, media_kv=None):
    """Scan x through a stacked block group. Returns (x, summed aux)."""
    def body(carry, layer_params):
        h, aux = block(layer_params, carry, cfg, run, kind=kind, mesh=mesh,
                       positions=positions, causal=causal, media_kv=media_kv)
        return h, aux

    if not run.scan_layers:
        aux_total = jnp.zeros((), jnp.float32)
        n = jax.tree.leaves(params)[0].shape[0]
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], params)
            x, aux = block(lp, x, cfg, run, kind=kind, mesh=mesh,
                           positions=positions, causal=causal,
                           media_kv=media_kv)
            aux_total = aux_total + aux
        return x, aux_total

    body = remat_wrap(body, run.remat)
    x, auxs = lax.scan(body, x, params)
    return x, jnp.sum(auxs)


def stack_decode(params, x, caches, cfg, run, *, kind="dense", mesh=None,
                 media_kv=None):
    """Scan one token through a stacked group, threading per-layer caches.
    caches: pytree stacked on axis 0."""
    def body(carry, inp):
        layer_params, cache = inp
        h, new_cache = block_decode(layer_params, carry, cache, cfg, run,
                                    kind=kind, mesh=mesh, media_kv=media_kv)
        return h, new_cache

    x, new_caches = lax.scan(body, x, (params, caches))
    return x, new_caches


def block_prefill(params, x, cfg: ModelConfig, run: RunConfig, *,
                  kind="dense", mesh=None, positions=None, pad_to=0):
    """Block forward that also returns KV-cache contents."""
    h = L.rms_norm(x, params["ln1"], cfg.norm_eps)
    if cfg.attention_kind == "mla":
        h, kv = A.mla_prefill(params["attn"], h, cfg, run,
                              positions=positions, pad_to=pad_to)
    else:
        h, kv = A.gqa_prefill(params["attn"], h, cfg, run,
                              positions=positions, pad_to=pad_to)
    x = x + h
    h = L.rms_norm(x, params["ln2"], cfg.norm_eps)
    if kind == "moe":
        h, _ = M.moe(params["moe"], h, cfg, run, mesh)
    else:
        h = L.mlp(params["mlp"], h, cfg.mlp_kind)
    return x + h, kv


def stack_prefill(params, x, cfg, run, *, kind="dense", mesh=None,
                  positions=None, pad_to=0):
    """Scan a stacked group, collecting per-layer KV caches as scan ys."""
    def body(carry, layer_params):
        h, kv = block_prefill(layer_params, carry, cfg, run, kind=kind,
                              mesh=mesh, positions=positions, pad_to=pad_to)
        return h, kv

    body = remat_wrap(body, run.remat)
    x, kvs = lax.scan(body, x, params)
    return x, kvs


# ---------------------------------------------------------------------------
# RWKV stack
# ---------------------------------------------------------------------------


def init_rwkv_stack(key, cfg: ModelConfig):
    keys = jax.random.split(key, cfg.n_layers)

    def one(k):
        p = R.init_rwkv6(k, cfg)
        p["ln1"] = jnp.ones((cfg.d_model,))
        p["ln2"] = jnp.ones((cfg.d_model,))
        return p

    return jax.vmap(one)(keys)


def rwkv_stack(params, x, cfg, run):
    def body(carry, lp):
        norms = {"ln1": lp["ln1"], "ln2": lp["ln2"]}
        return R.rwkv_block(lp, carry, cfg, run, norms), None

    body = remat_wrap(body, run.remat)
    x, _ = lax.scan(body, x, params)
    return x


def rwkv_stack_decode(params, x, caches, cfg, run):
    def body(carry, inp):
        lp, cache = inp
        norms = {"ln1": lp["ln1"], "ln2": lp["ln2"]}
        h, nc = R.rwkv_block_decode(lp, carry, cache, cfg, run, norms)
        return h, nc

    x, new_caches = lax.scan(body, x, (params, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# Zamba2 hybrid stack: groups of `period` Mamba2 blocks + a shared attention
# block (n_shared_sets alternating weight sets, NOT scanned — true weight
# sharing across depth, the Zamba2 trick).
# ---------------------------------------------------------------------------


def init_hybrid(key, cfg: ModelConfig):
    hy = cfg.hybrid
    n_groups = max(1, cfg.n_layers // hy.period)
    ks = jax.random.split(key, 4)
    mamba_keys = jax.random.split(ks[0], n_groups * hy.period)

    def one_m(k):
        p = SSM.init_mamba2(k, cfg)
        p["ln"] = jnp.ones((cfg.d_model,))
        return p

    mamba = jax.vmap(one_m)(mamba_keys)
    mamba = jax.tree.map(
        lambda a: a.reshape(n_groups, hy.period, *a.shape[1:]), mamba)
    shared_keys = jax.random.split(ks[1], hy.n_shared_sets)
    d_ff = hy.shared_d_ff or cfg.d_ff
    shared = jax.vmap(
        lambda k: init_block(k, cfg, "dense", d_ff))(shared_keys)
    return {"mamba": mamba, "shared": shared}


def hybrid_stack(params, x, cfg, run, *, positions=None):
    hy = cfg.hybrid
    n_groups = jax.tree.leaves(params["mamba"])[0].shape[0]
    n_sets = jax.tree.leaves(params["shared"])[0].shape[0]

    def group_body(carry, inp):
        g, mamba_g = inp
        h = carry

        def m_body(c, lp):
            y = SSM.mamba2(lp, L.rms_norm(c, lp["ln"], cfg.norm_eps), cfg, run)
            return c + y, None

        m_body = remat_wrap(m_body, run.remat)
        h, _ = lax.scan(m_body, h, mamba_g)
        sel = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, g % n_sets, 0, False),
            params["shared"])
        h, _ = block(sel, h, cfg, run, kind="dense", positions=positions)
        return h, None

    x, _ = lax.scan(group_body, x, (jnp.arange(n_groups), params["mamba"]))
    return x


def hybrid_stack_decode(params, x, caches, cfg, run):
    """caches: {"mamba": stacked (G,period,...) mamba caches,
    "attn": stacked (G, ...) kv caches}."""
    hy = cfg.hybrid
    n_sets = jax.tree.leaves(params["shared"])[0].shape[0]
    n_groups = jax.tree.leaves(params["mamba"])[0].shape[0]

    def group_body(carry, inp):
        g, mamba_g, mcache_g, acache = inp
        h = carry

        def m_body(c, inp2):
            lp, mc = inp2
            y, nmc = SSM.mamba2_decode(
                lp, L.rms_norm(c, lp["ln"], cfg.norm_eps), mc, cfg, run)
            return c + y, nmc

        h, new_mc = lax.scan(m_body, h, (mamba_g, mcache_g))
        sel = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, g % n_sets, 0, False),
            params["shared"])
        h, new_ac = block_decode(sel, h, acache, cfg, run, kind="dense")
        return h, (new_mc, new_ac)

    x, (new_m, new_a) = lax.scan(
        group_body, x,
        (jnp.arange(n_groups), params["mamba"], caches["mamba"],
         caches["attn"]))
    return x, {"mamba": new_m, "attn": new_a}


# ---------------------------------------------------------------------------
# VLM stack (llama-3.2-vision): groups of (period-1) self-attn blocks + 1
# gated cross-attn block. Media KV computed per cross layer from stub patch
# embeddings.
# ---------------------------------------------------------------------------


def init_vlm(key, cfg: ModelConfig):
    ca = cfg.cross_attn
    n_groups = cfg.n_layers // ca.period
    n_self = ca.period - 1
    ks = jax.random.split(key, 2)
    self_keys = jax.random.split(ks[0], n_groups * n_self)
    selfp = jax.vmap(lambda k: init_block(k, cfg, "dense"))(self_keys)
    selfp = jax.tree.map(
        lambda a: a.reshape(n_groups, n_self, *a.shape[1:]), selfp)
    cross_keys = jax.random.split(ks[1], n_groups)
    crossp = jax.vmap(lambda k: init_block(k, cfg, "cross"))(cross_keys)
    return {"self": selfp, "cross": crossp}


def vlm_stack(params, x, media, cfg, run, *, positions=None, decode_caches=None):
    n_groups = jax.tree.leaves(params["cross"])[0].shape[0]

    def group_body(carry, inp):
        selfp_g, crossp = inp
        h = carry

        def s_body(c, lp):
            y, _ = block(lp, c, cfg, run, kind="dense", positions=positions)
            return y, None

        s_body = remat_wrap(s_body, run.remat)
        h, _ = lax.scan(s_body, h, selfp_g)
        kv = A.cross_attn_kv(crossp["attn"], media)
        h, _ = block(crossp, h, cfg, run, kind="cross", media_kv=kv,
                     positions=positions)
        return h, None

    x, _ = lax.scan(group_body, x, (params["self"], params["cross"]))
    return x


def vlm_stack_decode(params, x, media, caches, cfg, run):
    def group_body(carry, inp):
        selfp_g, crossp, scache_g = inp
        h = carry

        def s_body(c, inp2):
            lp, sc = inp2
            y, nsc = block_decode(lp, c, sc, cfg, run, kind="dense")
            return y, nsc

        h, new_sc = lax.scan(s_body, h, (selfp_g, scache_g))
        kv = A.cross_attn_kv(crossp["attn"], media)
        h, _ = block_decode(crossp, h, None, cfg, run, kind="cross",
                            media_kv=kv)
        return h, new_sc

    x, new_caches = lax.scan(
        group_body, x, (params["self"], params["cross"], caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# Whisper enc-dec
# ---------------------------------------------------------------------------


def init_encdec(key, cfg: ModelConfig):
    ed = cfg.encdec
    ks = jax.random.split(key, 3)
    enc = init_stack(ks[0], cfg, ed.n_encoder_layers, "dense")

    def one_dec(k):
        kk = jax.random.split(k, 2)
        p = init_block(kk[0], cfg, "dense")
        p["cross"] = A.init_cross_attn(kk[1], cfg)
        p["ln_cross"] = jnp.ones((cfg.d_model,))
        return p

    dec = jax.vmap(one_dec)(jax.random.split(ks[1], cfg.n_layers))
    return {"enc": enc, "dec": dec, "enc_ln": jnp.ones((cfg.d_model,))}


def _dec_block(lp, x, enc_out, cfg, run, positions):
    h, _ = block({k: lp[k] for k in ("ln1", "ln2", "attn",
                                     "mlp" if "mlp" in lp else "moe")},
                 x, cfg, run, kind="dense", positions=positions)
    kv = A.cross_attn_kv(lp["cross"], enc_out)
    c = L.rms_norm(h, lp["ln_cross"], cfg.norm_eps)
    return h + A.cross_attn(lp["cross"], c, kv, run, gated=False)


def encdec_apply(params, frames, tokens_x, cfg, run, *, positions=None):
    """frames: (B, enc_len, d) stub embeddings; tokens_x: (B,S,d) embedded."""
    pos_e = jnp.arange(frames.shape[1])
    enc = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)
    enc, _ = stack(params["enc"], enc, cfg, run, kind="dense",
                   positions=pos_e, causal=False)
    enc = L.rms_norm(enc, params["enc_ln"], cfg.norm_eps)

    def body(carry, lp):
        return _dec_block(lp, carry, enc, cfg, run, positions), None

    body = remat_wrap(body, run.remat)
    x, _ = lax.scan(body, tokens_x, params["dec"])
    return x


def encdec_decode(params, x, enc_out, caches, cfg, run):
    def body(carry, inp):
        lp, cache = inp
        base = {k: lp[k] for k in ("ln1", "ln2", "attn", "mlp")}
        h, nc = block_decode(base, carry, cache, cfg, run, kind="dense")
        kv = A.cross_attn_kv(lp["cross"], enc_out)
        c = L.rms_norm(h, lp["ln_cross"], cfg.norm_eps)
        h = h + A.cross_attn(lp["cross"], c, kv, run, gated=False)
        return h, nc

    x, new_caches = lax.scan(body, x, (params["dec"], caches))
    return x, new_caches


def _sinusoid(S, d, dtype):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)[None]
