"""Unified model API over the six architecture families.

  model = build_model(cfg, run)
  params = model.init(key)
  loss, metrics = model.loss_fn(params, batch, mesh)          # train
  logits, caches = model.prefill(params, batch, max_len, mesh) # serving
  logits, caches = model.decode_step(params, batch, caches, mesh)

`input_specs(cfg, shape, run)` produces ShapeDtypeStruct stand-ins for every
input of the corresponding step — the dry-run lowers against these without
allocating anything.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import rwkv as R
from repro.models import ssm as SSM
from repro.models import transformer as T


def _batch_axes(mesh):
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def shard(x, mesh, *spec):
    if mesh is None:
        return x
    return lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


class Model:
    def __init__(self, cfg: ModelConfig, run: RunConfig):
        self.cfg = cfg
        self.run = run
        self.compute_dtype = jnp.dtype(run.compute_dtype)
        # pad vocab to a multiple of 128 (Megatron-style) so the embedding/
        # head shard cleanly over the model axis (whisper: 51865 -> 51968);
        # padded logit columns are masked to -inf in _logits
        v = cfg.vocab_size
        self.padded_vocab = v if v % 128 == 0 else (v // 128 + 1) * 128

    # ------------------------------------------------------------------ init
    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        p = {"embed": L.init_embed(ks[0], self.padded_vocab, cfg.d_model),
             "norm": jnp.ones((cfg.d_model,))}
        if not cfg.tie_embeddings:
            p["head"] = L.dense_init(ks[1], (self.padded_vocab, cfg.d_model),
                                     in_axis_size=cfg.d_model)
        fam = cfg.family
        if fam == "dense":
            p["layers"] = T.init_stack(ks[2], cfg, cfg.n_layers, "dense")
        elif fam == "moe":
            n_dense = cfg.moe.first_dense_layers
            if n_dense:
                p["dense_layers"] = T.init_stack(ks[3], cfg, n_dense, "dense",
                                                 d_ff=cfg.moe.d_ff_dense)
            p["layers"] = T.init_stack(ks[2], cfg, cfg.n_layers - n_dense,
                                       "moe")
            if cfg.mtp_depth:
                p["mtp"] = {
                    "proj": L.dense_init(ks[4], (2 * cfg.d_model, cfg.d_model),
                                         in_axis_size=2 * cfg.d_model),
                    "block": T.init_block(ks[5], cfg, "moe"),
                    "norm": jnp.ones((cfg.d_model,)),
                }
        elif fam == "ssm":
            p["layers"] = T.init_rwkv_stack(ks[2], cfg)
        elif fam == "hybrid":
            p["layers"] = T.init_hybrid(ks[2], cfg)
        elif fam == "vlm":
            p["layers"] = T.init_vlm(ks[2], cfg)
        elif fam == "audio":
            p["layers"] = T.init_encdec(ks[2], cfg)
        else:
            raise ValueError(fam)
        if self.run.param_dtype != "float32":
            dt = jnp.dtype(self.run.param_dtype)
            p = jax.tree.map(lambda a: a.astype(dt), p)
        return p

    # --------------------------------------------------------------- forward
    def _embed(self, params, tokens, mesh):
        x = L.embed(params["embed"], tokens, self.compute_dtype)
        if mesh is not None:
            x = shard(x, mesh, _batch_axes(mesh), None, None)
        return x

    def _logits(self, params, x, mesh):
        x = L.rms_norm(x, params["norm"], self.cfg.norm_eps)
        head = params["embed"] if self.cfg.tie_embeddings else params["head"]
        lg = L.logits(head, x)
        if self.padded_vocab != self.cfg.vocab_size:
            pad_mask = jnp.arange(self.padded_vocab) >= self.cfg.vocab_size
            lg = jnp.where(pad_mask, jnp.asarray(-1e30, lg.dtype), lg)
        if mesh is not None:
            lg = shard(lg, mesh, _batch_axes(mesh), None, "model")
        return lg

    def forward(self, params, batch, mesh=None):
        """Full-sequence forward -> (logits, aux). Train & simple prefill."""
        cfg, run = self.cfg, self.run
        tokens = batch["tokens"]
        x = self._embed(params, tokens, mesh)
        S = tokens.shape[1]
        positions = jnp.arange(S)
        aux = jnp.zeros((), jnp.float32)
        if cfg.family == "dense":
            x, aux = T.stack(params["layers"], x, cfg, run, kind="dense",
                             mesh=mesh, positions=positions)
        elif cfg.family == "moe":
            if "dense_layers" in params:
                x, _ = T.stack(params["dense_layers"], x, cfg, run,
                               kind="dense", mesh=mesh, positions=positions)
            x, aux = T.stack(params["layers"], x, cfg, run, kind="moe",
                             mesh=mesh, positions=positions)
        elif cfg.family == "ssm":
            x = T.rwkv_stack(params["layers"], x, cfg, run)
        elif cfg.family == "hybrid":
            x = T.hybrid_stack(params["layers"], x, cfg, run,
                               positions=positions)
        elif cfg.family == "vlm":
            media = batch["media"].astype(self.compute_dtype)
            x = T.vlm_stack(params["layers"], x, media, cfg, run,
                            positions=positions)
        elif cfg.family == "audio":
            frames = batch["frames"].astype(self.compute_dtype)
            x = T.encdec_apply(params["layers"], frames, x, cfg, run,
                               positions=positions)
        h = x
        return self._logits(params, x, mesh), (aux, h)

    # ------------------------------------------------------------------ loss
    def loss_fn(self, params, batch, mesh=None):
        cfg = self.cfg
        lg, (aux, h) = self.forward(params, batch, mesh)
        labels = batch["labels"]
        loss = L.cross_entropy(lg, labels)
        metrics = {"ce": loss, "aux": aux}
        if cfg.moe is not None:
            loss = loss + cfg.moe.router_aux_weight * aux
        if cfg.mtp_depth and "mtp" in params:
            loss = loss + 0.3 * self._mtp_loss(params, h, batch, mesh)
        return loss, metrics

    def _mtp_loss(self, params, h, batch, mesh):
        """DeepSeek-V3 multi-token prediction: one extra block predicting
        token t+2 from (norm(h_t), embed(token_{t+1}))."""
        cfg, run = self.cfg, self.run
        tokens, labels = batch["tokens"], batch["labels"]
        mp = params["mtp"]
        hn = L.rms_norm(h[:, :-1], mp["norm"], cfg.norm_eps)
        nxt = L.embed(params["embed"], tokens[:, 1:], self.compute_dtype)
        x = jnp.einsum("bsd,dk->bsk",
                       jnp.concatenate([hn, nxt], -1),
                       mp["proj"].astype(hn.dtype))
        x, _ = T.block(mp["block"], x, cfg, run, kind="moe", mesh=mesh,
                       positions=jnp.arange(x.shape[1]))
        lg = self._logits(params, x, mesh)
        return L.cross_entropy(lg[:, :-1], labels[:, 2:])

    # --------------------------------------------------------------- serving
    def init_caches(self, batch: int, max_len: int):
        cfg = self.cfg
        dt = self.compute_dtype
        quant = self.run.kv_cache_dtype == "int8"

        def stacked(n, make):
            one = make()
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n, *a.shape)).copy(), one)

        if cfg.family == "dense":
            return stacked(cfg.n_layers,
                           lambda: A.init_gqa_cache(cfg, batch, max_len, dt,
                                                    quant=quant))
        if cfg.family == "moe":
            mk = (lambda: A.init_mla_cache(cfg, batch, max_len, dt)) \
                if cfg.attention_kind == "mla" else \
                (lambda: A.init_gqa_cache(cfg, batch, max_len, dt,
                                          quant=quant))
            n_dense = cfg.moe.first_dense_layers
            out = {"moe": stacked(cfg.n_layers - n_dense, mk)}
            if n_dense:
                out["dense"] = stacked(n_dense, mk)
            return out
        if cfg.family == "ssm":
            return stacked(cfg.n_layers,
                           lambda: R.init_rwkv_cache(cfg, batch, dt))
        if cfg.family == "hybrid":
            hy = cfg.hybrid
            G = max(1, cfg.n_layers // hy.period)
            m = stacked(G * hy.period,
                        lambda: SSM.init_mamba2_cache(cfg, batch, dt))
            m = jax.tree.map(
                lambda a: a.reshape(G, hy.period, *a.shape[1:]), m)
            return {"mamba": m,
                    "attn": stacked(G, lambda: A.init_gqa_cache(
                        cfg, batch, max_len, dt, quant=quant))}
        if cfg.family == "vlm":
            ca = cfg.cross_attn
            G = cfg.n_layers // ca.period
            s = stacked(G * (ca.period - 1),
                        lambda: A.init_gqa_cache(cfg, batch, max_len, dt,
                                                 quant=quant))
            return jax.tree.map(
                lambda a: a.reshape(G, ca.period - 1, *a.shape[1:]), s)
        if cfg.family == "audio":
            return stacked(cfg.n_layers,
                           lambda: A.init_gqa_cache(cfg, batch, max_len, dt,
                                                    quant=quant))
        raise ValueError(cfg.family)

    def prefill(self, params, batch, max_len: int, mesh=None):
        """Process a prompt, return (last-position logits, filled caches)."""
        cfg, run = self.cfg, self.run
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens, mesh)
        positions = jnp.arange(S)
        pos_scalar = jnp.full((B,), S, jnp.int32)

        def kv_to_cache(kvs, n):
            k, v = kvs
            return {"k": k, "v": v,
                    "pos": jnp.broadcast_to(pos_scalar, (n, B)).copy()}

        if cfg.family in ("dense", "audio"):
            if cfg.family == "audio":
                # encode once, then prefill decoder (simplified: decoder-only
                # prefill path shares stack_prefill via dense blocks + cross)
                frames = batch["frames"].astype(self.compute_dtype)
                x = T.encdec_apply(params["layers"], frames, x, cfg, run,
                                   positions=positions)
                caches = self.init_caches(B, max_len)  # filled decoder caches
                return self._logits(params, x[:, -1:], mesh), caches
            x, kvs = T.stack_prefill(params["layers"], x, cfg, run,
                                     kind="dense", mesh=mesh,
                                     positions=positions, pad_to=max_len)
            caches = kv_to_cache(kvs, cfg.n_layers)
        elif cfg.family == "moe":
            caches = {}
            n_dense = cfg.moe.first_dense_layers
            if n_dense:
                x, kvs = T.stack_prefill(params["dense_layers"], x, cfg, run,
                                         kind="dense", mesh=mesh,
                                         positions=positions, pad_to=max_len)
                caches["dense"] = self._pack_mla(kvs, n_dense, pos_scalar) \
                    if cfg.attention_kind == "mla" else kv_to_cache(kvs, n_dense)
            x, kvs = T.stack_prefill(params["layers"], x, cfg, run,
                                     kind="moe", mesh=mesh,
                                     positions=positions, pad_to=max_len)
            n_moe = cfg.n_layers - n_dense
            caches["moe"] = self._pack_mla(kvs, n_moe, pos_scalar) \
                if cfg.attention_kind == "mla" else kv_to_cache(kvs, n_moe)
        else:
            # ssm / hybrid / vlm prefill: run forward then seed caches by
            # replaying decode state computation is family-specific; for
            # sub-quadratic archs the serve path enters at decode with a
            # precomputed state (see serve/engine.py)
            lg, _ = self.forward(params, batch, mesh)
            return lg[:, -1:], self.init_caches(B, max_len)
        return self._logits(params, x[:, -1:], mesh), caches

    @staticmethod
    def _pack_mla(kvs, n, pos_scalar):
        ckv, kr = kvs
        B = pos_scalar.shape[0]
        return {"ckv": ckv, "kr": kr,
                "pos": jnp.broadcast_to(pos_scalar, (n, B)).copy()}

    def decode_step(self, params, batch, caches, mesh=None):
        """One token for every sequence in the batch -> (logits, caches)."""
        cfg, run = self.cfg, self.run
        tokens = batch["tokens"]                     # (B, 1)
        x = self._embed(params, tokens, mesh)
        if cfg.family == "dense":
            x, caches = T.stack_decode(params["layers"], x, caches, cfg, run,
                                       kind="dense", mesh=mesh)
        elif cfg.family == "moe":
            n_dense = cfg.moe.first_dense_layers
            new = {}
            if n_dense:
                x, new["dense"] = T.stack_decode(
                    params["dense_layers"], x, caches["dense"], cfg, run,
                    kind="dense", mesh=mesh)
            x, new["moe"] = T.stack_decode(
                params["layers"], x, caches["moe"], cfg, run, kind="moe",
                mesh=mesh)
            caches = new
        elif cfg.family == "ssm":
            x, caches = T.rwkv_stack_decode(params["layers"], x, caches,
                                            cfg, run)
        elif cfg.family == "hybrid":
            x, caches = T.hybrid_stack_decode(params["layers"], x, caches,
                                              cfg, run)
        elif cfg.family == "vlm":
            media = batch["media"].astype(self.compute_dtype)
            x, caches = T.vlm_stack_decode(params["layers"], x, media,
                                           caches, cfg, run)
        elif cfg.family == "audio":
            enc_out = batch["enc_out"].astype(self.compute_dtype)
            x, caches = T.encdec_decode(params["layers"], x, enc_out, caches,
                                        cfg, run)
        return self._logits(params, x, mesh), caches


def build_model(cfg: ModelConfig, run: Optional[RunConfig] = None) -> Model:
    return Model(cfg, run or RunConfig())


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs for the dry-run) & param accounting
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig, run: RunConfig):
    """Returns (batch_specs, cache_specs|None) for the step the shape
    implies: train -> loss_fn, prefill -> forward, decode -> decode_step."""
    sd = jax.ShapeDtypeStruct
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    cdt = jnp.dtype(run.compute_dtype)
    batch = {}
    if shape.mode == "train":
        batch["tokens"] = sd((B, S), i32)
        batch["labels"] = sd((B, S), i32)
    elif shape.mode == "prefill":
        batch["tokens"] = sd((B, S), i32)
    else:  # decode
        batch["tokens"] = sd((B, 1), i32)
    if cfg.family == "vlm":
        batch["media"] = sd((B, cfg.cross_attn.n_media_tokens, cfg.d_model),
                            cdt)
    if cfg.family == "audio":
        if shape.mode == "decode":
            batch["enc_out"] = sd((B, cfg.encdec.enc_len, cfg.d_model), cdt)
        else:
            batch["frames"] = sd((B, cfg.encdec.enc_len, cfg.d_model), cdt)
    caches = None
    if shape.mode == "decode":
        model = Model(cfg, run)
        caches = jax.eval_shape(lambda: model.init_caches(B, S))
        caches = jax.tree.map(lambda s: sd(s.shape, s.dtype), caches)
    return batch, caches


def analytic_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count via eval_shape of init (no allocation)."""
    model = Model(cfg, RunConfig())
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = 0
    expert = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if any(k in ("w_gate", "w_up", "w_down") for k in keys):
            expert += n
    if not active_only or cfg.moe is None:
        return total
    frac = cfg.moe.top_k / cfg.moe.n_experts
    return int(total - expert + expert * frac)
