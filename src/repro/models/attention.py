"""Attention variants: GQA (full / blocked-flash / decode), DeepSeek MLA
(train + absorbed-latent decode), and cross-attention for VLM/enc-dec.

The "blocked" path is the XLA flash-style implementation (online softmax,
lax.scan over KV blocks) used for long-sequence prefill/train: activation
memory is O(block) instead of O(S^2). The Pallas kernel in
repro/kernels/flash_attention.py implements the same contract for TPU;
runtime selection is RunConfig.attn_impl.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA weights
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return {
        "wq": L.dense_init(ks[0], (d, H, Dh)),
        "wk": L.dense_init(ks[1], (d, K, Dh)),
        "wv": L.dense_init(ks[2], (d, K, Dh)),
        "wo": L.dense_init(ks[3], (H, Dh, d), in_axis_size=H * Dh),
    }


# ---------------------------------------------------------------------------
# softmax attention cores
# ---------------------------------------------------------------------------


def _grouped_scores(q, k):
    """q: (B,Sq,K,G,D), k: (B,Sk,K,D) -> (B,K,G,Sq,Sk)."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k)


def full_attention(q, k, v, *, causal: bool, q_offset=0, kv_len=None):
    """Plain softmax attention. q: (B,Sq,H,D); k,v: (B,Sk,K,D).
    q_offset: absolute position of q[0] (for causal masking w/ cache).
    kv_len: number of valid kv positions (decode) — scalar or (B,)."""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, D)
    s = _grouped_scores(qg, k) * (1.0 / math.sqrt(D))
    s = s.astype(jnp.float32)
    Sk = k.shape[1]
    if causal:
        qpos = q_offset + jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Sk)[None, :]
        s = jnp.where(qpos >= kpos, s, NEG_INF)
    if kv_len is not None:
        kv_len = jnp.asarray(kv_len)
        if kv_len.ndim == 0:
            mask = jnp.arange(Sk)[None, :] < kv_len
        else:   # per-row lengths (continuous batching)
            mask = jnp.arange(Sk)[None, None, None, None, :] < \
                kv_len[:, None, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(B, Sq, H, D)


def blocked_attention(q, k, v, *, causal: bool, block_q: int, block_kv: int,
                      q_offset: int = 0, zigzag: bool = False):
    """Flash-style attention: online softmax, scanned over KV blocks.

    Memory: O(B*H*block_q*block_kv) for scores instead of O(Sq*Sk).
    With ``causal`` and ``zigzag=False`` all kv blocks are visited for every
    q block (masked) — ~2x causal FLOP waste, removed by the zigzag schedule
    (see §Perf): q block i is fused with q block nq-1-i so every fused pair
    needs the same number of kv blocks.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    K = k.shape[2]
    G = H // K
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    # pad to block multiples
    pq = (-Sq) % block_q
    pk = (-Sk) % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq = (Sq + pq) // block_q
    nk = (Sk + pk) // block_kv
    qg = q.reshape(B, nq, block_q, K, G, D)
    scale = 1.0 / math.sqrt(D)

    if causal and zigzag and nq % 2 == 0 and Sq == Sk and q_offset == 0:
        return _zigzag_causal(qg, k, v, B, nq, block_q, nk, block_kv,
                              K, G, D, Sq, Sk, pq, scale, q.dtype)

    kpos = jnp.arange(nk * block_kv)

    def q_block(qi, qb):
        # qb: (B, block_q, K, G, D)
        def body(carry, ki):
            m, l, acc = carry
            kb = lax.dynamic_slice_in_dim(k, ki * block_kv, block_kv, 1)
            vb = lax.dynamic_slice_in_dim(v, ki * block_kv, block_kv, 1)
            s = _grouped_scores(qb, kb).astype(jnp.float32) * scale
            if causal:
                qpos = q_offset + qi * block_q + jnp.arange(block_q)
                kp = ki * block_kv + jnp.arange(block_kv)
                s = jnp.where(qpos[:, None] >= kp[None, :], s, NEG_INF)
            else:
                # mask kv padding
                kp = ki * block_kv + jnp.arange(block_kv)
                s = jnp.where(kp[None, :] < Sk, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            msafe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
            p = jnp.where(s > NEG_INF / 2,
                          jnp.exp(s - msafe[..., None]), 0.0)
            corr = jnp.where(m > NEG_INF / 2, jnp.exp(m - msafe), 0.0)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, K, G, block_q, D), jnp.float32)
        (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nk))
        o = acc / jnp.maximum(l[..., None], 1e-30)
        return o.astype(q.dtype)  # (B,K,G,block_q,D)

    outs = lax.map(lambda args: q_block(*args),
                   (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    # outs: (nq, B, K, G, block_q, D) -> (B, Sq, H, D)
    o = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5)
    o = o.reshape(B, nq * block_q, H, D)
    return o[:, :Sq]


def _zigzag_causal(qg, k, v, B, nq, block_q, nk, block_kv, K, G, D,
                   Sq, Sk, pq, scale, dtype):
    """Causal blocked attention with ~half the masked-FLOP waste removed.

    Fold trick: pair q-block p ("lo") with q-block nq-1-p ("hi"). lo needs
    kv blocks [0, p]; hi needs [0, nq-1-p]; combined need = nq+1 blocks —
    *constant across pairs*. Two lanes per scan step t in [0, T),
    T = ceil((nq+1)/2):

      lane A: serves lo with kv block t while t <= p, then serves hi with
              kv blocks from the top: j = nq - t  (t > p)
      lane B: always serves hi with kv block t (bottom-up)

    Lane A's top-down hi blocks are masked out where they would duplicate
    lane B's bottom-up coverage (j <= T-1) or exceed hi's need (j > nq-1-p).
    Total score work = 2 lanes * T * bq * bkv * (nq/2 pairs)
                     ~= Sq*Sk/2 + O(S*block)  vs  Sq*Sk for the plain path.

    Requires block_q == block_kv (caller guarantees by passing equal blocks
    when zigzag is on), Sq == Sk, no q_offset.
    """
    assert block_q == block_kv, "zigzag requires square blocks"
    half = nq // 2
    T = (nq + 1 + 1) // 2  # ceil((nq+1)/2)

    def one_update(carry, qb, qpos, kv_idx, valid):
        """Online-softmax update of (m,l,acc) for rows qb against kv block
        kv_idx; `valid` scalar bool gates the whole block."""
        m, l, acc = carry
        kb = lax.dynamic_slice_in_dim(k, kv_idx * block_kv, block_kv, 1)
        vb = lax.dynamic_slice_in_dim(v, kv_idx * block_kv, block_kv, 1)
        kp = kv_idx * block_kv + jnp.arange(block_kv)
        s = _grouped_scores(qb, kb).astype(jnp.float32) * scale
        mask = (qpos[:, None] >= kp[None, :]) & valid
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        msafe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
        pexp = jnp.where(s > NEG_INF / 2, jnp.exp(s - msafe[..., None]), 0.0)
        corr = jnp.where(m > NEG_INF / 2, jnp.exp(m - msafe), 0.0)
        l_new = l * corr + jnp.sum(pexp, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", pexp.astype(vb.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new)

    def pair_block(p):
        lo = qg[:, p].reshape(B, block_q, K, G, D)
        hi = qg[:, nq - 1 - p].reshape(B, block_q, K, G, D)
        lo_pos = p * block_q + jnp.arange(block_q)
        hi_pos = (nq - 1 - p) * block_q + jnp.arange(block_q)

        def body(carry, t):
            (cl, ch) = carry
            # lane A: serves lo (kv block t) while t <= p, afterwards serves
            # hi top-down (kv block nq-t). One real update per lane per step.
            a_is_lo = t <= p
            a_idx_hi = jnp.clip(nq - t, 0, nk - 1)
            a_hi_valid = (a_idx_hi > T - 1) & (a_idx_hi <= nq - 1 - p)
            qb = jnp.where(a_is_lo, lo, hi)
            qpos_a = jnp.where(a_is_lo, lo_pos, hi_pos)
            a_idx = jnp.where(a_is_lo, t, a_idx_hi)
            a_valid = a_is_lo | a_hi_valid
            c_in = jax.tree.map(lambda x, y: jnp.where(a_is_lo, x, y), cl, ch)
            c_out = one_update(c_in, qb, qpos_a,
                               jnp.where(a_valid, a_idx, 0), a_valid)
            cl = jax.tree.map(lambda n, o: jnp.where(a_is_lo, n, o), c_out, cl)
            ch = jax.tree.map(lambda n, o: jnp.where(a_is_lo, o, n), c_out, ch)
            # lane B: always serves hi bottom-up (kv block t)
            b_valid = t <= nq - 1 - p
            ch = one_update(ch, hi, hi_pos, jnp.where(b_valid, t, 0), b_valid)
            return (cl, ch), None

        def fresh():
            m0 = jnp.full((B, K, G, block_q), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, K, G, block_q), jnp.float32)
            a0 = jnp.zeros((B, K, G, block_q, D), jnp.float32)
            return (m0, l0, a0)

        (cl, ch), _ = lax.scan(body, (fresh(), fresh()), jnp.arange(T))

        def finish(c):
            m, l, acc = c
            return (acc / jnp.maximum(l[..., None], 1e-30)).astype(dtype)

        return finish(cl), finish(ch)  # each (B,K,G,bq,D)

    lo_outs, hi_outs = lax.map(pair_block, jnp.arange(half))
    # lo_outs[p] is q block p; hi_outs[p] is q block nq-1-p
    full = jnp.concatenate([lo_outs, hi_outs[::-1]], axis=0)  # (nq,B,K,G,bq,D)
    o = jnp.moveaxis(full, 0, 1).transpose(0, 1, 4, 2, 3, 5)
    o = o.reshape(B, nq * block_q, K * G, D)
    return o[:, :Sq]


def decode_attention(q, k_cache, v_cache, kv_len):
    """Single-step decode. q: (B,1,H,D); caches (B,Smax,K,D); kv_len scalar."""
    return full_attention(q, k_cache, v_cache, causal=False, kv_len=kv_len)


# ---------------------------------------------------------------------------
# int8-quantized KV cache (decode capacity optimization, §Perf-extras):
# halves at-rest HBM vs bf16. Symmetric per-(position, head) scales;
# attention runs chunked over the context so only one dequantized block is
# ever materialized (flash-decoding layout compatible).
# ---------------------------------------------------------------------------


def quantize_kv(x):
    """x: (..., D) -> (int8 values, bf16 scales (..., 1))."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127
                 ).astype(jnp.int8)
    return q, s.astype(jnp.bfloat16)


def decode_attention_q8(q, kq, ks, vq, vs, kv_len, block: int = 4096):
    """Decode attention against an int8 cache, dequantizing block-by-block
    with online softmax. q: (B,1,H,D); kq/vq: (B,S,K,D) int8;
    ks/vs: (B,S,K,1) scales; kv_len: (B,) or scalar."""
    B, _, H, D = q.shape
    S = kq.shape[1]
    K = kq.shape[2]
    G = H // K
    block = min(block, S)
    pad = (-S) % block
    if pad:
        zpad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        kq, vq = jnp.pad(kq, zpad4), jnp.pad(vq, zpad4)
        ks, vs = jnp.pad(ks, zpad4), jnp.pad(vs, zpad4)
    nb = (S + pad) // block
    qg = q.reshape(B, K, G, D).astype(jnp.float32)
    scale = 1.0 / math.sqrt(D)
    kv_len = jnp.asarray(kv_len)
    if kv_len.ndim == 0:
        kv_len = jnp.full((B,), kv_len)

    def body(carry, bi):
        m, l, acc = carry
        sl = lambda a: lax.dynamic_slice_in_dim(a, bi * block, block, 1)
        kb = sl(kq).astype(jnp.float32) * sl(ks).astype(jnp.float32)
        vb = sl(vq).astype(jnp.float32) * sl(vs).astype(jnp.float32)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, kb) * scale
        pos = bi * block + jnp.arange(block)
        s = jnp.where(pos[None, None, None, :] <
                      kv_len[:, None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        msafe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
        p = jnp.where(s > NEG_INF / 2, jnp.exp(s - msafe[..., None]), 0.0)
        corr = jnp.where(m > NEG_INF / 2, jnp.exp(m - msafe), 0.0)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgs,bskd->bkgd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G), jnp.float32)
    a0 = jnp.zeros((B, K, G, D), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nb))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------


def _project_qkv(params, x, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    q = L.rotary(q, positions, cfg.rope_kind, cfg.rope_fraction, cfg.rope_theta)
    k = L.rotary(k, positions, cfg.rope_kind, cfg.rope_fraction, cfg.rope_theta)
    return q, k, v


def gqa(params, x, cfg: ModelConfig, run: RunConfig, *, positions=None,
        causal: bool = True):
    """Self-attention over a full sequence (train / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _project_qkv(params, x, cfg, positions)
    if run.attn_impl == "full":
        o = full_attention(q, k, v, causal=causal)
    elif run.attn_impl == "pallas":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=causal,
                                 block_q=run.attn_block_q,
                                 block_kv=run.attn_block_kv)
    else:
        o = blocked_attention(q, k, v, causal=causal,
                              block_q=run.attn_block_q,
                              block_kv=run.attn_block_kv,
                              zigzag=(run.attn_impl == "zigzag"))
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))


def gqa_prefill(params, x, cfg: ModelConfig, run: RunConfig, *,
                positions=None, pad_to: int = 0):
    """Like gqa() but also returns the (k, v) cache content, padded to
    `pad_to` positions (the serve-time max length)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _project_qkv(params, x, cfg, positions)
    if run.attn_impl == "full":
        o = full_attention(q, k, v, causal=True)
    else:
        o = blocked_attention(q, k, v, causal=True,
                              block_q=run.attn_block_q,
                              block_kv=run.attn_block_kv,
                              zigzag=(run.attn_impl == "zigzag"))
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    if pad_to > S:
        pad = ((0, 0), (0, pad_to - S), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    return out, (k, v)


def mla_prefill(params, x, cfg: ModelConfig, run: RunConfig, *,
                positions=None, pad_to: int = 0):
    """MLA forward that also emits the latent cache (ckv, kr)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    out = mla(params, x, cfg, run, positions=positions, causal=True)
    ckv, kr = _mla_latent(params, x, cfg, positions)
    if pad_to > S:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad_to - S), (0, 0)))
        kr = jnp.pad(kr, ((0, 0), (0, pad_to - S), (0, 0)))
    return out, (ckv, kr)


def gqa_decode(params, x, cache, cfg: ModelConfig, run: RunConfig):
    """One-token decode against a KV cache.

    cache: {"k": (B,Smax,K,D), "v": ..., "pos": (B,) int32} — pos[b] is the
    slot this token writes for row b (per-row: continuous batching);
    kv_len = pos+1. int8 caches carry "k_scale"/"v_scale" (B,Smax,K,1).
    """
    B = x.shape[0]
    pos = cache["pos"]                       # (B,)
    positions = pos[:, None]
    q, k, v = _project_qkv(params, x, cfg, positions)
    rows = jnp.arange(B)
    if "k_scale" in cache:                   # int8 quantized cache
        kq8, ksc = quantize_kv(k[:, 0])
        vq8, vsc = quantize_kv(v[:, 0])
        kq = cache["k"].at[rows, pos].set(kq8)
        vq = cache["v"].at[rows, pos].set(vq8)
        ks = cache["k_scale"].at[rows, pos].set(ksc)
        vs = cache["v_scale"].at[rows, pos].set(vsc)
        o = decode_attention_q8(q, kq, ks, vq, vs, pos + 1)
        out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
        return out, {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs,
                     "pos": pos + 1}
    k_cache = cache["k"].at[rows, pos].set(
        k[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[rows, pos].set(
        v[:, 0].astype(cache["v"].dtype))
    o = decode_attention(q, k_cache, v_cache, pos + 1)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return out, {"k": k_cache, "v": v_cache, "pos": pos + 1}


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                   quant: bool = False):
    K, Dh = cfg.n_kv_heads, cfg.d_head
    if quant:
        return {"k": jnp.zeros((batch, max_len, K, Dh), jnp.int8),
                "v": jnp.zeros((batch, max_len, K, Dh), jnp.int8),
                "k_scale": jnp.zeros((batch, max_len, K, 1), jnp.bfloat16),
                "v_scale": jnp.zeros((batch, max_len, K, 1), jnp.bfloat16),
                "pos": jnp.zeros((batch,), jnp.int32)}
    return {"k": jnp.zeros((batch, max_len, K, Dh), dtype),
            "v": jnp.zeros((batch, max_len, K, Dh), dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}


# ---------------------------------------------------------------------------
# Cross-attention (vision / enc-dec). KV from media embeddings; for decode the
# media KV is static so it is computed once at prefill and carried in cache.
# ---------------------------------------------------------------------------


def init_cross_attn(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return {
        "wq": L.dense_init(ks[0], (d, H, Dh)),
        "wk": L.dense_init(ks[1], (d, K, Dh)),
        "wv": L.dense_init(ks[2], (d, K, Dh)),
        "wo": L.dense_init(ks[3], (H, Dh, d), in_axis_size=H * Dh),
        "gate": jnp.zeros(()),        # llama-vision tanh gate (0-init)
    }


def cross_attn_kv(params, media):
    k = jnp.einsum("bmd,dhk->bmhk", media, params["wk"].astype(media.dtype))
    v = jnp.einsum("bmd,dhk->bmhk", media, params["wv"].astype(media.dtype))
    return k, v


def cross_attn(params, x, kv, run: RunConfig, gated: bool = True):
    k, v = kv
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    if x.shape[1] > 4096:
        o = blocked_attention(q, k, v, causal=False,
                              block_q=run.attn_block_q,
                              block_kv=min(run.attn_block_kv, k.shape[1]))
    else:
        o = full_attention(q, k, v, causal=False)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    if gated:
        out = jnp.tanh(params["gate"]).astype(x.dtype) * out
    return out


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wdq": L.dense_init(ks[0], (d, m.q_lora_rank)),
        "q_norm": jnp.ones((m.q_lora_rank,)),
        "wuq": L.dense_init(ks[1], (m.q_lora_rank, H, qk),
                            in_axis_size=m.q_lora_rank),
        "wdkv": L.dense_init(ks[2], (d, m.kv_lora_rank)),
        "kv_norm": jnp.ones((m.kv_lora_rank,)),
        "wuk": L.dense_init(ks[3], (m.kv_lora_rank, H, m.qk_nope_dim),
                            in_axis_size=m.kv_lora_rank),
        "wuv": L.dense_init(ks[4], (m.kv_lora_rank, H, m.v_head_dim),
                            in_axis_size=m.kv_lora_rank),
        "wkr": L.dense_init(ks[5], (d, m.qk_rope_dim)),
        "wo": L.dense_init(ks[6], (H, m.v_head_dim, d),
                           in_axis_size=H * m.v_head_dim),
    }


def _mla_q(params, x, cfg, positions):
    m = cfg.mla
    cq = jnp.einsum("bsd,dr->bsr", x, params["wdq"].astype(x.dtype))
    cq = L.rms_norm(cq, params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wuq"].astype(x.dtype))
    q_nope = q[..., :m.qk_nope_dim]
    q_rope = L.rotary(q[..., m.qk_nope_dim:], positions, "full", 1.0,
                      cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(params, x, cfg, positions):
    ckv = jnp.einsum("bsd,dr->bsr", x, params["wdkv"].astype(x.dtype))
    ckv = L.rms_norm(ckv, params["kv_norm"], cfg.norm_eps)
    kr = jnp.einsum("bsd,dr->bsr", x, params["wkr"].astype(x.dtype))
    kr = L.rotary(kr[:, :, None, :], positions, "full", 1.0,
                  cfg.rope_theta)[:, :, 0, :]
    return ckv, kr


def mla(params, x, cfg: ModelConfig, run: RunConfig, *, positions=None,
        causal: bool = True):
    """MLA over a full sequence: expand latents to per-head K/V and run the
    blocked softmax core with the combined (nope|rope) q/k."""
    m = cfg.mla
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q_nope, q_rope = _mla_q(params, x, cfg, positions)
    ckv, kr = _mla_latent(params, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, params["wuk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", ckv, params["wuv"].astype(x.dtype))
    H = cfg.n_heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, m.qk_rope_dim))],
        axis=-1)
    # pad v to qk dim so the shared core can be reused, then slice
    qk = m.qk_nope_dim + m.qk_rope_dim
    if run.attn_impl == "full":
        o = full_attention(q, k, v if v.shape[-1] == qk else
                           jnp.pad(v, ((0, 0),) * 3 + ((0, qk - m.v_head_dim),)),
                           causal=causal)
    else:
        vv = v if v.shape[-1] == qk else \
            jnp.pad(v, ((0, 0),) * 3 + ((0, qk - m.v_head_dim),))
        o = blocked_attention(q, k, vv, causal=causal,
                              block_q=run.attn_block_q,
                              block_kv=run.attn_block_kv)
    o = o[..., :m.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))


def mla_decode(params, x, cache, cfg: ModelConfig, run: RunConfig):
    """Absorbed-latent decode: cache only (c_kv, k_rope) = kv_lora+rope dims
    per token (DeepSeek-V3's memory saving), absorb wuk into q and wuv into
    the output path. pos: (B,) per-row positions."""
    m = cfg.mla
    B = x.shape[0]
    pos = cache["pos"]                       # (B,)
    positions = pos[:, None]
    q_nope, q_rope = _mla_q(params, x, cfg, positions)      # (B,1,H,*)
    ckv_t, kr_t = _mla_latent(params, x, cfg, positions)    # (B,1,r),(B,1,rope)
    rows = jnp.arange(B)
    ckv = cache["ckv"].at[rows, pos].set(
        ckv_t[:, 0].astype(cache["ckv"].dtype))
    kr = cache["kr"].at[rows, pos].set(kr_t[:, 0].astype(cache["kr"].dtype))
    # absorb: q_lat (B,1,H,r) = q_nope @ wuk^T
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["wuk"].astype(x.dtype))
    s = (jnp.einsum("bshr,btr->bhst", q_lat, ckv.astype(x.dtype)) +
         jnp.einsum("bshk,btk->bhst", q_rope, kr.astype(x.dtype)))
    s = s.astype(jnp.float32) / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    s = jnp.where(jnp.arange(ckv.shape[1])[None, None, None, :] <=
                  pos[:, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhst,btr->bshr", p, ckv.astype(x.dtype))   # latent ctx
    o = jnp.einsum("bshr,rhk->bshk", ctx, params["wuv"].astype(x.dtype))
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return out, {"ckv": ckv, "kr": kr, "pos": pos + 1}


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {"ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}
