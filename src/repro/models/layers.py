"""Core layer primitives shared by all architectures.

Pure-functional style: ``init_*`` builds a param pytree (nested dicts of
jnp arrays), ``apply``-style functions take (params, x, ...). Weight layout
conventions (chosen for TP sharding; see sharding/rules.py):

  embed:        (vocab, d_model)
  attn q/k/v:   (d_model, n_heads, d_head)      heads -> 'model'
  attn out:     (n_heads, d_head, d_model)      heads -> 'model'
  mlp up/gate:  (d_model, d_ff)                 ff -> 'model'
  mlp down:     (d_ff, d_model)                 ff -> 'model'
  experts:      (E, ...) leading expert dim     E -> 'model'
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis_size: Optional[int] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LM practice)."""
    if in_axis_size is None:
        in_axis_size = shape[0]
    std = 1.0 / math.sqrt(max(in_axis_size, 1))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def zeros_init(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones_init(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings: full / partial / 2d (GLM) / none
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float, positions):
    """(..., dim/2) angle table for given positions (any int array)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """Rotate pairs (x[..., ::2], x[..., 1::2]). x: (..., S, H, D) with
    cos/sin broadcastable (..., S, 1, D/2)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def rotary(x, positions, kind: str, fraction: float, theta: float):
    """Apply RoPE variant to (B, S, H, D) given positions (B, S) or (S,).

    kind: "full"    — rotate all dims
          "partial" — rotate leading `fraction` of dims (nemotron)
          "2d"      — GLM-style: rotate first half of dims with position ids,
                      second quarter-pairs kept — implemented as partial(0.5)
                      over interleaved pairs, which matches ChatGLM's applied
                      form for 1-d text positions.
          "none"
    """
    if kind == "none":
        return x
    d = x.shape[-1]
    rot = d if kind == "full" else int(d * fraction)
    rot = max(2, (rot // 2) * 2)
    if positions.ndim == 1:
        positions = positions[None, :]
    cos, sin = rope_freqs(rot, theta, positions)      # (B, S, rot/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    if rot == d:
        return apply_rope(x, cos, sin)
    xr, xp = x[..., :rot], x[..., rot:]
    return jnp.concatenate([apply_rope(xr, cos, sin), xp], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, kind: str):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "gate": dense_init(ks[0], (d_model, d_ff)),
            "up": dense_init(ks[1], (d_model, d_ff)),
            "down": dense_init(ks[2], (d_ff, d_model), in_axis_size=d_ff),
        }
    # relu2 / gelu: two-matrix MLP
    return {
        "up": dense_init(ks[1], (d_model, d_ff)),
        "down": dense_init(ks[2], (d_ff, d_model), in_axis_size=d_ff),
    }


def mlp(params, x, kind: str):
    if kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, params["up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    elif kind == "relu2":
        u = jnp.einsum("bsd,df->bsf", x, params["up"].astype(x.dtype))
        h = jnp.square(jax.nn.relu(u))
    elif kind == "gelu":
        u = jnp.einsum("bsd,df->bsf", x, params["up"].astype(x.dtype))
        h = jax.nn.gelu(u)
    else:
        raise ValueError(kind)
    return jnp.einsum("bsf,fd->bsd", h, params["down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------


def init_embed(key, vocab: int, d_model: int):
    return dense_init(key, (vocab, d_model), in_axis_size=d_model)


def embed(table, ids, compute_dtype):
    return jnp.take(table, ids, axis=0).astype(compute_dtype)


def logits(table_or_head, x):
    """x: (B, S, D) -> (B, S, V). Head stored (V, D) (embed layout) or (D, V)."""
    w = table_or_head
    if w.shape[0] == x.shape[-1]:
        return jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype))


def cross_entropy(lg, labels, z_loss: float = 0.0):
    """Token-mean CE with optional z-loss; labels < 0 are masked."""
    lg = lg.astype(jnp.float32)
    m = jnp.max(lg, axis=-1, keepdims=True)
    lse = m.squeeze(-1) + jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1))
    tgt = jnp.take_along_axis(
        lg, jnp.maximum(labels, 0)[..., None], axis=-1).squeeze(-1)
    nll = lse - tgt
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
