"""Mamba2 block (SSD — state space dual, chunked scan).

Recurrence per head (state h: (N, P), N = d_state, P = head_dim):
    a_t = exp(dt_t * A)                    (scalar decay per head, A < 0)
    h_t = a_t * h_{t-1} + dt_t * B_t x_t^T
    y_t = C_t^T h_t + D * x_t

Chunked closed form (chunk Q, cum[i] = sum_{k<=i} dt_k*A, all exponents <= 0
so it is unconditionally stable):
    Y_intra[i] = sum_{j<=i} (C_i.B_j) exp(cum[i]-cum[j]) dt_j x_j
    Y_inter[i] = exp(cum[i]) C_i . h_in
    h_out      = exp(cum[Q-1]) h_in + sum_j exp(cum[Q-1]-cum[j]) dt_j B_j x_j^T

The Pallas kernel in repro/kernels/ssd.py implements the same contract;
ref oracle = the recurrent path below.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L


def init_mamba2(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    N = s.d_state
    conv_dim = d_inner + 2 * s.n_groups * N
    ks = jax.random.split(key, 6)
    return {
        # in_proj -> [z (d_inner), xBC (conv_dim), dt (H)]
        "in_proj": L.dense_init(ks[0], (d, 2 * d_inner + 2 * s.n_groups * N + H)),
        "conv_w": L.dense_init(ks[1], (s.d_conv, conv_dim)) * 0.5,
        "conv_b": jnp.zeros((conv_dim,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),    # A = -exp(A_log)
        "D": jnp.ones((H,)),
        "dt_bias": jnp.log(jnp.expm1(                     # softplus^-1 of ~1e-3..1e-1
            jnp.exp(jax.random.uniform(ks[2], (H,),
                                       minval=jnp.log(1e-3), maxval=jnp.log(1e-1))))),
        "ssm_norm": jnp.ones((d_inner,)),
        "out_proj": L.dense_init(ks[3], (d_inner, d), in_axis_size=d_inner),
    }


def _split_in_proj(cfg, proj):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    gN = s.n_groups * s.d_state
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner:2 * d_inner + 2 * gN]
    dt = proj[..., 2 * d_inner + 2 * gN:]
    return z, xBC, dt, d_inner, H, gN


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, width d_conv. xBC: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(W):
        out = out + pad[:, i:i + xBC.shape[1]] * w[i]
    return out + b


def mamba2(params, x, cfg: ModelConfig, run: RunConfig):
    """Full-sequence (train/prefill) Mamba2 block. x: (B,S,d) -> (B,S,d)."""
    s = cfg.ssm
    B, S, d = x.shape
    proj = jnp.einsum("bsd,dk->bsk", x, params["in_proj"].astype(x.dtype))
    z, xBC, dt, d_inner, H, gN = _split_in_proj(cfg, proj)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"].astype(x.dtype),
                                   params["conv_b"].astype(x.dtype)))
    xs = xBC[..., :d_inner].reshape(B, S, H, s.head_dim)
    Bm = xBC[..., d_inner:d_inner + gN].reshape(B, S, s.n_groups, s.d_state)
    Cm = xBC[..., d_inner + gN:].reshape(B, S, s.n_groups, s.d_state)
    # broadcast groups over heads
    rep = H // s.n_groups
    Bm = jnp.repeat(Bm, rep, axis=2)   # (B,S,H,N)
    Cm = jnp.repeat(Cm, rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))            # (H,)

    if run.attn_impl == "pallas":
        from repro.kernels import ops as kops
        y, _ = kops.ssd(xs, dt, A, Bm, Cm, chunk=s.chunk)
    else:
        y, _ = ssd_chunked(xs, dt, A, Bm, Cm, chunk=s.chunk)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xs.astype(y.dtype)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), params["ssm_norm"], cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, params["out_proj"].astype(x.dtype))


def ssd_chunked(xs, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD. xs: (B,S,H,P); dt: (B,S,H) f32; A: (H,); Bm/Cm: (B,S,H,N).
    Returns y (B,S,H,P) f32 and final state (B,H,N,P)."""
    B, S, H, P = xs.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nC = (S + pad) // Q
    xs_c = xs.reshape(B, nC, Q, H, P).astype(jnp.float32)
    dt_c = dt.reshape(B, nC, Q, H)
    Bm_c = Bm.reshape(B, nC, Q, H, N).astype(jnp.float32)
    Cm_c = Cm.reshape(B, nC, Q, H, N).astype(jnp.float32)

    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def per_chunk(h, inp):
        xq, dq, bq, cq = inp          # (B,Q,H,P),(B,Q,H),(B,Q,H,N),(B,Q,H,N)
        la = dq * A[None, None, :]    # (B,Q,H) log-decay per step, <= 0
        cum = jnp.cumsum(la, axis=1)  # (B,Q,H)
        # intra-chunk: M[b,h,i,j] = (C_i.B_j) exp(cum_i-cum_j) dt_j  (j<=i)
        cb = jnp.einsum("bihn,bjhn->bhij", cq, bq)
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,i,j,H)
        dec = jnp.where(jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :],
                        dec.transpose(0, 3, 1, 2), 0.0)          # (B,H,i,j)
        M = cb * dec * dq.transpose(0, 2, 1)[:, :, None, :]      # *dt_j
        y_intra = jnp.einsum("bhij,bjhp->bihp", M, xq)
        # inter-chunk: exp(cum_i) C_i . h_in
        y_inter = jnp.einsum("bihn,bhnp->bihp", cq, h) * \
            jnp.exp(cum)[:, :, :, None]
        # state update
        tail = jnp.exp(cum[:, -1:, :] - cum)                     # (B,Q,H)
        h_new = h * jnp.exp(cum[:, -1])[:, :, None, None] + \
            jnp.einsum("bjhn,bjhp->bhnp", bq * (tail * dq)[..., None], xq)
        return h_new, y_intra + y_inter

    h_fin, ys = lax.scan(per_chunk, h0,
                         (xs_c.transpose(1, 0, 2, 3, 4),
                          dt_c.transpose(1, 0, 2, 3),
                          Bm_c.transpose(1, 0, 2, 3, 4),
                          Cm_c.transpose(1, 0, 2, 3, 4)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nC * Q, H, P)
    return y[:, :S], h_fin


def ssd_recurrent(xs, dt, A, Bm, Cm, h0=None):
    """Step-by-step oracle for tests / ref.py. Same signature as chunked."""
    B, S, H, P = xs.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        a = jnp.exp(dt_t * A[None, :])                 # (B,H)
        h = h * a[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhnp", b_t * dt_t[..., None], x_t)
        y = jnp.einsum("bhn,bhnp->bhp", c_t, h)
        return h, y

    xs32 = xs.astype(jnp.float32)
    h, ys = lax.scan(step, h0,
                     (xs32.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
                      Bm.astype(jnp.float32).transpose(1, 0, 2, 3),
                      Cm.astype(jnp.float32).transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3), h


def mamba2_decode(params, x, cache, cfg: ModelConfig, run: RunConfig):
    """One-token decode. cache: {"h": (B,H,N,P) f32, "conv": (B,W-1,convdim)}."""
    s = cfg.ssm
    B = x.shape[0]
    proj = jnp.einsum("bsd,dk->bsk", x, params["in_proj"].astype(x.dtype))
    z, xBC, dt, d_inner, H, gN = _split_in_proj(cfg, proj)
    # conv with carried window
    W = s.d_conv
    win = jnp.concatenate([cache["conv"], xBC.astype(cache["conv"].dtype)], 1)
    conv_out = jnp.einsum("bwc,wc->bc", win, params["conv_w"].astype(win.dtype))
    xBC = jax.nn.silu(conv_out + params["conv_b"].astype(win.dtype))[:, None, :]
    new_conv = win[:, 1:]
    xs = xBC[..., :d_inner].reshape(B, 1, H, s.head_dim)
    rep = H // s.n_groups
    Bm = jnp.repeat(xBC[..., d_inner:d_inner + gN]
                    .reshape(B, 1, s.n_groups, s.d_state), rep, 2)
    Cm = jnp.repeat(xBC[..., d_inner + gN:]
                    .reshape(B, 1, s.n_groups, s.d_state), rep, 2)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) +
                          params["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    h = cache["h"]
    a = jnp.exp(dtv * A[None, :])
    h = h * a[:, :, None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bm[:, 0].astype(jnp.float32) * dtv[..., None],
        xs[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), h)
    y = y + params["D"].astype(y.dtype)[None, :, None] * \
        xs[:, 0].astype(jnp.float32)
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), params["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"].astype(x.dtype))
    return out, {"h": h, "conv": new_conv}


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return {"h": jnp.zeros((batch, H, s.d_state, s.head_dim), jnp.float32),
            "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype)}
